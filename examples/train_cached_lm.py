"""End-to-end driver: train an LM for a few hundred steps with every input
byte served through IGTCache (delegates to the production launcher).

    PYTHONPATH=src python examples/train_cached_lm.py --steps 200

Use ``--arch mamba2-370m --reduced`` etc. to pick any assigned architecture;
``--cache-bundle juicefs`` swaps the cache policy bundle under the SAME
training code (the paper's "no code intrusion" property).
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "qwen3-1.7b", "--reduced", "--steps", "200",
                "--batch", "4", "--seq", "256"] + argv
    elif "--reduced" not in argv:
        argv = ["--reduced"] + argv
    raise SystemExit(main(argv))

"""Quickstart: watch IGTCache observe → classify → adapt, in 60 seconds.

Three workloads hit one unified cache: a sequential scan, random training
epochs, and zipf-hot RAG queries.  The engine classifies each stream from its
access gaps (K-S test) and picks prefetch/eviction per stream — no hints.

The cache is opened through the client API (``open_cache``): the client
owns prefetch execution (here the deterministic ``SimExecutor`` — this
script drives a virtual clock) and can return the actual bytes, so no
caller ever loops over prefetch candidates by hand.

Part 2 is the ``file://`` walkthrough: the same ``open_cache`` call
pointed at a *real directory* (the URI store registry resolves
``file:///dir`` to a ``LocalFSStore``), serving actual file bytes with
ranged reads — the storage API that turns the reproduction from
simulator-only into a system you can run on your own data.

Part 3 is the cache *daemon*: the same directory served as a network
service (``repro.daemon.CacheDaemon`` on a unix socket), with two
independent ``open_cache("cache://...")`` clients sharing one cache —
the second client's reads hit blocks the first one warmed.

Part 4 is *tiered storage over an object store*: a ``mock-s3://``
bucket (a real in-process HTTP server speaking ranged GETs) behind a
``tiered+`` RAM+disk hierarchy — blocks the kernel evicts spill to
checksummed local files and are re-served from disk instead of
re-crossing the network.

Part 5 is the *survivable* daemon: the same service journaled to disk
and run under a ``DaemonSupervisor``.  We kill the daemon mid-stream —
reads keep flowing (degraded, straight from the backing store), the
supervisor respawns it on the same socket, the journal warm-restores
the cache manifest, and the client reconnects by itself and goes right
back to hitting.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import os
import random
import tempfile

import numpy as np

from repro.core import CacheConfig, open_cache
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset


def main():
    store = RemoteStore()
    store.add(make_dataset("scan_set", "flat_files", n_files=600,
                           small_file_size=256 * 1024))
    store.add(make_dataset("train_set", "dir_tree", n_dirs=30,
                           files_per_dir=20, small_file_size=256 * 1024))
    store.add(make_dataset("rag_set", "flat_files", n_files=400,
                           small_file_size=256 * 1024))
    cfg = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB,
                      rebalance_period=5.0)
    client = open_cache(store, 256 * MB, cfg=cfg, executor="sim")

    t = 0.0
    rng = random.Random(0)
    nrng = np.random.default_rng(0)
    scan = store.datasets["scan_set"].files
    train = store.datasets["train_set"].files
    rag = store.datasets["rag_set"].files
    rag_perm = nrng.permutation(len(rag))
    train_order = list(range(len(train)))

    si = 0
    first_bytes = None
    for epoch in range(3):
        rng.shuffle(train_order)
        for j in train_order:
            # one sequential access
            f = scan[si % len(scan)]; si += 1
            client.read(f.path, 0, f.size, t); t += 0.01
            # one random-training access
            f = train[j]
            client.read(f.path, 0, f.size, t); t += 0.01
            # one zipf RAG access — ask the client for the bytes too
            f = rag[int(rag_perm[(nrng.zipf(1.3) - 1) % len(rag)])]
            res = client.read(f.path, 0, f.size, t, fetch=True); t += 0.01
            if first_bytes is None:
                first_bytes = len(res.data)

    print("\nDetected streams (pattern → policy chosen by the cache):")
    for path, cmu in sorted(client.iter_workload_cmus()):
        tot = cmu.hits + cmu.misses
        pats = {s.pattern.value: type(s.policy).__name__
                for s in cmu.substreams.values()}
        print(f"  {'/'.join(path):22s} pattern={cmu.effective_pattern().value:10s} "
              f"quota={cmu.quota >> 20:4d}MB hit_ratio={cmu.hits / max(1, tot):.2f} "
              f"policies={pats}")
    s = client.snapshot()
    print(f"\nOverall: CHR={s['hit_ratio']:.3f}  prefetch_hits={s['prefetch_hits']}"
          f"  tree_nodes={s['nodes']}")
    print(f"Executor: {s['executor']}  (first fetched passage: "
          f"{first_bytes} bytes)")
    print("Sequential stream should show eager+prefetch, random → uniform "
          "pinning, zipf → LRU.")


def file_store_walkthrough():
    """The ``file://`` path: cache a real directory tree.

    Everything below works identically against ``sim://`` — that is the
    point of the URI store registry: the client and kernel never learn
    which backend serves the bytes.
    """
    print("\n--- file:// walkthrough ------------------------------------")
    root = tempfile.mkdtemp(prefix="igt-quickstart-")
    rng = np.random.default_rng(0)
    for d in range(3):
        os.makedirs(os.path.join(root, "corpus", f"{d:02d}"))
        for i in range(4):
            data = rng.integers(0, 256, 192 * 1024, dtype=np.uint8)
            with open(os.path.join(root, "corpus", f"{d:02d}",
                                   f"{i:03d}.bin"), "wb") as f:
                f.write(data.tobytes())

    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=64 * 1024)
    # open_cache accepts a URI: file:///dir → LocalFSStore (real bytes,
    # ranged reads); "threaded" runs background prefetch workers that
    # retry transient store errors per the client's RetryPolicy
    client = open_cache(f"file://{root}", 16 * MB, cfg=cfg,
                        executor="threaded", fetch_bytes=True)
    caps = client.store_capabilities()
    print(f"store: LocalFSStore over {root}")
    print(f"negotiated capabilities: ranges={caps.ranges} "
          f"batching={caps.batching} concurrency={caps.concurrency}")

    files = [("corpus", f"{d:02d}", f"{i:03d}.bin")
             for d in range(3) for i in range(4)]
    for rel in files:                       # pass 1: demand misses
        res = client.read(rel, 0, client.meta.file_size(rel))
        on_disk = open(os.path.join(root, *rel), "rb").read()
        assert bytes(res.data) == on_disk, "client bytes != on-disk bytes"
    hits = 0
    for rel in files:                       # pass 2: served from cache
        res = client.read(rel, 0, client.meta.file_size(rel))
        hits += sum(1 for b in res.blocks if b.hit)
    # partial-extent read: only the requested sub-range moves (fetch_range)
    res = client.read(files[0], 100_000, 5_000)
    assert len(res.data) == 5_000
    client.flush(timeout=10.0)
    snap = client.snapshot()
    client.close()
    print(f"pass 1 verified against on-disk bytes; pass 2 hit "
          f"{hits}/{sum(1 for _ in files) * 3} blocks in cache")
    print(f"executor accounting: {snap['executor']}")


def daemon_walkthrough():
    """Cache-as-a-service: one daemon, many client processes.

    The daemon wraps the same ``open_cache`` stack behind a unix
    socket; thin clients connect with ``open_cache("cache://<sock>")``
    and share one kernel — one allocation, one hit-ratio, one prefetch
    timeline — instead of each process running its own cache.
    """
    print("\n--- cache:// daemon walkthrough ----------------------------")
    from repro.daemon import CacheDaemon

    root = tempfile.mkdtemp(prefix="igt-daemon-")
    rng = np.random.default_rng(1)
    for d in range(2):
        os.makedirs(os.path.join(root, "shared", f"{d:02d}"))
        for i in range(4):
            data = rng.integers(0, 256, 128 * 1024, dtype=np.uint8)
            with open(os.path.join(root, "shared", f"{d:02d}",
                                   f"{i:03d}.bin"), "wb") as f:
                f.write(data.tobytes())
    files = [("shared", f"{d:02d}", f"{i:03d}.bin")
             for d in range(2) for i in range(4)]

    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=64 * 1024)
    # no uds= → the daemon picks a temp socket; d.uri is the address
    with CacheDaemon(f"file://{root}", 16 * MB, cfg=cfg) as d:
        print(f"daemon up at {d.uri}")

        # client A (think: trainer #1) — cold reads, verified on-disk
        with open_cache(d.uri, fetch_bytes=True) as a:
            for rel in files:
                res = a.read(rel, 0, a.meta.file_size(rel))
                on_disk = open(os.path.join(root, *rel), "rb").read()
                assert bytes(res.data) == on_disk, "daemon bytes != disk"
        print(f"client A verified {len(files)} files against disk "
              "(cold: demand misses warm the shared cache)")

        # client B (trainer #2, a *separate* session) rides A's warmth
        with open_cache(d.uri, fetch_bytes=True) as b:
            hits = total = 0
            for rel in files:
                res = b.read(rel, 0, b.meta.file_size(rel))
                total += len(res.blocks)
                hits += sum(1 for blk in res.blocks if blk.hit)
        print(f"client B hit {hits}/{total} blocks without fetching a "
              "byte from the store")

        st = d.daemon_stats()
        print(f"daemon accounting: sessions_served={st['byes']} "
              f"served_reads={st['served_reads']} spills={st['spills']} "
              f"arena_free={st['arena_free']}/{st['arena_total']}")


def tiered_s3_walkthrough():
    """Tiered RAM+disk cache over an object store.

    ``tiered+mock-s3://...`` composes two registry schemes: the inner
    store is a deterministic S3-dialect HTTP server (ranged GETs, so
    only the requested extent crosses the wire), and the ``tiered+``
    wrapper keeps hot blocks in RAM while spilling kernel-evicted
    blocks to checksummed files in a local spill directory.  A second
    pass over the data is then served from local disk — zero network
    bytes — and every payload is verified against the bucket's
    deterministic contents.
    """
    print("\n--- tiered+mock-s3:// walkthrough --------------------------")
    from repro.storage.s3 import mock_object_bytes

    spill = tempfile.mkdtemp(prefix="igt-spill-")
    # 2 dirs x 3 objects of 128KB each, synthesized from the URI's seed
    # ram_bytes=256KB holds only 4 of the 12 blocks: the rest must spill
    uri = (f"tiered+mock-s3://quickstart/corpus?dirs=2&files=3&file_kb=128"
           f"&block_size=65536&ram_bytes=262144&disk_mb=8&spill_dir={spill}")
    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=64 * 1024)
    client = open_cache(uri, 2 * MB, cfg=cfg, executor="threaded",
                        fetch_bytes=True)
    files = [("corpus", f"{d:02d}", f"{i:03d}.bin")
             for d in range(2) for i in range(3)]
    for rel in files:                       # pass 1: ranged GETs, verified
        res = client.read(rel, 0, client.meta.file_size(rel))
        want = bytes(mock_object_bytes("corpus", "/".join(rel[1:]),
                                       0, 128 * 1024))
        assert bytes(res.data) == want, "client bytes != bucket bytes"
    for rel in files:                       # pass 2: RAM + spill tier serve
        res = client.read(rel, 0, client.meta.file_size(rel))
        want = bytes(mock_object_bytes("corpus", "/".join(rel[1:]),
                                       0, 128 * 1024))
        assert bytes(res.data) == want, "tier bytes != bucket bytes"
    client.flush(timeout=10.0)
    tiers = client.snapshot()["store"]["tiers"]
    client.close()
    print(f"pass 1 fetched {len(files)} objects over ranged HTTP GETs "
          "(bytes verified)")
    print(f"pass 2 served from the tiers: ram_hits={tiers['ram_hits']} "
          f"disk_hits={tiers['disk_hits']} spills={tiers['spills']} "
          f"(spill dir: {tiers['spill_dir']})")
    print(f"tier occupancy: ram={tiers['ram_used'] >> 10}KB "
          f"disk={tiers['disk_used'] >> 10}KB "
          f"remote bytes after warmup: {tiers['remote_bytes'] >> 10}KB")


def survivable_daemon_walkthrough():
    """Kill the daemon, keep reading, come back warm.

    The daemon journals admission-relevant mutations and periodically
    snapshots the engine's warm-restart manifest; a
    ``DaemonSupervisor`` respawns a crashed daemon on the same socket
    within a restart budget.  The client needs no ceremony: with a
    ``backing=`` store it serves reads degraded while the daemon is
    away, then reconnects and replays its pins on its own.
    """
    print("\n--- survivable daemon walkthrough --------------------------")
    import time

    from repro.daemon import CacheDaemon, DaemonSupervisor

    root = tempfile.mkdtemp(prefix="igt-survive-")
    data = os.path.join(root, "data")
    rng = np.random.default_rng(2)
    os.makedirs(os.path.join(data, "set"))
    for i in range(16):
        blob = rng.integers(0, 256, 128 * 1024, dtype=np.uint8)
        with open(os.path.join(data, "set", f"{i:03d}.bin"), "wb") as f:
            f.write(blob.tobytes())
    files = [("set", f"{i:03d}.bin") for i in range(16)]

    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=64 * 1024)
    sock = os.path.join(root, "igt.sock")
    jdir = os.path.join(root, "journal")

    # the factory is the supervisor's respawn recipe: same socket path,
    # same journal dir — a new daemon replays the journal on start
    def factory():
        return CacheDaemon(f"file://{data}", 16 * MB, cfg=cfg, uds=sock,
                           journal_dir=jdir, snapshot_every_s=0.2).start()

    sup = DaemonSupervisor(factory, restart_budget=3)
    # backing= gives the client a local byte path for degraded reads;
    # it must agree with the daemon on block geometry (block keys name
    # block-index extents, resolved against the store's block_size)
    client = open_cache(sup.uri, fetch_bytes=True,
                        backing=f"file://{data}?block_size=65536")
    try:
        # warm the shared cache (shuffled so the streams stay resident
        # rather than classifying sequential and eagerly evicting)
        for i in rng.permutation(len(files)):
            client.read(files[i], 0, client.meta.file_size(files[i]))
        sup.daemon.write_snapshot()     # pin the manifest for the drill
        print(f"warmed {len(files)} files; journal at {jdir}")

        sup.kill_daemon()               # sockets die mid-conversation
        degraded = 0
        for i in rng.permutation(len(files)):
            res = client.read(files[i], 0,
                              client.meta.file_size(files[i]))
            on_disk = open(os.path.join(data, *files[i]), "rb").read()
            assert bytes(res.data) == on_disk, "degraded bytes != disk"
        degraded = client.client_stats.degraded_reads
        print(f"daemon killed: {degraded} reads served degraded from "
              "the backing store (bytes verified), zero errors")

        deadline = time.monotonic() + 10.0
        while client.state != "up" and time.monotonic() < deadline:
            time.sleep(0.05)
        assert client.state == "up", "client did not reconnect"
        ev = next(e for e in reversed(sup.supervisor_stats()["events"])
                  if e["kind"] == "respawn_done")
        print(f"supervisor respawned the daemon in "
              f"{ev['recovery_s'] * 1e3:.1f}ms; journal restore: "
              f"mode={ev['restore']['mode']} "
              f"blocks={ev['restore']['blocks']}")

        hits = total = 0
        for i in rng.permutation(len(files)):
            res = client.read(files[i], 0,
                              client.meta.file_size(files[i]))
            total += len(res.blocks)
            hits += sum(1 for blk in res.blocks if blk.hit)
        conn = client.connection_stats()
        print(f"after auto-reconnect (session {conn['reconnects']} "
              f"reconnect): {hits}/{total} blocks hit the warm-restored "
              "cache")
    finally:
        client.close()
        sup.close()


if __name__ == "__main__":
    main()
    file_store_walkthrough()
    daemon_walkthrough()
    tiered_s3_walkthrough()
    survivable_daemon_walkthrough()

"""Quickstart: watch IGTCache observe → classify → adapt, in 60 seconds.

Three workloads hit one unified cache: a sequential scan, random training
epochs, and zipf-hot RAG queries.  The engine classifies each stream from its
access gaps (K-S test) and picks prefetch/eviction per stream — no hints.

The cache is opened through the client API (``open_cache``): the client
owns prefetch execution (here the deterministic ``SimExecutor`` — this
script drives a virtual clock) and can return the actual bytes, so no
caller ever loops over prefetch candidates by hand.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import random

import numpy as np

from repro.core import CacheConfig, open_cache
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset


def main():
    store = RemoteStore()
    store.add(make_dataset("scan_set", "flat_files", n_files=600,
                           small_file_size=256 * 1024))
    store.add(make_dataset("train_set", "dir_tree", n_dirs=30,
                           files_per_dir=20, small_file_size=256 * 1024))
    store.add(make_dataset("rag_set", "flat_files", n_files=400,
                           small_file_size=256 * 1024))
    cfg = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB,
                      rebalance_period=5.0)
    client = open_cache(store, 256 * MB, cfg=cfg, executor="sim")

    t = 0.0
    rng = random.Random(0)
    nrng = np.random.default_rng(0)
    scan = store.datasets["scan_set"].files
    train = store.datasets["train_set"].files
    rag = store.datasets["rag_set"].files
    rag_perm = nrng.permutation(len(rag))
    train_order = list(range(len(train)))

    si = 0
    first_bytes = None
    for epoch in range(3):
        rng.shuffle(train_order)
        for j in train_order:
            # one sequential access
            f = scan[si % len(scan)]; si += 1
            client.read(f.path, 0, f.size, t); t += 0.01
            # one random-training access
            f = train[j]
            client.read(f.path, 0, f.size, t); t += 0.01
            # one zipf RAG access — ask the client for the bytes too
            f = rag[int(rag_perm[(nrng.zipf(1.3) - 1) % len(rag)])]
            res = client.read(f.path, 0, f.size, t, fetch=True); t += 0.01
            if first_bytes is None:
                first_bytes = len(res.data)

    print("\nDetected streams (pattern → policy chosen by the cache):")
    for path, cmu in sorted(client.iter_workload_cmus()):
        tot = cmu.hits + cmu.misses
        pats = {s.pattern.value: type(s.policy).__name__
                for s in cmu.substreams.values()}
        print(f"  {'/'.join(path):22s} pattern={cmu.effective_pattern().value:10s} "
              f"quota={cmu.quota >> 20:4d}MB hit_ratio={cmu.hits / max(1, tot):.2f} "
              f"policies={pats}")
    s = client.snapshot()
    print(f"\nOverall: CHR={s['hit_ratio']:.3f}  prefetch_hits={s['prefetch_hits']}"
          f"  tree_nodes={s['nodes']}")
    print(f"Executor: {s['executor']}  (first fetched passage: "
          f"{first_bytes} bytes)")
    print("Sequential stream should show eager+prefetch, random → uniform "
          "pinning, zipf → LRU.")


if __name__ == "__main__":
    main()

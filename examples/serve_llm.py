"""Serve a small LM with batched requests; RAG retrievals flow through the
unified cache (a skewed stream → the cache converges to LRU for it).

The retrieval cache is a ``CacheClient`` (``open_cache``): serving runs on
the wall clock, so prefetch candidates execute on the background
``ThreadedExecutor`` instead of inside the request path.

    PYTHONPATH=src python examples/serve_llm.py --requests 12
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core import CacheConfig, open_cache
from repro.core.types import MB
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServingEngine
from repro.storage import RemoteStore, make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))

    store = RemoteStore()
    store.add(make_dataset("knowledge", "flat_files", n_files=500,
                           small_file_size=64 * 1024))
    cache = open_cache(store, 16 * MB,
                       cfg=CacheConfig(min_share=2 * MB,
                                       rebalance_quantum=2 * MB),
                       executor="threaded")
    srv = ServingEngine(params, cfg, batch=args.batch, max_seq=128,
                        cache_engine=cache, knowledge_dataset="knowledge",
                        retrieval_k=4)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(3, 8),
                              dtype=np.int32)
        srv.submit(Request(rid, prompt, max_new=args.max_new))
    done = srv.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    for r in done[:4]:
        print(f"  req{r.rid}: retrieved {r.retrieved} passages → "
              f"tokens {r.output}")
    cache.flush(timeout=5.0)
    s = cache.snapshot()
    pattern = next((c.effective_pattern().value
                    for _p, c in cache.iter_workload_cmus()), "?")
    print(f"retrieval cache: CHR={s['hit_ratio']:.3f} over "
          f"{s['hits']+s['misses']} passage reads (pattern: {pattern}; "
          f"executor: {s['executor']})")
    cache.close()


if __name__ == "__main__":
    main()

"""The paper's headline scenario: 18 heterogeneous AI jobs, one unified
cache, discrete-event cluster simulation — IGTCache vs vanilla JuiceFS vs no
cache.

    PYTHONPATH=src python examples/mixed_cluster.py [--scale 0.5]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import CacheConfig, bundle_client
from repro.core.types import MB
from repro.sim import ClusterSim, make_paper_suite
from repro.storage import RemoteStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    suite = make_paper_suite(scale=args.scale, seed=args.seed)
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())
    share = max(16 * MB, cap // 128)
    cfg = CacheConfig(min_share=share, rebalance_quantum=share,
                      rebalance_period=10.0,
                      prefetch_budget_bytes=max(64 * MB, cap // 8))
    print(f"{len(suite.jobs)} jobs, data {suite.total_bytes() >> 20} MB, "
          f"cache {cap >> 20} MB (35%)\n")
    results = {}
    for name in ("igtcache", "juicefs", "nocache"):
        # one constructor path for every consumer: the sim swaps the
        # client's prefetch transport onto its simulated link internally
        client = bundle_client("prefetch_none" if name == "nocache" else name,
                               store, 0 if name == "nocache" else cap,
                               cfg=cfg)
        res = ClusterSim(suite, client).run()
        results[name] = res
        print(f"{name:10s} avg JCT {res.avg_jct:8.1f}s   "
              f"CHR {res.hit_ratio:.3f}   makespan {res.makespan:7.0f}s")
    ig, ju, nc = (results[k] for k in ("igtcache", "juicefs", "nocache"))
    print(f"\nIGTCache vs JuiceFS : JCT −{(1-ig.avg_jct/ju.avg_jct)*100:.1f}%  "
          f"CHR +{(ig.hit_ratio/ju.hit_ratio-1)*100:.1f}%")
    print(f"JuiceFS  vs no-cache: JCT −{(1-ju.avg_jct/nc.avg_jct)*100:.1f}%  "
          f"(paper: 55.0%)")


if __name__ == "__main__":
    main()

"""Tiered-storage benchmark (the ``tier_path`` axis).

The tiering claim: at *equal total capacity*, splitting the budget into
a RAM kernel tier plus a local-disk spill tier beats a flat RAM cache
on the mixed paper suite.  The kernel never retains sequential blocks
(eager eviction / demand read-through), so flat RAM beyond the
random/skewed working sets is wasted — the disk tier captures scan sets
between epochs and re-serves them at disk cost instead of crossing the
shared remote link.

Protocol: ``build_world(scale, seed, cache_ratio=0.5)`` (0.5 so the flat
baseline is *saturated*, not capacity-starved); flat = IGTCache at the
full budget; tiered = IGTCache at ``ram_frac`` of the budget over a
``TieredStore(mode="index")`` whose disk tier holds the remainder.
Metrics: combined CHR ((kernel hits + disk hits) / lookups), remote
link bytes-moved, and mean JCT.  A bytes-mode spill/promote throughput
micro rides along.  Results merge into ``BENCH_overhead.json`` under
``tier_path`` (``--smoke`` → the smoke file; exercised by
tests/test_bench_smoke.py).
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

# .common bootstraps sys.path with REPO_ROOT/src — must import before repro
from .common import build_world, csv_row, merge_overhead_section, scaled_cfg

from repro.core import bundle_client
from repro.core.types import MB
from repro.sim import ClusterSim
from repro.storage import MemStore, TieredStore

RAM_FRAC = 0.8


def _run(suite, store, ram, disk):
    """One ClusterSim pass: IGTCache kernel over ``ram`` bytes, with an
    index-mode disk tier of ``disk`` bytes (0 = flat baseline)."""
    backing = store
    if disk > 0:
        backing = TieredStore(store, mode="index", ram_bytes=ram,
                              disk_bytes=disk)
    client = bundle_client("igtcache", backing, ram, cfg=scaled_cfg(ram))
    res = ClusterSim(suite, client).run()
    kh, km = res.stats["hits"], res.stats["misses"]
    disk_hits = res.tier_stats.get("disk_hits", 0)
    return {
        "capacity_mb": round((ram + disk) / MB, 1),
        "ram_mb": round(ram / MB, 1),
        "disk_mb": round(disk / MB, 1),
        "kernel_chr": round(res.hit_ratio, 4),
        "combined_chr": round((kh + disk_hits) / max(1, kh + km), 4),
        "link_mb": round(res.link_bytes / MB, 1),
        "avg_jct_s": round(res.avg_jct, 2),
        "makespan_s": round(res.makespan, 2),
        "tier": {k: res.tier_stats[k]
                 for k in ("disk_hits", "prefetch_disk_hits", "misses",
                           "admission_skips", "disk_evictions")
                 if k in res.tier_stats},
    }


def _spill_micro(n_blocks: int, block: int = 256 * 1024):
    """Bytes-mode disk-tier throughput: spill N blocks, promote them
    back; MB/s each way (checksummed file writes + verified reads)."""
    mem = MemStore(block_size=block)
    rng = np.random.default_rng(0)
    for i in range(n_blocks):
        mem.add_file(("micro", f"f{i:04d}"),
                     rng.integers(0, 256, block, dtype=np.uint8).tobytes())
    with tempfile.TemporaryDirectory(prefix="igt-bench-") as root:
        ts = TieredStore(mem, ram_bytes=block, disk_bytes=(n_blocks + 1) * block,
                         spill_dir=root)
        paths = [("micro", f"f{i:04d}", "#0") for i in range(n_blocks)]
        t0 = time.perf_counter()
        for p in paths:
            ts.fetch_range(p, 0, block)      # fill + spill on RAM pressure
        spill_dt = time.perf_counter() - t0
        spilled = ts.tier_stats()["spills"]
        t0 = time.perf_counter()
        for p in paths:
            ts.fetch_range(p, 0, block)      # disk hit + promote
        read_dt = time.perf_counter() - t0
        hits = ts.tier_stats()["disk_hits"]
    total_mb = n_blocks * block / MB
    return {"blocks": n_blocks, "block_kb": block // 1024,
            "spilled": spilled, "disk_hits": hits,
            "spill_MBps": round(total_mb / spill_dt, 1),
            "promote_MBps": round(total_mb / read_dt, 1)}


def main(smoke: bool = False, seed: int = 0, json_path=None):
    scale = 0.02 if smoke else 0.05
    suite, store, cap = build_world(scale, seed, cache_ratio=0.5)
    ram = int(cap * RAM_FRAC)

    section = {"smoke": smoke, "seed": seed, "scale": scale,
               "cache_ratio": 0.5, "ram_frac": RAM_FRAC}
    section["flat"] = _run(suite, store, cap, 0)
    # fresh suite: the sim mutates job state in place
    suite, store, _cap = build_world(scale, seed, cache_ratio=0.5)
    section["tiered"] = _run(suite, store, ram, cap - ram)
    section["spill_micro"] = _spill_micro(16 if smoke else 64)

    flat, tiered = section["flat"], section["tiered"]
    section["chr_gain"] = round(tiered["combined_chr"] - flat["kernel_chr"], 4)
    section["link_mb_saved"] = round(flat["link_mb"] - tiered["link_mb"], 1)
    if not smoke:
        # the acceptance claim: equal total budget, tiered wins both axes
        assert tiered["combined_chr"] > flat["kernel_chr"], section
        assert tiered["link_mb"] < flat["link_mb"], section

    rows = [
        csv_row("tier_path.flat_chr", flat["kernel_chr"],
                f"link_mb={flat['link_mb']} jct={flat['avg_jct_s']}"),
        csv_row("tier_path.tiered_combined_chr", tiered["combined_chr"],
                f"kernel_chr={tiered['kernel_chr']} "
                f"link_mb={tiered['link_mb']} jct={tiered['avg_jct_s']}"),
        csv_row("tier_path.chr_gain", section["chr_gain"],
                f"link_mb_saved={section['link_mb_saved']}"),
        csv_row("tier_path.spill_MBps", section["spill_micro"]["spill_MBps"],
                f"promote_MBps={section['spill_micro']['promote_MBps']}"),
    ]
    merge_overhead_section("tier_path", section, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled run for the test job")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke=args.smoke, seed=args.seed)

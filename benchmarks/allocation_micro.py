"""Fig. 12/13 — cache allocation schemes on four space-sensitive jobs
(datasets scaled 10× down, shared cache scaled accordingly — as §5.4)."""
from __future__ import annotations

import json

from .common import build_world, csv_row, run_sim

JOBS = [9, 13, 14, 16]
BUNDLES = ["alloc_igt", "alloc_shared", "alloc_quiver", "alloc_fluid"]


def main(scale: float = 1.0, seed: int = 0):
    # ×0.1 datasets (the paper's own scaling for this experiment)
    suite, store, cap = build_world(scale=scale * 0.35, seed=seed,
                                    job_filter=JOBS, cache_ratio=0.20)
    rows = []
    res_by = {}
    for b in BUNDLES:
        res, eng = run_sim(suite, store, cap, b, trace_alloc=(b == "alloc_igt"))
        res_by[b] = res
        rows.append(csv_row(f"fig12.{b}.avg_jct_s", round(res.avg_jct, 1),
                            f"chr={res.hit_ratio:.3f}"))
        if b == "alloc_igt" and res.alloc_trace:
            # Fig 13: dump the quota/benefit time series
            with open("bench_alloc_trace.json", "w") as f:
                json.dump(res.alloc_trace[:400], f, default=str)
    igt = res_by["alloc_igt"]
    second_jct = min(r.avg_jct for k, r in res_by.items() if k != "alloc_igt")
    second_chr = max(r.hit_ratio for k, r in res_by.items()
                     if k != "alloc_igt")
    rows.append(csv_row("fig12.jct_reduction_vs_second_best_pct",
                        round((1 - igt.avg_jct / second_jct) * 100, 1),
                        "paper=7.5"))
    rows.append(csv_row("fig12.chr_gain_vs_second_best_pct",
                        round((igt.hit_ratio / second_chr - 1) * 100, 1),
                        "paper=10.1"))
    return rows


if __name__ == "__main__":
    main()

"""Fig. 12/13 — cache allocation schemes on four space-sensitive jobs
(datasets scaled 10× down, shared cache scaled accordingly — as §5.4).

``run_sketch_micro`` additionally measures the PR-7 demand-tracking
pipeline at 1M distinct blocks: per-access update, per-round per-stream
demand query, wire serialization, and coordinator-side deserialize+merge
— sketch (CMS + SpaceSaving) vs the exact per-block ghost-counter path
it replaced.  The bench *asserts* the sketch pipeline costs no more per
access than the exact pipeline while shipping O(KB) instead of O(MB);
results land in the shared overhead JSON's ``sketch_path`` section.
"""
from __future__ import annotations

import gc
import json
import pickle
import time

import numpy as np

from .common import build_world, csv_row, merge_overhead_section, run_sim

JOBS = [9, 13, 14, 16]
BUNDLES = ["alloc_igt", "alloc_shared", "alloc_quiver", "alloc_fluid"]


def main(scale: float = 1.0, seed: int = 0):
    # ×0.1 datasets (the paper's own scaling for this experiment)
    suite, store, cap = build_world(scale=scale * 0.35, seed=seed,
                                    job_filter=JOBS, cache_ratio=0.20)
    rows = []
    res_by = {}
    for b in BUNDLES:
        res, eng = run_sim(suite, store, cap, b, trace_alloc=(b == "alloc_igt"))
        res_by[b] = res
        rows.append(csv_row(f"fig12.{b}.avg_jct_s", round(res.avg_jct, 1),
                            f"chr={res.hit_ratio:.3f}"))
        if b == "alloc_igt" and res.alloc_trace:
            # Fig 13: dump the quota/benefit time series
            with open("bench_alloc_trace.json", "w") as f:
                json.dump(res.alloc_trace[:400], f, default=str)
    igt = res_by["alloc_igt"]
    second_jct = min(r.avg_jct for k, r in res_by.items() if k != "alloc_igt")
    second_chr = max(r.hit_ratio for k, r in res_by.items()
                     if k != "alloc_igt")
    rows.append(csv_row("fig12.jct_reduction_vs_second_best_pct",
                        round((1 - igt.avg_jct / second_jct) * 100, 1),
                        "paper=7.5"))
    rows.append(csv_row("fig12.chr_gain_vs_second_best_pct",
                        round((igt.hit_ratio / second_chr - 1) * 100, 1),
                        "paper=10.1"))
    rows.extend(run_sketch_micro(seed=seed))
    return rows


# ---------------------------------------------------------------------------
# sketch micro-bench (PR 7): demand-tracking pipeline at 1M distinct blocks
# ---------------------------------------------------------------------------

def _ghost_stream(n_distinct: int, seed: int):
    """A ghost-hit stream with exactly ``n_distinct`` distinct block keys
    across 16 datasets: one pass over the full population (every block
    re-missed at least once) plus an equal volume of zipf-skewed re-hits
    (ghost hits concentrate on the hottest recently-evicted blocks)."""
    rng = np.random.default_rng(seed)
    base = rng.permutation(n_distinct)
    hot = rng.zipf(1.2, n_distinct) % n_distinct
    idx = np.concatenate([base, hot])
    return [f"ds{i & 15}/part{(i >> 4) & 255}/blk#{i}" for i in idx.tolist()]


def _exact_pipeline(keys, n_streams: int):
    """The pre-sketch path: exact per-block counters, per-stream demand
    by scanning the table, full-dump wire format, coordinator merge of a
    second shard's dump.  Returns (us_per_access, query_us, merge_us,
    wire_bytes)."""
    t0 = time.perf_counter()
    counts: dict = {}
    get = counts.get
    for k in keys:
        counts[k] = get(k, 0) + 1
    update_s = time.perf_counter() - t0
    # round: per-stream distinct/mass (the demand signal plan_moves needs)
    t0 = time.perf_counter()
    per_stream = {f"ds{i}": [0, 0] for i in range(n_streams)}
    for k, c in counts.items():
        row = per_stream[k[:k.index("/")]]
        row[0] += 1
        row[1] += c
    query_s = time.perf_counter() - t0
    # round: ship the table, coordinator ingests + merges a peer's table
    t0 = time.perf_counter()
    wire = pickle.dumps(counts, protocol=pickle.HIGHEST_PROTOCOL)
    peer = pickle.loads(wire)
    for k, c in peer.items():
        counts[k] = counts.get(k, 0) + c
    ship_s = time.perf_counter() - t0
    total_us = (update_s + query_s + ship_s) / len(keys) * 1e6
    return total_us, query_s * 1e6, ship_s * 1e6, len(wire)


def _sketch_pipeline(keys, n_streams: int):
    """The PR-7 path over the same stream: DemandSketch notes + batched
    folds, per-stream demand via distinct_under, O(KB) wire payloads,
    coordinator deserialize + merge (exactly what ``note_round`` does)."""
    from repro.core.sketch import CountMinSketch, DemandSketch, SpaceSaving

    sk = DemandSketch()
    t0 = time.perf_counter()
    note = sk.note
    for k in keys:
        note(k)
    sk.fold()
    update_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(n_streams):
        sk.distinct_under(f"ds{i}/")
    query_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    cms_wire, topk_wire = sk.serialize()
    heat = CountMinSketch.deserialize(cms_wire)
    hot = SpaceSaving.deserialize(topk_wire)
    heat.merge(sk.cms)
    hot.merge(sk.topk)
    ship_s = time.perf_counter() - t0
    total_us = (update_s + query_s + ship_s) / len(keys) * 1e6
    return total_us, query_s * 1e6, ship_s * 1e6, len(cms_wire) + len(topk_wire)


def run_sketch_micro(smoke: bool = False, seed: int = 0, json_path=None):
    """Interleaved sketch-vs-exact pipeline comparison; best-of-repeats
    per path.  Asserts the headline claim: the sketch path costs no more
    per access than the exact ghost-counter path it replaced, while its
    wire payload is O(KB) instead of growing with the block population."""
    n_distinct = 100_000 if smoke else 1_000_000
    repeats = 2 if smoke else 3
    keys = _ghost_stream(n_distinct, seed)
    best = {"exact": None, "sketch": None}
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        for _ in range(repeats):
            for name, fn in (("exact", _exact_pipeline),
                             ("sketch", _sketch_pipeline)):
                got = fn(keys, 16)
                if best[name] is None or got[0] < best[name][0]:
                    best[name] = got
    finally:
        if gc_was_enabled:
            gc.enable()
    section = {"smoke": smoke, "n_distinct": n_distinct,
               "n_accesses": len(keys), "repeats": repeats}
    rows = []
    for name in ("exact", "sketch"):
        total_us, query_us, ship_us, wire = best[name]
        section[name] = {
            "us_per_access": round(total_us, 3),
            "query_us": round(query_us, 1),
            "ship_merge_us": round(ship_us, 1),
            "wire_bytes": wire,
        }
        rows.append(csv_row(f"sketch_path.{name}.us_per_access",
                            round(total_us, 3),
                            f"wire_bytes={wire} n_distinct={n_distinct}"))
    exact_us = section["exact"]["us_per_access"]
    sketch_us = section["sketch"]["us_per_access"]
    section["sketch_vs_exact"] = round(sketch_us / exact_us, 3)
    section["wire_reduction"] = round(section["exact"]["wire_bytes"]
                                      / section["sketch"]["wire_bytes"], 1)
    # The headline crossover is a population-scale claim: the exact
    # table's scan/ship cost grows with the distinct-block count while
    # the sketch path is flat, so the strict bound is asserted at the
    # full 1M-distinct scale.  The down-scaled smoke population still
    # fits in cache for the exact dict, so smoke only guards against the
    # sketch path regressing to far costlier than exact.
    if smoke:
        assert sketch_us <= 2.0 * exact_us, (
            f"sketch pipeline ({sketch_us:.3f} us/access) regressed far "
            f"past the exact pipeline ({exact_us:.3f}) even down-scaled")
    else:
        assert sketch_us <= exact_us, (
            f"sketch demand pipeline ({sketch_us:.3f} us/access) must not "
            f"cost more than the exact ghost-counter pipeline "
            f"({exact_us:.3f}) at {n_distinct} distinct blocks")
    assert section["sketch"]["wire_bytes"] <= 24 * 1024
    merge_overhead_section("sketch_path", section, json_path=json_path)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled sketch micro-bench only")
    args = ap.parse_args()
    if args.smoke:
        run_sketch_micro(smoke=True)
    else:
        main()

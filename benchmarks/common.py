"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core import CacheConfig, bundle_client  # noqa: E402
from repro.core.types import MB  # noqa: E402
from repro.sim import ClusterSim, make_paper_suite  # noqa: E402
from repro.storage import RemoteStore  # noqa: E402


def scaled_cfg(capacity: int, **kw) -> CacheConfig:
    """Paper hyper-parameters with size-proportional shares (the paper's
    640 MB min-share/quantum is ~0.4 % of its 150 GB cache)."""
    share = max(16 * MB, capacity // 128)
    defaults = dict(min_share=share, rebalance_quantum=share,
                    rebalance_period=10.0,
                    prefetch_budget_bytes=max(64 * MB, capacity // 8))
    defaults.update(kw)
    return CacheConfig(**defaults)


def build_world(scale: float = 1.0, seed: int = 0, job_filter=None,
                cache_ratio: float = 0.35):
    suite = make_paper_suite(scale=scale, seed=seed, job_filter=job_filter)
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(cache_ratio * suite.total_bytes())
    return suite, store, cap


def run_sim(suite, store, cap, bundle_name: str, cfg: CacheConfig = None,
            capacity_override: int = None, **sim_kw):
    capacity = cap if capacity_override is None else capacity_override
    client = bundle_client(bundle_name, store, capacity,
                           cfg=cfg or scaled_cfg(cap))
    sim = ClusterSim(suite, client, **sim_kw)
    res = sim.run()
    return res, client.engine


def csv_row(name: str, value, derived: str = "") -> str:
    line = f"{name},{value},{derived}"
    print(line, flush=True)
    return line


def merge_overhead_section(section_name: str, section: dict,
                           json_path=None) -> Path:
    """Read-modify-write one section of the shared perf-trajectory file
    (BENCH_overhead.json): a benchmark's axis lands next to the
    kernel/sharded/client numbers without clobbering them.  Smoke runs
    land in the smoke file so they never overwrite the canonical
    full-sweep record."""
    if json_path is not None:
        out = Path(json_path)
    elif section.get("smoke"):
        out = REPO_ROOT / "BENCH_overhead_smoke.json"
    else:
        out = REPO_ROOT / "BENCH_overhead.json"
    payload = {}
    if out.exists():
        try:
            payload = json.loads(out.read_text())
        except ValueError:
            payload = {}
    payload[section_name] = section
    payload.setdefault("bench", "overhead")
    payload["generated_unix"] = round(time.time(), 1)
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"[bench] merged {section_name} into {out}", flush=True)
    return out


def emit_json(name: str, payload: dict, path=None) -> Path:
    """Persist one benchmark's results as BENCH_<name>.json at the repo root
    so the perf trajectory is tracked across PRs (each PR overwrites its
    bench file; git history keeps the trajectory)."""
    out = Path(path) if path is not None else REPO_ROOT / f"BENCH_{name}.json"
    record = dict(payload)
    record.setdefault("bench", name)
    record.setdefault("generated_unix", round(time.time(), 1))
    out.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    print(f"[bench] wrote {out}", flush=True)
    return out

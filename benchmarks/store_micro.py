"""Storage-API micro-benchmarks (the ``store_path`` axis of this PR).

Three questions the v2 ``BackingStore`` protocol was designed around:

1. **ranged vs whole-block over-fetch** — a partial-extent read under
   the v1 protocol fetched the block prefix ``[0, offset+length)`` and
   sliced; ``fetch_range`` moves only the requested bytes.  Measured on
   the simulated store (synthesis cost) and on a real ``LocalFSStore``
   tree (seek+read vs full-prefix read).
2. **batched vs serial demand fetches** — ``read_batch(fetch=True)``
   funnels every miss of the batch through one ``fetch_demand`` call
   (one ``fetch_many`` per shard under the ThreadedExecutor); the serial
   path pays one round-trip per request.
3. **synthesis vs simulated transfer** (satellite guard) — the hoisted
   per-file digest + counter-based generator must synthesize a 4 MB
   block far *under* the ~182 ms the transfer model charges for it, so
   content generation can never distort a simulated result.  This is an
   **assertion**, not just a number: the benchmark fails if synthesis
   regresses past the transfer budget.

Protocol: interleaved same-protocol repeats, best-of-N, GC paused
(docs/PERF.md).  Results merge into ``BENCH_overhead.json`` under
``store_path`` (``--smoke`` → ``BENCH_overhead_smoke.json``; exercised
by tests/test_bench_smoke.py).
"""
from __future__ import annotations

import argparse
import gc
import os
import tempfile
import time

import numpy as np

# .common bootstraps sys.path with REPO_ROOT/src — must import before repro
from .common import csv_row, merge_overhead_section

from repro.core import CacheConfig, open_cache
from repro.core.types import MB, block_key
from repro.storage import (LocalFSStore, RemoteStore, TransferModel,
                           make_dataset)


def _timed(fn) -> float:
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()


# ------------------------------------------------------------------ worlds

def _sim_store():
    store = RemoteStore()
    store.add(make_dataset("ds", "big_files", n_files=8, file_size=64 * MB))
    return store


def _fs_tree(root: str, n_files: int, file_bytes: int) -> LocalFSStore:
    rng = np.random.default_rng(0)
    os.makedirs(os.path.join(root, "ds"), exist_ok=True)
    chunk = rng.integers(0, 256, file_bytes, dtype=np.uint8).tobytes()
    for i in range(n_files):
        with open(os.path.join(root, "ds", f"{i:04d}.bin"), "wb") as f:
            f.write(chunk)
    return LocalFSStore(root, block_size=256 * 1024)


def _range_trace(store, n: int, seed: int, read_len: int):
    """(block_path, offset) pairs at random sub-block offsets."""
    rng = np.random.default_rng(seed)
    reqs = []
    files = [p for p in store._files]
    bs = store.block_size
    for _ in range(n):
        fp = files[int(rng.integers(0, len(files)))]
        nblocks = max(1, store.file_size(fp) // bs)
        b = int(rng.integers(0, nblocks))
        off = int(rng.integers(0, max(1, bs - read_len)))
        reqs.append((block_key(fp, b), off))
    return reqs


# --------------------------------------------------- axis 1: ranged reads

def _bench_ranged(store, n: int, seed: int, read_len: int):
    reqs = _range_trace(store, n, seed, read_len)

    def ranged():
        for bp, off in reqs:
            store.fetch_range(bp, off, read_len)

    def overfetch():            # the v1 protocol: prefix fetch + slice
        for bp, off in reqs:
            store.fetch_block(bp, off + read_len)[off:off + read_len]

    t_r = _timed(ranged) / n * 1e6
    t_o = _timed(overfetch) / n * 1e6
    moved_r = n * read_len
    moved_o = sum(off + read_len for _, off in reqs)
    return {"ranged_us": round(t_r, 1), "overfetch_us": round(t_o, 1),
            "speedup": round(t_o / max(t_r, 1e-9), 2),
            "bytes_moved_ratio": round(moved_o / moved_r, 2)}


# ------------------------------------------------ axis 2: batched demand

def _batch_world(tmpdir: str, n_files: int):
    root = os.path.join(tmpdir, "batchw")
    store = _fs_tree(root, n_files=n_files, file_bytes=1 * MB)
    cfg = CacheConfig(block_size=256 * 1024, min_share=4 * MB,
                      rebalance_quantum=4 * MB)
    return store, cfg


def _bench_batched(tmpdir: str, n_reqs: int, batch: int, seed: int):
    """Cold-miss demand fetches: read_batch funnel vs per-read serial.
    Fresh tree + client per protocol run (every block touched once)."""
    rng = np.random.default_rng(seed)

    def requests(store):
        files = [p for p in store._files]
        rng.shuffle(files)
        return [(fp, 0, 64 * 1024) for fp in files[:n_reqs]]

    def serial():
        store, cfg = _batch_world(tmpdir, n_reqs)
        client = open_cache(store, 512 * MB, cfg=cfg, executor="threaded",
                            fetch_bytes=True)
        reqs = requests(store)

        def go():
            for fp, off, sz in reqs:
                client.read(fp, off, sz)

        us = _timed(go) / len(reqs) * 1e6
        client.close()
        return us

    def batched():
        store, cfg = _batch_world(tmpdir, n_reqs)
        client = open_cache(store, 512 * MB, cfg=cfg, executor="threaded",
                            fetch_bytes=True)
        reqs = requests(store)

        def go():
            for i in range(0, len(reqs), batch):
                client.read_batch(reqs[i:i + batch])

        us = _timed(go) / len(reqs) * 1e6
        client.close()
        return us

    t_s, t_b = serial(), batched()
    return {"serial_us_per_req": round(t_s, 1),
            "batched_us_per_req": round(t_b, 1),
            "batch": batch,
            "speedup": round(t_s / max(t_b, 1e-9), 2)}


# ------------------------------------------- axis 3: synthesis-vs-transfer

def _bench_synthesis(store, repeats: int):
    """Satellite guard: synthesizing a 4 MB block must stay far under the
    simulated transfer time for the same bytes (else content generation,
    not the cost model, would dominate simulated runs)."""
    bp = block_key(next(iter(store._files)), 0)
    best = min(_timed(lambda: store.fetch_block(bp, 4 * MB))
               for _ in range(repeats))
    budget = TransferModel().remote_time(4 * MB)
    assert best < budget, (
        f"block synthesis regressed: {best * 1e3:.1f} ms per 4 MB block "
        f"exceeds the simulated transfer budget {budget * 1e3:.1f} ms")
    return {"synth_4mb_ms": round(best * 1e3, 3),
            "transfer_4mb_ms": round(budget * 1e3, 1),
            "synth_under_transfer": True,
            "headroom_x": round(budget / max(best, 1e-9), 1)}


# ------------------------------------------------------------------- main

def main(smoke: bool = False, seed: int = 0, json_path=None):
    n_ranged = 400 if smoke else 4_000
    n_reqs = 48 if smoke else 512
    repeats = 2 if smoke else 3
    read_len = 64 * 1024
    rows = []
    section = {"smoke": smoke, "read_len": read_len}

    with tempfile.TemporaryDirectory(prefix="igt-store-micro-") as tmpdir:
        # interleaved best-of-N per protocol family (PERF.md); "best" is
        # the run with the fastest primary metric
        primary = {"ranged_sim": "ranged_us", "ranged_fs": "ranged_us",
                   "batched_demand": "batched_us_per_req"}
        best: dict = {}
        for _ in range(repeats):
            sim = _bench_ranged(_sim_store(), n_ranged, seed, read_len)
            fs_store = _fs_tree(os.path.join(tmpdir, "rangedw"),
                                n_files=64, file_bytes=1 * MB)
            fs = _bench_ranged(fs_store, n_ranged, seed, read_len)
            bt = _bench_batched(tmpdir, n_reqs=n_reqs, batch=16, seed=seed)
            for name, got in (("ranged_sim", sim), ("ranged_fs", fs),
                              ("batched_demand", bt)):
                key = primary[name]
                if name not in best or got[key] < best[name][key]:
                    best[name] = got
        section.update(best)
        section["synthesis"] = _bench_synthesis(_sim_store(), repeats + 1)

    for axis in ("ranged_sim", "ranged_fs"):
        rows.append(csv_row(f"store_path.{axis}.ranged_us",
                            section[axis]["ranged_us"],
                            f"overfetch={section[axis]['overfetch_us']} "
                            f"moved_x={section[axis]['bytes_moved_ratio']}"))
    bd = section["batched_demand"]
    rows.append(csv_row("store_path.batched_demand.us_per_req",
                        bd["batched_us_per_req"],
                        f"serial={bd['serial_us_per_req']}"))
    rows.append(csv_row("store_path.synthesis.synth_4mb_ms",
                        section["synthesis"]["synth_4mb_ms"],
                        f"budget={section['synthesis']['transfer_4mb_ms']}"))
    merge_overhead_section("store_path", section, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled sweep for the test job")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke=args.smoke, seed=args.seed)

"""Fig. 9 — prefetching schemes on prefetch-sensitive jobs.

Baselines: stride, enhanced-stride (JuiceFS default), SFP (file-Markov),
none; IGTCache runs with prefetch adaptivity only (eviction/allocation
fixed, as §5.2 does).  Also reproduces the two ablations: hierarchical
prefetching on the ICOADS location scan (job-4) and statistical prefetching
on the fine-tune job (job-7).
"""
from __future__ import annotations

from .common import build_world, csv_row, run_sim

JOBS = [1, 2, 4, 5, 6, 8, 11]      # sequential, prefetch-sensitive (§5.2)
BUNDLES = ["prefetch_igt", "prefetch_stride", "prefetch_enhanced",
           "prefetch_sfp", "prefetch_none"]


def main(scale: float = 1.0, seed: int = 0):
    suite, store, cap = build_world(scale=scale, seed=seed, job_filter=JOBS)
    rows = []
    jcts = {}
    for b in BUNDLES:
        res, _ = run_sim(suite, store, cap, b)
        jcts[b] = res
        rows.append(csv_row(f"fig9.{b}.avg_jct_s", round(res.avg_jct, 1),
                            f"chr={res.hit_ratio:.3f}"))
    best_other = min(r.avg_jct for k, r in jcts.items()
                     if k != "prefetch_igt")
    igt = jcts["prefetch_igt"]
    rows.append(csv_row(
        "fig9.jct_reduction_vs_second_best_pct",
        round((1 - igt.avg_jct / best_other) * 100, 1), "paper=64.9"))
    best_chr = max(r.hit_ratio for k, r in jcts.items()
                   if k != "prefetch_igt")
    rows.append(csv_row(
        "fig9.chr_gain_vs_second_best_pct",
        round((igt.hit_ratio / max(best_chr, 1e-9) - 1) * 100, 1),
        "paper=68.2"))

    # --- hierarchical prefetching ablation (job-4, Fig 7/9) --------------
    suite4, store4, cap4 = build_world(scale=scale, seed=seed, job_filter=[4])
    res_h, _ = run_sim(suite4, store4, cap4, "prefetch_igt")
    res_n, _ = run_sim(suite4, store4, cap4, "prefetch_none")
    rows.append(csv_row("fig9.hierarchical.job4_jct_s",
                        round(res_h.jct[4], 1),
                        f"none={res_n.jct[4]:.1f}"))
    rows.append(csv_row("fig9.hierarchical.jct_reduction_pct",
                        round((1 - res_h.jct[4] / res_n.jct[4]) * 100, 1),
                        "paper=64.4"))

    # --- statistical prefetching ablation (job-7 first epoch) ------------
    suite7, store7, cap7 = build_world(scale=scale, seed=seed, job_filter=[7],
                                       cache_ratio=1.2)
    res_s, eng_s = run_sim(suite7, store7, cap7, "igtcache")
    res_u, _ = run_sim(suite7, store7, cap7, "prefetch_none")
    rows.append(csv_row("fig9.statistical.job7_jct_s", round(res_s.jct[7], 1),
                        f"noprefetch={res_u.jct[7]:.1f} paper_epoch1=-6.8%"))
    return rows


if __name__ == "__main__":
    main()

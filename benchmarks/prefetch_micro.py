"""Fig. 9 — prefetching schemes on prefetch-sensitive jobs — plus the
client-path overhead axis (PR 3).

Baselines: stride, enhanced-stride (JuiceFS default), SFP (file-Markov),
none; IGTCache runs with prefetch adaptivity only (eviction/allocation
fixed, as §5.2 does).  Also reproduces the two ablations: hierarchical
prefetching on the ICOADS location scan (job-4) and statistical prefetching
on the fine-tune job (job-7).

The **client-path axis** measures what the CacheClient layer costs on top
of the bare kernel: the same seeded trace is driven through (a) the
caller-driven kernel loop (read + inline complete_prefetch — the PR-2
reference), (b) ``CacheClient`` + ``SimExecutor``, and (c) ``CacheClient``
+ ``ThreadedExecutor`` (per-shard background workers; flushed inside the
timed region so completions are paid for).  Runs are interleaved
(best-of-N, GC paused — the docs/PERF.md protocol) and the three points
land in ``BENCH_overhead.json`` under ``client_path`` next to the kernel
trajectory.  ``--smoke`` runs a down-scaled client axis for the test job.
"""
from __future__ import annotations

import argparse
import gc
import time

import numpy as np

# .common bootstraps sys.path with REPO_ROOT/src — must import before repro
from .common import build_world, csv_row, merge_overhead_section, run_sim

from repro.core import (CacheConfig, IGTCache, SimExecutor, ThreadedExecutor,
                        open_cache)
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset

JOBS = [1, 2, 4, 5, 6, 8, 11]      # sequential, prefetch-sensitive (§5.2)
BUNDLES = ["prefetch_igt", "prefetch_stride", "prefetch_enhanced",
           "prefetch_sfp", "prefetch_none"]


# ---------------------------------------------------------------- client axis

def _client_world():
    store = RemoteStore()
    store.add(make_dataset("ds", "dir_tree", n_dirs=40, files_per_dir=60,
                           small_file_size=9 * MB))
    cfg = CacheConfig(node_cap=10_000, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    return store, cfg


def _trace(files, n_accesses: int, seed: int):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(files), n_accesses)
    offs = rng.integers(0, 2, n_accesses)
    return idx, offs


def _timed(fn) -> float:
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()


def _run_kernel(store, cfg, n_accesses, seed) -> float:
    eng = IGTCache(store, 512 * MB, cfg=cfg)
    files = store.datasets["ds"].files
    idx, offs = _trace(files, n_accesses, seed)

    def go():
        for i, j in enumerate(idx):
            f = files[int(j)]
            out = eng.read(f.path, int(offs[i]) * 4 * MB, 64 * 1024,
                           time.monotonic())
            for p, s in out.prefetches:
                eng.complete_prefetch(p, s, time.monotonic())

    return _timed(go) / n_accesses * 1e6


def _run_client(store, cfg, n_accesses, seed, threaded: bool) -> float:
    executor = (ThreadedExecutor(max_fetch_bytes=0) if threaded
                else SimExecutor())
    client = open_cache(store, 512 * MB, cfg=cfg, executor=executor)
    files = store.datasets["ds"].files
    idx, offs = _trace(files, n_accesses, seed)

    def go():
        for i, j in enumerate(idx):
            f = files[int(j)]
            client.read(f.path, int(offs[i]) * 4 * MB, 64 * 1024)
        client.flush(timeout=60.0)      # pay for in-flight completions

    us = _timed(go) / n_accesses * 1e6
    client.close()
    return us


def client_axis(smoke: bool = False, seed: int = 0, json_path=None):
    """Interleaved kernel vs SimExecutor-client vs ThreadedExecutor-client
    sweep; merged into BENCH_overhead.json's ``client_path`` section."""
    n_accesses = 4_000 if smoke else 20_000
    repeats = 2 if smoke else 3
    protocols = {
        "kernel_loop": lambda st, cf: _run_kernel(st, cf, n_accesses, seed),
        "client_sim": lambda st, cf: _run_client(st, cf, n_accesses, seed,
                                                 threaded=False),
        "client_threaded": lambda st, cf: _run_client(st, cf, n_accesses,
                                                      seed, threaded=True),
    }
    best = {}
    for _ in range(repeats):
        for name, fn in protocols.items():     # interleaved, same protocol
            store, cfg = _client_world()
            us = fn(store, cfg)
            if name not in best or us < best[name]:
                best[name] = us
    rows = []
    section = {"n_accesses": n_accesses, "repeats": repeats, "smoke": smoke}
    for name, us in best.items():
        section[name] = {"us_per_access": round(us, 1)}
        rows.append(csv_row(f"client_path.{name}.us_per_access",
                            round(us, 1), "interleaved-protocol"))
    section["client_overhead_pct"] = round(
        (best["client_sim"] / best["kernel_loop"] - 1) * 100, 1)
    rows.append(csv_row("client_path.sim_overhead_vs_kernel_pct",
                        section["client_overhead_pct"]))
    merge_overhead_section("client_path", section, json_path)
    return rows


def main(scale: float = 1.0, seed: int = 0, smoke: bool = False,
         json_path=None):
    if smoke:
        return client_axis(smoke=True, seed=seed, json_path=json_path)
    suite, store, cap = build_world(scale=scale, seed=seed, job_filter=JOBS)
    rows = []
    jcts = {}
    for b in BUNDLES:
        res, _ = run_sim(suite, store, cap, b)
        jcts[b] = res
        rows.append(csv_row(f"fig9.{b}.avg_jct_s", round(res.avg_jct, 1),
                            f"chr={res.hit_ratio:.3f}"))
    best_other = min(r.avg_jct for k, r in jcts.items()
                     if k != "prefetch_igt")
    igt = jcts["prefetch_igt"]
    rows.append(csv_row(
        "fig9.jct_reduction_vs_second_best_pct",
        round((1 - igt.avg_jct / best_other) * 100, 1), "paper=64.9"))
    best_chr = max(r.hit_ratio for k, r in jcts.items()
                   if k != "prefetch_igt")
    rows.append(csv_row(
        "fig9.chr_gain_vs_second_best_pct",
        round((igt.hit_ratio / max(best_chr, 1e-9) - 1) * 100, 1),
        "paper=68.2"))

    # --- hierarchical prefetching ablation (job-4, Fig 7/9) --------------
    suite4, store4, cap4 = build_world(scale=scale, seed=seed, job_filter=[4])
    res_h, _ = run_sim(suite4, store4, cap4, "prefetch_igt")
    res_n, _ = run_sim(suite4, store4, cap4, "prefetch_none")
    rows.append(csv_row("fig9.hierarchical.job4_jct_s",
                        round(res_h.jct[4], 1),
                        f"none={res_n.jct[4]:.1f}"))
    rows.append(csv_row("fig9.hierarchical.jct_reduction_pct",
                        round((1 - res_h.jct[4] / res_n.jct[4]) * 100, 1),
                        "paper=64.4"))

    # --- statistical prefetching ablation (job-7 first epoch) ------------
    suite7, store7, cap7 = build_world(scale=scale, seed=seed, job_filter=[7],
                                       cache_ratio=1.2)
    res_s, eng_s = run_sim(suite7, store7, cap7, "igtcache")
    res_u, _ = run_sim(suite7, store7, cap7, "prefetch_none")
    rows.append(csv_row("fig9.statistical.job7_jct_s", round(res_s.jct[7], 1),
                        f"noprefetch={res_u.jct[7]:.1f} paper_epoch1=-6.8%"))

    # --- client-path overhead axis (PR 3) --------------------------------
    rows.extend(client_axis(smoke=False, seed=seed, json_path=json_path))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled client-path axis only (test job)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args()
    main(scale=args.scale, seed=args.seed, smoke=args.smoke)

"""Fig. 8 — end-to-end JCT + CHR across the 18-job heterogeneous suite."""
from __future__ import annotations

from .common import build_world, csv_row, run_sim, scaled_cfg


def main(scale: float = 1.0, seed: int = 0):
    suite, store, cap = build_world(scale=scale, seed=seed)
    rows = []
    results = {}
    for name in ("igtcache", "juicefs", "nocache"):
        if name == "nocache":
            res, _ = run_sim(suite, store, cap, "prefetch_none",
                             capacity_override=0)
        else:
            res, _ = run_sim(suite, store, cap, name)
        results[name] = res

    ig, ju, nc = results["igtcache"], results["juicefs"], results["nocache"]
    rows.append(csv_row("fig8.igtcache.avg_jct_s", round(ig.avg_jct, 1),
                        f"chr={ig.hit_ratio:.3f}"))
    rows.append(csv_row("fig8.juicefs.avg_jct_s", round(ju.avg_jct, 1),
                        f"chr={ju.hit_ratio:.3f}"))
    rows.append(csv_row("fig8.nocache.avg_jct_s", round(nc.avg_jct, 1),
                        "chr=0.000"))
    rows.append(csv_row("fig8.jct_reduction_vs_juicefs_pct",
                        round((1 - ig.avg_jct / ju.avg_jct) * 100, 1),
                        "paper=52.2"))
    rows.append(csv_row("fig8.chr_gain_vs_juicefs_pct",
                        round((ig.hit_ratio / ju.hit_ratio - 1) * 100, 1),
                        "paper=55.6"))
    rows.append(csv_row("fig8.juicefs_vs_nocache_jct_reduction_pct",
                        round((1 - ju.avg_jct / nc.avg_jct) * 100, 1),
                        "paper=55.0"))
    # per-pattern subsets (Fig 8 breakdown)
    for pat in ("sequential", "random", "skewed", "mixed"):
        jobs = [j.job_id for j in suite.jobs if j.pattern == pat]
        for name, res in (("igtcache", ig), ("juicefs", ju)):
            avg = sum(res.jct[j] for j in jobs) / len(jobs)
            rows.append(csv_row(f"fig8.{pat}.{name}.avg_jct_s",
                                round(avg, 1), f"n={len(jobs)}"))
    return rows


if __name__ == "__main__":
    main()

"""Fig. 10 — eviction schemes on eviction-sensitive jobs (per-job cache =
50 % of dataset, no prefetch, as §5.3)."""
from __future__ import annotations

from .common import build_world, csv_row, run_sim

JOBS = [7, 9, 13, 14, 16]          # random + skewed mix
BUNDLES = ["evict_igt", "evict_lru", "evict_fifo", "evict_arc",
           "evict_uniform", "evict_sieve", "evict_lfu"]


def main(scale: float = 1.0, seed: int = 0):
    suite, store, cap = build_world(scale=scale, seed=seed, job_filter=JOBS,
                                    cache_ratio=0.5)
    rows = []
    res_by = {}
    for b in BUNDLES:
        res, _ = run_sim(suite, store, cap, b)
        res_by[b] = res
        rows.append(csv_row(f"fig10.{b}.avg_jct_s", round(res.avg_jct, 1),
                            f"chr={res.hit_ratio:.3f}"))
    igt = res_by["evict_igt"]
    second_jct = min(r.avg_jct for k, r in res_by.items() if k != "evict_igt")
    second_chr = max(r.hit_ratio for k, r in res_by.items()
                     if k != "evict_igt")
    rows.append(csv_row("fig10.jct_reduction_vs_second_best_pct",
                        round((1 - igt.avg_jct / second_jct) * 100, 1),
                        "paper=11.2"))
    rows.append(csv_row("fig10.chr_gain_vs_second_best_pct",
                        round((igt.hit_ratio / second_chr - 1) * 100, 1),
                        "paper=13.2"))
    return rows


if __name__ == "__main__":
    main()

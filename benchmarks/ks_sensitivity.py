"""Fig. 14/15 — pattern-recognition accuracy vs K-S significance level and
observation-window size (100 trials per stream type)."""
from __future__ import annotations

import random

import numpy as np

from repro.core.pattern import classify
from repro.core.types import AccessRecord, CacheConfig, Pattern

from .common import csv_row

C = 5000
TRIALS = 100


def _recs(indices):
    return [AccessRecord(int(i), C, t * 0.05, str(int(i)))
            for t, i in enumerate(indices)]


def gen_random(rng, window):
    perm = list(range(C))
    rng.shuffle(perm)
    return _recs(perm[:window])


def gen_skewed(nrng, window):
    perm = nrng.permutation(C)
    idx = perm[(nrng.zipf(1.3, window) - 1) % C]
    return _recs(idx)


def accuracy(alpha: float, window: int, seed: int = 0):
    cfg = CacheConfig(alpha=alpha, window=window)
    rng = random.Random(seed)
    nrng = np.random.default_rng(seed)
    ok_rand = sum(
        classify(gen_random(rng, window), C, cfg).pattern is Pattern.RANDOM
        for _ in range(TRIALS))
    ok_skew = sum(
        classify(gen_skewed(nrng, window), C, cfg).pattern is Pattern.SKEWED
        for _ in range(TRIALS))
    return ok_rand / TRIALS, ok_skew / TRIALS


def main(scale: float = 1.0, seed: int = 0):
    rows = []
    for alpha in (0.05, 0.01, 0.001):
        r, s = accuracy(alpha, window=100, seed=seed)
        rows.append(csv_row(f"fig14.alpha_{alpha}.random_acc", r,
                            f"skewed_acc={s}"))
    for window in (10, 50, 100, 1000):
        r, s = accuracy(0.01, window=window, seed=seed)
        rows.append(csv_row(f"fig15.window_{window}.random_acc", r,
                            f"skewed_acc={s}"))
    return rows


if __name__ == "__main__":
    main()

"""Cache-daemon micro-benchmarks (``daemon_path`` + ``daemon_recovery``).

The daemon's scale-out claim: the serve path adds one framed round-trip
per batch but removes the per-process kernel, so N client *processes*
sharing one daemon should deliver aggregate metadata throughput that
scales with N — past the single-client multi-process driver number
(``proc_4`` in ``BENCH_overhead.json``), which pays RPC fan-out per
batch without any cross-process sharing to show for it.

Protocol: one ``CacheDaemon`` on a temp UDS over a seeded RemoteStore
world; for N in {1, 2, 4}, fork N client processes that each
``open_cache("cache://...")`` and drive seeded metadata ``read_batch``
loops (no byte fetches — this is the command-path number, matching the
other axes) through a start barrier; aggregate accesses/s is the total
access count over the slowest client's wall time.  Results merge into
``BENCH_overhead.json`` under ``daemon_path`` (``--smoke`` → the smoke
file; exercised by tests/test_bench_smoke.py).

``--recovery`` runs the PR 10 survivability axis instead
(``daemon_recovery`` section): warm a journaled daemon, kill it under a
:class:`~repro.daemon.DaemonSupervisor`, and record the whole recovery
arc — degraded-read latency while the daemon is away, supervisor
respawn time (including journal restore), client reconnect time, and
the ramp back to a fully-hitting pass.  The acceptance number is the
warm-vs-cold contrast: a warm restart (journal restore) reaches a
100 %-hit pass in one pass, where a cold daemon must re-learn the
working set over several.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import tempfile
import time

import numpy as np

# .common bootstraps sys.path with REPO_ROOT/src — must import before repro
from .common import REPO_ROOT, csv_row, merge_overhead_section

from repro.core import CacheConfig, open_cache
from repro.core.types import MB
from repro.daemon import CacheDaemon, DaemonSupervisor, RemoteCacheClient
from repro.storage import RemoteStore, make_dataset

CLIENT_COUNTS = (1, 2, 4)


def _world(n_datasets: int, files_per_dir: int):
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"job{i}", "dir_tree", n_dirs=4,
                               files_per_dir=files_per_dir,
                               small_file_size=256 * 1024))
    return store


def _client_proc(uri, files, n_steps, batch, seed, barrier, q):
    """One forked client: seeded metadata read_batch loop, wall time
    measured from the shared start barrier."""
    rng = np.random.default_rng(seed)
    steps = []
    for _ in range(n_steps):
        picks = rng.integers(0, len(files), batch)
        steps.append([(files[int(j)][0], 0, files[int(j)][1])
                      for j in picks])
    with open_cache(f"{uri}?label=bench{seed}") as client:
        # connection + a warm-up batch outside the timed region
        client.read_batch(steps[0])
        barrier.wait()
        t0 = time.perf_counter()
        for reqs in steps:
            client.read_batch(reqs)
        dt = time.perf_counter() - t0
    q.put((n_steps * batch, dt))


def _measure(uri, files, n_clients, n_steps, batch, seed):
    ctx = mp.get_context("fork")
    barrier = ctx.Barrier(n_clients)
    q = ctx.SimpleQueue()
    procs = [ctx.Process(target=_client_proc,
                         args=(uri, files, n_steps, batch, seed + 31 * c,
                               barrier, q))
             for c in range(n_clients)]
    for p in procs:
        p.start()
    # results are tiny tuples, so the queue pipe can't fill: join first
    # and fail loudly on a dead child instead of hanging in get()
    for p in procs:
        p.join(120)
        if p.exitcode != 0:
            raise RuntimeError(f"bench client exited {p.exitcode}")
    results = [q.get() for _ in procs]
    total = sum(n for n, _ in results)
    wall = max(dt for _, dt in results)     # aggregate over the slowest
    return {"accesses": total,
            "accesses_per_s": round(total / wall, 1),
            "us_per_access": round(wall / total * 1e6, 1)}


def _proc4_reference():
    """The single-client 4-worker number this axis must scale past."""
    try:
        payload = json.loads((REPO_ROOT / "BENCH_overhead.json").read_text())
        return payload["proc_path"]["proc_4"]["us_per_access"]
    except (OSError, KeyError, ValueError):
        return None


def main(smoke: bool = False, seed: int = 0, json_path=None):
    n_steps = 8 if smoke else 64
    batch = 8 if smoke else 64
    files_per_dir = 4 if smoke else 8
    store = _world(4, files_per_dir)
    cfg = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                      window=40, reanalyze_every=20, node_cap=2000)
    section = {"smoke": smoke, "batch": batch, "seed": seed,
               "n_accesses_per_client": n_steps * batch}
    with CacheDaemon(store, 96 * MB, cfg=cfg) as daemon:
        files = [(f.path, f.size)
                 for ds in store.datasets.values() for f in ds.files]
        for n in CLIENT_COUNTS:
            section[f"daemon_{n}"] = _measure(daemon.uri, files, n,
                                              n_steps, batch, seed)
        st = daemon.daemon_stats()
        section["daemon_stats"] = {
            "served_reads": st["served_reads"], "byes": st["byes"],
            "spills": st["spills"], "reaped": st["reaped"]}

    r1 = section["daemon_1"]["accesses_per_s"]
    r4 = section["daemon_4"]["accesses_per_s"]
    section["scaling_4_vs_1"] = round(r4 / r1, 2)
    proc4_us = _proc4_reference()
    section["proc_4_reference_us"] = proc4_us
    if proc4_us:
        # aggregate daemon throughput vs the single-client proc_4 rate
        section["daemon_4_vs_proc_4"] = round(
            r4 / (1e6 / proc4_us), 2)

    rows = [
        csv_row("daemon_path.daemon_1_accesses_per_s", r1,
                f"us_per_access={section['daemon_1']['us_per_access']}"),
        csv_row("daemon_path.daemon_4_accesses_per_s", r4,
                f"us_per_access={section['daemon_4']['us_per_access']}"),
        csv_row("daemon_path.scaling_4_vs_1", section["scaling_4_vs_1"],
                f"daemon_2={section['daemon_2']['accesses_per_s']}"),
        csv_row("daemon_path.daemon_4_vs_proc_4",
                section.get("daemon_4_vs_proc_4"),
                f"proc_4_us={proc4_us}"),
    ]
    merge_overhead_section("daemon_path", section, json_path)
    return rows


def _hit_pass(cli, pass_files, now, rng):
    """One shuffled read pass over the working set: (hits, blocks,
    wall_s).  Shuffled, not in-order — a sequential scan classifies as
    an eager-eviction stream whose blocks are consumed on read, which
    leaves nothing resident for the snapshot to carry across a
    restart.  The random pattern is the cache-*keeping* workload the
    warm/cold contrast is about."""
    hits = total = 0
    order = rng.permutation(len(pass_files))
    t0 = time.perf_counter()
    for i, j in enumerate(order):
        fp, size = pass_files[int(j)]
        r = cli.read(fp, 0, size, now + i)
        for blk in r.blocks:
            hits += bool(blk.hit)
            total += 1
    return hits, total, time.perf_counter() - t0


def _ramp(cli, pass_files, now, rng, max_passes=12):
    """Passes (and wall seconds) until a pass hits on every block —
    the time-to-rewarmed number the warm/cold contrast is about."""
    wall = 0.0
    for p in range(1, max_passes + 1):
        hits, total, dt = _hit_pass(cli, pass_files, now + p * 1000, rng)
        wall += dt
        if hits == total:
            return p, round(wall, 4), 1.0
    return max_passes, round(wall, 4), hits / max(1, total)


def run_recovery(smoke: bool = False, seed: int = 0, json_path=None):
    """The ``daemon_recovery`` axis: kill → degraded → respawn →
    warm-restore → reconnect, each leg timed."""
    n_pass_files = 16 if smoke else 64
    store = _world(2, 4 if smoke else 8)
    files = [(f.path, f.size)
             for ds in store.datasets.values() for f in ds.files]
    pass_files = files[:n_pass_files]
    cfg = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                      window=40, reanalyze_every=20, node_cap=2000)
    root = tempfile.mkdtemp(prefix="igt-recovery-")
    sock = f"{root}/d.sock"
    jdir = f"{root}/journal"

    def factory():
        return CacheDaemon(store, 96 * MB, cfg=cfg, uds=sock,
                           journal_dir=jdir,
                           snapshot_every_s=0.2).start()

    section = {"smoke": smoke, "seed": seed,
               "n_pass_files": n_pass_files}
    rng = np.random.default_rng(seed)
    sup = DaemonSupervisor(factory, restart_budget=4)
    cli = RemoteCacheClient(sup.uri, fetch_bytes=True, backing=store,
                            max_backoff_s=0.25)
    try:
        # cold ramp: a fresh daemon re-learns the working set over
        # repeated passes — the baseline the warm restart must beat
        passes, wall, chr_ = _ramp(cli, pass_files, 0.0, rng)
        section["cold_ramp"] = {"passes": passes, "wall_s": wall,
                                "final_pass_chr": chr_}
        # pin the pre-fault manifest: the drill measures restore cost,
        # not snapshot cadence (the periodic snapshot may race the ramp)
        sup.daemon.write_snapshot()
        # --- kill drill: degraded latency + recovery + reconnect time
        t_kill = time.perf_counter()
        sup.kill_daemon()
        lat = []
        for i, (fp, size) in enumerate(pass_files):
            t0 = time.perf_counter()
            r = cli.read(fp, 0, size, 5000.0 + i)
            lat.append(time.perf_counter() - t0)
            assert r.data is not None       # degraded reads always serve
        section["degraded"] = {
            "reads": len(lat),
            "us_per_read": round(sum(lat) / len(lat) * 1e6, 1),
            "worst_us": round(max(lat) * 1e6, 1),
        }
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            try:
                cli.heartbeat()
                break
            except ConnectionError:
                time.sleep(0.01)
        section["reconnect_s"] = round(time.perf_counter() - t_kill, 4)
        done = [e for e in sup.events if e["kind"] == "respawn_done"]
        section["respawn_s"] = round(done[-1]["recovery_s"], 4)
        section["restore"] = {
            k: done[-1]["restore"].get(k)
            for k in ("mode", "blocks", "bytes", "restore_s")}
        # warm ramp: the respawned daemon restored its manifest from
        # the journal — the working set should hit on the first pass
        passes, wall, chr_ = _ramp(cli, pass_files, 10_000.0, rng)
        section["warm_ramp"] = {"passes": passes, "wall_s": wall,
                                "final_pass_chr": chr_}
        cs = cli.connection_stats()
        section["client"] = {
            "reconnects": cs["reconnects"],
            "disconnects": cs["disconnects"],
            "degraded_reads": cs["client_stats"]["degraded_reads"],
            "degraded_bytes": cs["client_stats"]["degraded_bytes"],
        }
    finally:
        cli.close()
        sup.close()

    rows = [
        csv_row("daemon_recovery.respawn_s", section["respawn_s"],
                f"restore_mode={section['restore']['mode']}"),
        csv_row("daemon_recovery.reconnect_s", section["reconnect_s"],
                f"reconnects={section['client']['reconnects']}"),
        csv_row("daemon_recovery.degraded_us_per_read",
                section["degraded"]["us_per_read"],
                f"reads={section['degraded']['reads']}"),
        csv_row("daemon_recovery.warm_ramp_passes",
                section["warm_ramp"]["passes"],
                f"cold={section['cold_ramp']['passes']}"),
    ]
    merge_overhead_section("daemon_recovery", section, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled run for the test job")
    ap.add_argument("--recovery", action="store_true",
                    help="run the daemon_recovery axis instead")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.recovery:
        run_recovery(smoke=args.smoke, seed=args.seed)
    else:
        main(smoke=args.smoke, seed=args.seed)

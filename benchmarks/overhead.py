"""Fig. 17 — IGTCache management overhead vs AccessStreamTree node count:
per-access CPU time (µs) and tree memory (MB).  The paper reports 47.6 µs and
73.2 MB at the 10 000-node default (Go implementation; ours is Python —
the shape of the curves, O(log N) time / O(N) memory, is the claim).

Methodology (documented in docs/PERF.md): each configuration runs the same
seeded trace ``repeats`` times and reports the best run (standard practice
for CPU-overhead microbenchmarks — the minimum is the least noise-polluted
sample); the cyclic GC is paused during the timed region so the number
measures the engine, not the allocator's global heap scans.  Results are
printed as CSV rows and persisted to ``BENCH_overhead.json`` so the perf
trajectory is tracked across PRs.  ``--smoke`` runs a single down-scaled
configuration in a couple of seconds for the test job.

``--shards 1,4`` (the default) additionally measures the path-hash sharded
facade (``ShardedIGTCache``) at the 10k cap over an 8-dataset layout, with
the shard counts interleaved run-by-run so the pair is same-protocol
comparable; the points land in the JSON's ``sharded`` section.
"""
from __future__ import annotations

import argparse
import gc
import sys
import time

import numpy as np

from repro.core import CacheConfig, IGTCache, ShardedIGTCache
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset

from .common import csv_row, emit_json

# Historical reference points for the speedup bookkeeping in the JSON:
#   * "pr1_start": what this benchmark printed on the seed engine when PR 1
#     began (seed harness: single run, default GC) — the number the PR's
#     ≥5× target was calibrated against;
#   * "same_protocol": the seed engine re-measured at PR 1 end with THIS
#     harness (best-of-3, GC paused) interleaved with the new engine on the
#     same machine — the apples-to-apples baseline.  The container's CPU
#     throughput varies by >2× over hours, so only interleaved same-protocol
#     pairs are comparable; see docs/PERF.md.
SEED_US_PER_ACCESS_10K = {
    "pr1_start": 221.6,
    "same_protocol": 74.4,
}


def tree_memory_bytes(tree) -> int:
    total = 0
    for node in tree.iter_nodes():
        total += sys.getsizeof(node)
        total += node.ring_memory_bytes()
        total += sys.getsizeof(node.child_hits)
    return total


def _timed_trace(eng, files, n_accesses: int, seed: int) -> float:
    """The shared measurement protocol: seeded random 64 KiB reads with
    inline prefetch completion, timed with the cyclic GC paused.  One copy
    for both the unsharded and the sharded axis — the interleaved
    same-protocol comparison is only meaningful if both run exactly this.
    Returns µs/access."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(files), n_accesses)
    offs = rng.integers(0, 2, n_accesses)
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for i, j in enumerate(idx):
            f = files[int(j)]
            out = eng.read(f.path, int(offs[i]) * 4 * MB, 64 * 1024,
                           i * 0.001)
            for p, s in out.prefetches:
                eng.complete_prefetch(p, s, i * 0.001)
        dt = time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()
    return dt / n_accesses * 1e6


def _run_once(node_cap: int, n_accesses: int, seed: int):
    # Deep layout (multi-block files → file nodes materialize) so the tree
    # genuinely grows toward the cap: ~1 + 80 dirs + 80×120 file nodes
    # reachable under the paper's window-100 child pruning.
    store = RemoteStore()
    store.add(make_dataset("ds", "dir_tree", n_dirs=80, files_per_dir=120,
                           small_file_size=9 * MB))
    cfg = CacheConfig(node_cap=node_cap, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    eng = IGTCache(store, 512 * MB, cfg=cfg)
    us = _timed_trace(eng, store.datasets["ds"].files, n_accesses, seed)
    mem = tree_memory_bytes(eng.tree)
    return us, mem, eng.tree.node_count()


def measure(node_cap: int, n_accesses: int = 30_000, seed: int = 0,
            repeats: int = 3):
    """Best-of-``repeats`` µs/access (the trace and final engine state are
    identical across repeats, so mem/nodes are taken from the fastest run)."""
    best = None
    for _ in range(max(1, repeats)):
        got = _run_once(node_cap, n_accesses, seed)
        if best is None or got[0] < best[0]:
            best = got
    return best


def _run_once_sharded(node_cap: int, n_accesses: int, seed: int,
                      n_shards: int):
    """One timed run of the path-hash sharded facade.

    Multi-dataset layout (sharding routes on the top-level component, so a
    single-dataset trace would land on one shard): 8 dir_tree datasets with
    the same total dir/file population as the unsharded Fig.-17 layout.
    Every shard count replays the identical seeded trace, so the
    ``n_shards`` axis isolates routing + partitioning overhead.
    """
    store = RemoteStore()
    for i in range(8):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=10,
                               files_per_dir=120, small_file_size=9 * MB))
    cfg = CacheConfig(node_cap=node_cap, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    eng = ShardedIGTCache(store, 512 * MB, cfg=cfg, n_shards=n_shards)
    files = [f for ds in store.datasets.values() for f in ds.files]
    us = _timed_trace(eng, files, n_accesses, seed)
    mem = sum(tree_memory_bytes(s.tree) for s in eng.shards)
    return us, mem, eng.node_count()


def measure_shards(shard_counts, node_cap: int, n_accesses: int,
                   seed: int, repeats: int):
    """Interleaved same-protocol sweep over shard counts: repeats alternate
    between configurations so the container's CPU drift (>2×/hour, see
    docs/PERF.md) hits every configuration equally; best run per count."""
    best = {n: None for n in shard_counts}
    for _ in range(max(1, repeats)):
        for n in shard_counts:
            got = _run_once_sharded(node_cap, n_accesses, seed, n)
            if best[n] is None or got[0] < best[n][0]:
                best[n] = got
    return best


def main(scale: float = 1.0, seed: int = 0, smoke: bool = False,
         json_path=None, shard_counts=(1, 4)):
    caps = (10_000,) if smoke else (100, 1000, 10_000, 100_000)
    n_accesses = 6_000 if smoke else 30_000
    repeats = 2 if smoke else 3
    rows = []
    results = {}
    for cap in caps:
        us, mem, nodes = measure(cap, n_accesses=n_accesses, seed=seed,
                                 repeats=repeats)
        results[str(cap)] = {
            "us_per_access": round(us, 1),
            "tree_mb": round(mem / 2**20, 2),
            "nodes": nodes,
        }
        rows.append(csv_row(f"fig17.nodecap_{cap}.us_per_access",
                            round(us, 1),
                            f"mem_mb={mem/2**20:.1f} nodes={nodes} "
                            f"paper@10k=47.6us/73.2MB"))
    # ---- sharded-facade axis (interleaved, same protocol, 10k cap) ----
    sharded = {}
    if shard_counts:
        shard_accesses = 4_000 if smoke else 30_000
        got = measure_shards(tuple(shard_counts), 10_000, shard_accesses,
                             seed, repeats)
        for n in shard_counts:
            us, mem, nodes = got[n]
            sharded[str(n)] = {
                "us_per_access": round(us, 1),
                "tree_mb": round(mem / 2**20, 2),
                "nodes": nodes,
            }
            rows.append(csv_row(f"sharded.shards_{n}.us_per_access",
                                round(us, 1),
                                f"mem_mb={mem/2**20:.1f} nodes={nodes} "
                                f"interleaved-protocol"))
    payload = {
        "n_accesses": n_accesses,
        "repeats": repeats,
        "smoke": smoke,
        "results": results,
        "sharded": sharded,
        "paper_reference": {"us_per_access_at_10k": 47.6,
                            "tree_mb_at_10k": 73.2},
        "seed_reference": dict(SEED_US_PER_ACCESS_10K),
    }
    at10k = results.get("10000")
    if at10k:
        payload["speedup_vs_pr1_start_seed"] = round(
            SEED_US_PER_ACCESS_10K["pr1_start"] / at10k["us_per_access"], 2)
        payload["speedup_same_protocol"] = round(
            SEED_US_PER_ACCESS_10K["same_protocol"] / at10k["us_per_access"],
            2)
    # smoke runs must not clobber the canonical full-sweep record
    name = "overhead_smoke" if smoke else "overhead"
    emit_json(name, payload, path=json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single down-scaled configuration for the test job")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", default="1,4",
                    help="comma-separated shard counts for the sharded-"
                         "facade axis ('' disables it)")
    args = ap.parse_args()
    counts = tuple(int(x) for x in args.shards.split(",") if x.strip())
    main(seed=args.seed, smoke=args.smoke, shard_counts=counts)

"""Fig. 17 — IGTCache management overhead vs AccessStreamTree node count:
per-access CPU time (µs) and tree memory (MB).  The paper reports 47.6 µs and
73.2 MB at the 10 000-node default (Go implementation; ours is Python —
the shape of the curves, O(log N) time / O(N) memory, is the claim)."""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import CacheConfig, IGTCache
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset

from .common import csv_row


def tree_memory_bytes(tree) -> int:
    total = 0
    for node in tree.iter_nodes():
        total += sys.getsizeof(node)
        total += sys.getsizeof(node.records) + 96 * len(node.records)
        total += sys.getsizeof(node.child_hits)
    return total


def measure(node_cap: int, n_accesses: int = 30_000, seed: int = 0):
    # Deep layout (multi-block files → file nodes materialize) so the tree
    # genuinely grows to the cap: ~1 + 100 dirs + 100×100 file nodes ≈ 10k
    # reachable under the paper's window-100 child pruning.
    store = RemoteStore()
    store.add(make_dataset("ds", "dir_tree", n_dirs=80, files_per_dir=120,
                           small_file_size=9 * MB))
    cfg = CacheConfig(node_cap=node_cap, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    eng = IGTCache(store, 512 * MB, cfg=cfg)
    files = store.datasets["ds"].files
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(files), n_accesses)
    offs = rng.integers(0, 2, n_accesses)
    t0 = time.perf_counter()
    for i, j in enumerate(idx):
        f = files[int(j)]
        out = eng.read(f.path, int(offs[i]) * 4 * MB, 64 * 1024, i * 0.001)
        for p, s in out.prefetches:
            eng.complete_prefetch(p, s, i * 0.001)
    dt = time.perf_counter() - t0
    us = dt / n_accesses * 1e6
    mem = tree_memory_bytes(eng.tree)
    return us, mem, eng.tree.node_count()


def main(scale: float = 1.0, seed: int = 0):
    rows = []
    for cap in (100, 1000, 10_000, 100_000):
        us, mem, nodes = measure(cap, seed=seed)
        rows.append(csv_row(f"fig17.nodecap_{cap}.us_per_access",
                            round(us, 1),
                            f"mem_mb={mem/2**20:.1f} nodes={nodes} "
                            f"paper@10k=47.6us/73.2MB"))
    return rows


if __name__ == "__main__":
    main()

"""Fig. 17 — IGTCache management overhead vs AccessStreamTree node count:
per-access CPU time (µs) and tree memory (MB).  The paper reports 47.6 µs and
73.2 MB at the 10 000-node default (Go implementation; ours is Python —
the shape of the curves, O(log N) time / O(N) memory, is the claim).

Methodology (documented in docs/PERF.md): each configuration runs the same
seeded trace ``repeats`` times and reports the best run (standard practice
for CPU-overhead microbenchmarks — the minimum is the least noise-polluted
sample); the cyclic GC is paused during the timed region so the number
measures the engine, not the allocator's global heap scans.  Results are
printed as CSV rows and persisted to ``BENCH_overhead.json`` so the perf
trajectory is tracked across PRs.  ``--smoke`` runs a single down-scaled
configuration in a couple of seconds for the test job.

``--shards 1,4,8,16`` (the default) additionally measures the path-hash
sharded facade (``ShardedIGTCache``) at the 10k cap over an 8-dataset
layout, with the shard counts interleaved run-by-run so the set is
same-protocol comparable; the points land in the JSON's ``sharded``
section.  The same shard counts (>1) drive the ``rebalance_path`` axis:
the scaled paper-suite cluster sim per shard count under both
``quantum_policy`` settings, recording CHR gap vs unsharded, summary
bytes/round shipped by the sketch-based demand summaries, and
rounds-to-converge (the round after which the planner goes quiet).

``--procs 1,2,4`` (the default) measures the **multi-process shard
driver** (``core.procdriver.ProcessShardedCache``) on a batched
whole-sample ``read_batch`` protocol (steady-state: untimed warmup
prefix), always alongside the single-process kernel loop and the
in-process 4-shard facade, all interleaved run-by-run; the points land
in the JSON's ``proc_path`` section via ``merge_overhead_section`` (the
headline is ``proc_4`` beating both ``proc_1`` and the in-process
engines — shard count as an actual throughput knob).
"""
from __future__ import annotations

import argparse
import gc
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (CacheConfig, IGTCache, ProcessShardedCache,
                        ShardedIGTCache)
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset

from .common import csv_row, emit_json, merge_overhead_section

# Historical reference points for the speedup bookkeeping in the JSON:
#   * "pr1_start": what this benchmark printed on the seed engine when PR 1
#     began (seed harness: single run, default GC) — the number the PR's
#     ≥5× target was calibrated against;
#   * "same_protocol": the seed engine re-measured at PR 1 end with THIS
#     harness (best-of-3, GC paused) interleaved with the new engine on the
#     same machine — the apples-to-apples baseline.  The container's CPU
#     throughput varies by >2× over hours, so only interleaved same-protocol
#     pairs are comparable; see docs/PERF.md.
SEED_US_PER_ACCESS_10K = {
    "pr1_start": 221.6,
    "same_protocol": 74.4,
}


def tree_memory_bytes(tree) -> int:
    total = 0
    for node in tree.iter_nodes():
        total += sys.getsizeof(node)
        total += node.ring_memory_bytes()
        total += sys.getsizeof(node.child_hits)
    return total


def _timed_trace(eng, files, n_accesses: int, seed: int) -> float:
    """The shared measurement protocol: seeded random 64 KiB reads with
    inline prefetch completion, timed with the cyclic GC paused.  One copy
    for both the unsharded and the sharded axis — the interleaved
    same-protocol comparison is only meaningful if both run exactly this.
    Returns µs/access."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(files), n_accesses)
    offs = rng.integers(0, 2, n_accesses)
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for i, j in enumerate(idx):
            f = files[int(j)]
            out = eng.read(f.path, int(offs[i]) * 4 * MB, 64 * 1024,
                           i * 0.001)
            for p, s in out.prefetches:
                eng.complete_prefetch(p, s, i * 0.001)
        dt = time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()
    return dt / n_accesses * 1e6


def _run_once(node_cap: int, n_accesses: int, seed: int):
    # Deep layout (multi-block files → file nodes materialize) so the tree
    # genuinely grows toward the cap: ~1 + 80 dirs + 80×120 file nodes
    # reachable under the paper's window-100 child pruning.
    store = RemoteStore()
    store.add(make_dataset("ds", "dir_tree", n_dirs=80, files_per_dir=120,
                           small_file_size=9 * MB))
    cfg = CacheConfig(node_cap=node_cap, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    eng = IGTCache(store, 512 * MB, cfg=cfg)
    us = _timed_trace(eng, store.datasets["ds"].files, n_accesses, seed)
    mem = tree_memory_bytes(eng.tree)
    return us, mem, eng.tree.node_count()


def measure(node_cap: int, n_accesses: int = 30_000, seed: int = 0,
            repeats: int = 3):
    """Best-of-``repeats`` µs/access (the trace and final engine state are
    identical across repeats, so mem/nodes are taken from the fastest run)."""
    best = None
    for _ in range(max(1, repeats)):
        got = _run_once(node_cap, n_accesses, seed)
        if best is None or got[0] < best[0]:
            best = got
    return best


def _run_once_sharded(node_cap: int, n_accesses: int, seed: int,
                      n_shards: int):
    """One timed run of the path-hash sharded facade.

    Multi-dataset layout (sharding routes on the top-level component, so a
    single-dataset trace would land on one shard): 8 dir_tree datasets with
    the same total dir/file population as the unsharded Fig.-17 layout.
    Every shard count replays the identical seeded trace, so the
    ``n_shards`` axis isolates routing + partitioning overhead.
    """
    store = RemoteStore()
    for i in range(8):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=10,
                               files_per_dir=120, small_file_size=9 * MB))
    cfg = CacheConfig(node_cap=node_cap, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    eng = ShardedIGTCache(store, 512 * MB, cfg=cfg, n_shards=n_shards)
    files = [f for ds in store.datasets.values() for f in ds.files]
    us = _timed_trace(eng, files, n_accesses, seed)
    mem = sum(tree_memory_bytes(s.tree) for s in eng.shards)
    return us, mem, eng.node_count()


def measure_shards(shard_counts, node_cap: int, n_accesses: int,
                   seed: int, repeats: int):
    """Interleaved same-protocol sweep over shard counts: repeats alternate
    between configurations so the container's CPU drift (>2×/hour, see
    docs/PERF.md) hits every configuration equally; best run per count."""
    best = {n: None for n in shard_counts}
    for _ in range(max(1, repeats)):
        for n in shard_counts:
            got = _run_once_sharded(node_cap, n_accesses, seed, n)
            if best[n] is None or got[0] < best[n][0]:
                best[n] = got
    return best


# ---------------------------------------------------------------------------
# multi-process shard driver axis (proc_path)
# ---------------------------------------------------------------------------

def _proc_store():
    """The 8-dataset layout of the sharded axis (routing is per dataset)."""
    store = RemoteStore()
    for i in range(8):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=10,
                               files_per_dir=120, small_file_size=9 * MB))
    return store


def _timed_batch_trace(eng, files, n_accesses: int, seed: int,
                       batch: int, warmup_frac: float = 0.25) -> float:
    """The ``read_batch`` measurement protocol shared by every driver on
    the proc axis: the seeded random 64 KiB trace of ``_timed_trace``,
    grouped into fixed-size batches, with inline prefetch completion.
    In-process engines complete the returned candidates here (the
    caller-driven loop); the process driver runs ``prefetch="inline"``
    so its workers complete the same candidates kernel-side — the
    completion loop below then sees empty lists, and the kernel state
    evolution is identical.

    The first ``warmup_frac`` of the trace runs **untimed** for every
    configuration: this axis measures *steady-state* throughput of a
    long-running shard driver, not first-touch costs (tree build,
    fork/COW page materialization, pickle memo warmup — the process
    driver pays the latter two once per worker lifetime, the in-process
    engines never do).  Accesses are **whole-sample reads** (the full
    9 MB file → a 3-block extent at the 4 MB block size): batched
    ``read_batch`` traffic is training loaders fetching samples, not
    sub-block probes — this is the protocol the single-access Fig.-17
    axis does *not* cover.  Returns µs/access over the timed portion."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(files), n_accesses)
    reqs = []
    for j in idx:
        f = files[int(j)]
        reqs.append((f.path, 0, f.size))
    warm = int(n_accesses * warmup_frac) // batch * batch

    def drive(start: int, stop: int) -> None:
        for s in range(start, stop, batch):
            now = s * 0.001
            outs = eng.read_batch(reqs[s:s + batch], now)
            for out in outs:
                for p, sz in out.prefetches:
                    eng.complete_prefetch(p, sz, now)

    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        drive(0, warm)                       # untimed warmup, all configs
        t0 = time.perf_counter()
        drive(warm, n_accesses)
        dt = time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()
    return dt / max(1, n_accesses - warm) * 1e6


def _run_once_proc_axis(config, node_cap: int, n_accesses: int, seed: int,
                        batch: int) -> float:
    """One timed run of one proc-axis configuration.

    ``config`` is ``("kernel", 1)`` (plain IGTCache — the single-process
    kernel loop), ``("facade", n)`` (in-process ShardedIGTCache) or
    ``("proc", n)`` (the multi-process driver, workers GC-paused to
    match the client-side GC pause of the in-process runs)."""
    store = _proc_store()
    cfg = CacheConfig(node_cap=node_cap, min_share=8 * MB,
                      rebalance_quantum=8 * MB)
    kind, n = config
    if kind == "kernel":
        eng = IGTCache(store, 512 * MB, cfg=cfg)
    elif kind == "facade":
        eng = ShardedIGTCache(store, 512 * MB, cfg=cfg, n_shards=n)
    else:
        eng = ProcessShardedCache(store, 512 * MB, cfg=cfg, n_procs=n,
                                  prefetch="inline", pause_worker_gc=True)
    files = [f for ds in store.datasets.values() for f in ds.files]
    try:
        return _timed_batch_trace(eng, files, n_accesses, seed, batch)
    finally:
        if kind == "proc":
            eng.close()


def measure_procs(proc_counts, node_cap: int, n_accesses: int, seed: int,
                  repeats: int, batch: int = 256):
    """Interleaved same-protocol sweep for the multi-process driver: the
    single-process kernel loop, the in-process 4-shard facade, and the
    process driver at each ``--procs`` count all run the identical
    batched trace back-to-back within each repeat; best per config."""
    configs = [("kernel", 1), ("facade", 4)] + \
              [("proc", n) for n in proc_counts]
    best = {c: None for c in configs}
    for _ in range(max(1, repeats)):
        for c in configs:
            us = _run_once_proc_axis(c, node_cap, n_accesses, seed, batch)
            if best[c] is None or us < best[c]:
                best[c] = us
    return best


def main(scale: float = 1.0, seed: int = 0, smoke: bool = False,
         json_path=None, shard_counts=(1, 4, 8, 16), proc_counts=(1, 2, 4)):
    caps = (10_000,) if smoke else (100, 1000, 10_000, 100_000)
    n_accesses = 6_000 if smoke else 30_000
    repeats = 2 if smoke else 3
    rows = []
    results = {}
    for cap in caps:
        us, mem, nodes = measure(cap, n_accesses=n_accesses, seed=seed,
                                 repeats=repeats)
        results[str(cap)] = {
            "us_per_access": round(us, 1),
            "tree_mb": round(mem / 2**20, 2),
            "nodes": nodes,
        }
        rows.append(csv_row(f"fig17.nodecap_{cap}.us_per_access",
                            round(us, 1),
                            f"mem_mb={mem/2**20:.1f} nodes={nodes} "
                            f"paper@10k=47.6us/73.2MB"))
    # ---- sharded-facade axis (interleaved, same protocol, 10k cap) ----
    sharded = {}
    if shard_counts:
        shard_accesses = 4_000 if smoke else 30_000
        got = measure_shards(tuple(shard_counts), 10_000, shard_accesses,
                             seed, repeats)
        for n in shard_counts:
            us, mem, nodes = got[n]
            sharded[str(n)] = {
                "us_per_access": round(us, 1),
                "tree_mb": round(mem / 2**20, 2),
                "nodes": nodes,
            }
            rows.append(csv_row(f"sharded.shards_{n}.us_per_access",
                                round(us, 1),
                                f"mem_mb={mem/2**20:.1f} nodes={nodes} "
                                f"interleaved-protocol"))
    payload = {
        "n_accesses": n_accesses,
        "repeats": repeats,
        "smoke": smoke,
        "results": results,
        "sharded": sharded,
        "paper_reference": {"us_per_access_at_10k": 47.6,
                            "tree_mb_at_10k": 73.2},
        "seed_reference": dict(SEED_US_PER_ACCESS_10K),
    }
    at10k = results.get("10000")
    if at10k:
        payload["speedup_vs_pr1_start_seed"] = round(
            SEED_US_PER_ACCESS_10K["pr1_start"] / at10k["us_per_access"], 2)
        payload["speedup_same_protocol"] = round(
            SEED_US_PER_ACCESS_10K["same_protocol"] / at10k["us_per_access"],
            2)
    # smoke runs must not clobber the canonical full-sweep record
    name = "overhead_smoke" if smoke else "overhead"
    # ... and a full run must not clobber the axes other benchmarks
    # merged into the shared file (client_path / store_path / proc_path):
    # carry unknown sections over before rewriting
    from .common import REPO_ROOT
    prev_path = (Path(json_path) if json_path is not None
                 else REPO_ROOT / f"BENCH_{name}.json")
    if prev_path.exists():
        try:
            prev = json.loads(prev_path.read_text())
        except ValueError:
            prev = {}
        for key, val in prev.items():
            if key not in ("bench", "generated_unix"):
                payload.setdefault(key, val)
    out_path = emit_json(name, payload, path=json_path)
    # ---- multi-process driver axis (interleaved, batched protocol) ----
    if proc_counts:
        rows.extend(run_proc_axis(tuple(proc_counts), seed=seed,
                                  smoke=smoke,
                                  json_path=json_path or out_path))
    # ---- cross-shard rebalance axis (cluster sim, both policies) ------
    reb_counts = tuple(n for n in shard_counts if n > 1)
    if reb_counts:
        rows.extend(run_rebalance_axis(reb_counts, seed=seed, smoke=smoke,
                                       json_path=json_path or out_path))
    return rows


def run_rebalance_axis(shard_counts=(4, 8, 16), seed: int = 0,
                       smoke: bool = False, json_path=None):
    """Measure + record the ``rebalance_path`` section: the scaled
    paper-suite cluster sim (the tier-1 convergence scenario) per shard
    count under both move-sizing policies, against one unsharded
    reference run.  Reported per configuration:

    * ``chr`` / ``chr_gap_pp`` — block hit ratio and its gap vs the
      unsharded engine (positive = sharded worse);
    * ``rounds`` / ``rounds_to_converge`` — cross-shard rounds run, and
      the (1-based) index of the last round that still moved bytes —
      after it the planner is quiet, i.e. converged;
    * ``summary_bytes_round_max/mean`` — wire size of all shards'
      demand summaries per round (exact top-k rows + CMS/SpaceSaving
      payloads), the number that must stay O(KB)/shard;
    * ``moves`` / ``bytes_moved_mb`` — total planner activity.
    """
    from repro.sim import ClusterSim, make_paper_suite

    scale = 0.08 if smoke else 0.15
    if smoke:
        shard_counts = shard_counts[:1]
    suite = make_paper_suite(scale=scale, seed=seed,
                             job_filter=[2, 8, 9, 14, 16])
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())

    def sim_cfg(policy):
        share = max(16 * MB, cap // 128)
        return CacheConfig(min_share=share, rebalance_quantum=share,
                           rebalance_period=10.0,
                           prefetch_budget_bytes=max(64 * MB, cap // 8),
                           quantum_policy=policy)

    mono = ClusterSim(suite, IGTCache(store, cap, cfg=sim_cfg("adaptive"))
                      ).run()
    rows = []
    section = {"smoke": smoke, "scale": scale,
               "unsharded_chr": round(mono.hit_ratio, 4)}
    for n in shard_counts:
        for policy in ("adaptive", "fixed"):
            eng = ShardedIGTCache(store, cap, cfg=sim_cfg(policy),
                                  n_shards=n)
            res = ClusterSim(suite, eng).run()
            trace = res.rebalance_trace
            sb = [r["summary_bytes"] for r in trace]
            active = [i for i, r in enumerate(trace) if r["moves"]]
            key = f"{policy}_{n}"
            section[key] = {
                "chr": round(res.hit_ratio, 4),
                "chr_gap_pp": round(
                    (mono.hit_ratio - res.hit_ratio) * 100, 2),
                "rounds": len(trace),
                "rounds_to_converge": (active[-1] + 1) if active else 0,
                "moves": sum(r["moves"] for r in trace),
                "bytes_moved_mb": round(
                    sum(r["bytes_moved"] for r in trace) / 2**20, 1),
                "summary_bytes_round_max": max(sb, default=0),
                "summary_bytes_round_mean": (round(sum(sb) / len(sb), 1)
                                             if sb else 0),
            }
            rows.append(csv_row(
                f"rebalance_path.{key}.chr_gap_pp",
                section[key]["chr_gap_pp"],
                f"chr={section[key]['chr']} "
                f"rounds_to_converge={section[key]['rounds_to_converge']} "
                f"summary_bytes_max={section[key]['summary_bytes_round_max']}"
            ))
    merge_overhead_section("rebalance_path", section, json_path=json_path)
    return rows


def run_proc_axis(proc_counts=(1, 2, 4), seed: int = 0, smoke: bool = False,
                  json_path=None):
    """Measure + record the ``proc_path`` section on its own (main()
    calls this; re-recording the axis does not require re-running the
    whole Fig.-17 sweep).  More repeats than the other axes: the driver
    configurations are the most sensitive to the container's CPU
    weather (4 worker processes on ~1.5 effective cores), and best-of
    needs samples to find a representative window for every config —
    interleaving keeps any single run internally fair."""
    proc_accesses = 1_024 if smoke else 8_192
    batch = 128 if smoke else 256
    repeats = 2 if smoke else 4
    rows = []
    got = measure_procs(proc_counts, 10_000, proc_accesses,
                        seed, repeats, batch=batch)
    section = {"smoke": smoke, "n_accesses": proc_accesses,
               "repeats": repeats, "batch": batch}
    for (kind, n), us in got.items():
        key = "kernel_1" if kind == "kernel" else f"{kind}_{n}"
        section[key] = {"us_per_access": round(us, 1)}
        rows.append(csv_row(f"proc_path.{key}.us_per_access",
                            round(us, 1), "interleaved-batched-protocol"))
    if "proc_4" in section and "proc_1" in section:
        section["speedup_4p_vs_1p"] = round(
            section["proc_1"]["us_per_access"] /
            section["proc_4"]["us_per_access"], 2)
        section["speedup_4p_vs_kernel"] = round(
            section["kernel_1"]["us_per_access"] /
            section["proc_4"]["us_per_access"], 2)
        section["speedup_4p_vs_facade"] = round(
            section["facade_4"]["us_per_access"] /
            section["proc_4"]["us_per_access"], 2)
    # lands next to results/sharded/client_path/store_path without
    # clobbering them (read-modify-write of the shared JSON)
    merge_overhead_section("proc_path", section, json_path=json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single down-scaled configuration for the test job")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", default="1,4,8,16",
                    help="comma-separated shard counts for the sharded-"
                         "facade axis and (counts >1) the rebalance_path "
                         "axis ('' disables both)")
    ap.add_argument("--procs", default="1,2,4",
                    help="comma-separated worker counts for the multi-"
                         "process driver axis ('' disables it); the "
                         "single-process kernel loop and the in-process "
                         "4-shard facade are always measured alongside, "
                         "interleaved")
    args = ap.parse_args()
    counts = tuple(int(x) for x in args.shards.split(",") if x.strip())
    procs = tuple(int(x) for x in args.procs.split(",") if x.strip())
    main(seed=args.seed, smoke=args.smoke, shard_counts=counts,
         proc_counts=procs)

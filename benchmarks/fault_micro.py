"""Availability micro-benchmark (the ``fault_path`` axis).

The fault-tolerant runtime claims three things a number can check:

1. **Recovery time** — a SIGKILLed shard worker is respawned by the
   supervisor with its store re-opened and kernel rebuilt cold; the
   respawn event records ``recovery_s`` from death detection to the
   shard serving again.
2. **Degraded-read cost** — while the shard is down, its reads bypass
   the kernel and fetch straight from the backing store.  Bytes always
   arrive; the question is what the detour costs per batch relative to
   the fault-free path.
3. **Post-recovery CHR convergence** — the respawned kernel starts cold
   but observes the same access stream; over a trailing window its CHR
   must converge back toward the fault-free run's (the chaos e2e test
   asserts the 5 % bound; here the gap is *recorded* into the perf
   trajectory).

Protocol: two runs of the same seeded trace against the multi-process
driver (2 workers) — fault-free baseline, then a chaos run with one
worker killed a third of the way in (``sim.chaos.ChaosMonkey``).  Both
runs step through identical ``read_batch`` calls with byte fetches on.
Results merge into ``BENCH_overhead.json`` under ``fault_path``
(``--smoke`` → ``BENCH_overhead_smoke.json``; exercised by
tests/test_bench_smoke.py).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

# .common bootstraps sys.path with REPO_ROOT/src — must import before repro
from .common import csv_row, merge_overhead_section

from repro.core import CacheConfig, open_cache
from repro.core.types import MB
from repro.sim.chaos import ChaosMonkey
from repro.storage import RemoteStore, make_dataset


def _world(n_datasets: int, files_per_dir: int):
    """Distinct top-level datasets so the key space spreads across both
    shard workers (routing hashes the top-level path component)."""
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"job{i}", "dir_tree", n_dirs=4,
                               files_per_dir=files_per_dir,
                               small_file_size=256 * 1024))
    return store


def _trace(store, n_steps: int, batch: int, seed: int):
    files = [f for ds in store.datasets.values() for f in ds.files]
    rng = np.random.default_rng(seed)
    steps = []
    for _ in range(n_steps):
        picks = rng.integers(0, len(files), batch)
        steps.append([(files[int(j)].path, 0, files[int(j)].size)
                      for j in picks])
    return steps


def _open(store, cap, cfg):
    return open_cache(store, cap, cfg=cfg, driver="process", n_procs=2,
                      arena_bytes=32 * MB, fetch_bytes=True,
                      rpc_timeout_s=10.0)


def _chr_delta(snap0: dict, snap1: dict) -> float:
    """Block-level CHR over the window between two stats snapshots."""
    hits = snap1["hits"] - snap0["hits"]
    total = hits + snap1["misses"] - snap0["misses"]
    return hits / total if total else 0.0


def _run(store, cap, cfg, steps, kill_step=None):
    """Drive one seeded trace; optionally kill a worker at ``kill_step``.
    Returns per-step latencies, windowed CHR samples, and fault/client
    accounting."""
    client = _open(store, cap, cfg)
    lat = []
    snaps = []
    monkey = None
    try:
        for i, reqs in enumerate(steps):
            if kill_step is not None and i == kill_step:
                monkey = ChaosMonkey(client)
                target = client.engine.shard_id(reqs[0][0])
                monkey.kill(target, reason="fault_micro")
            t0 = time.perf_counter()
            client.read_batch(reqs)
            lat.append(time.perf_counter() - t0)
            snaps.append(client.stats.snapshot())
        fault = client.fault_stats()
        return {"lat": lat, "snaps": snaps, "fault": fault,
                "client": client.client_stats.snapshot(),
                "strikes": monkey.strikes if monkey else []}
    finally:
        if monkey is not None:
            monkey.resume_all()
        client.close()


def main(smoke: bool = False, seed: int = 0, json_path=None):
    n_steps = 24 if smoke else 120
    batch = 8 if smoke else 16
    n_datasets = 4
    files_per_dir = 4 if smoke else 8
    store = _world(n_datasets, files_per_dir)
    cap = 96 * MB
    cfg = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                      window=40, reanalyze_every=20, node_cap=2000)
    steps = _trace(store, n_steps, batch, seed)
    kill_step = n_steps // 3
    window = max(4, n_steps // 4)          # trailing convergence window

    base = _run(store, cap, cfg, steps)
    chaos = _run(store, cap, cfg, steps, kill_step=kill_step)

    # recovery time straight from the supervisor's respawn event
    respawns = [e for e in chaos["fault"]["events"] if e["kind"] == "respawn"]
    recovery_s = respawns[0]["recovery_s"] if respawns else None

    # degraded-read cost: the batch that hit the fault (killed worker →
    # typed error → direct store fetches) vs the fault-free mean batch
    base_us = float(np.mean(base["lat"])) * 1e6
    degraded_us = chaos["lat"][kill_step] * 1e6

    # post-recovery CHR over the trailing window, both runs
    chr_base = _chr_delta(base["snaps"][-window], base["snaps"][-1])
    chr_chaos = _chr_delta(chaos["snaps"][-window], chaos["snaps"][-1])
    gap_pct = abs(chr_base - chr_chaos) * 100.0

    section = {
        "smoke": smoke, "n_steps": n_steps, "batch": batch,
        "kill_step": kill_step, "window": window,
        "baseline": {"us_per_batch": round(base_us, 1),
                     "chr_final": round(base["snaps"][-1]["hits"] /
                                        max(1, base["snaps"][-1]["hits"] +
                                            base["snaps"][-1]["misses"]), 4),
                     "chr_window": round(chr_base, 4)},
        "chaos": {"degraded_batch_us": round(degraded_us, 1),
                  "degraded_cost_x": round(degraded_us / max(base_us, 1e-9),
                                           2),
                  "chr_window": round(chr_chaos, 4),
                  "degraded_reads": chaos["client"]["degraded_reads"],
                  "degraded_bytes": chaos["client"]["degraded_bytes"],
                  "restarts": chaos["fault"]["restarts"],
                  "shard_states": {str(k): v["state"] for k, v in
                                   chaos["fault"]["shards"].items()}},
        "recovery_s": round(recovery_s, 4) if recovery_s is not None else None,
        "chr_gap_pct": round(gap_pct, 2),
        "converged_within_5pct": bool(gap_pct <= 5.0),
    }

    rows = [
        csv_row("fault_path.recovery_s", section["recovery_s"],
                f"restarts={section['chaos']['restarts']}"),
        csv_row("fault_path.degraded_batch_us",
                section["chaos"]["degraded_batch_us"],
                f"baseline={section['baseline']['us_per_batch']} "
                f"cost_x={section['chaos']['degraded_cost_x']}"),
        csv_row("fault_path.degraded_reads",
                section["chaos"]["degraded_reads"],
                f"bytes={section['chaos']['degraded_bytes']}"),
        csv_row("fault_path.chr_gap_pct", section["chr_gap_pct"],
                f"base={section['baseline']['chr_window']} "
                f"chaos={section['chaos']['chr_window']} "
                f"within_5pct={section['converged_within_5pct']}"),
    ]
    merge_overhead_section("fault_path", section, json_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="down-scaled run for the test job")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke=args.smoke, seed=args.seed)

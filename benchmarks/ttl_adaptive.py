"""Fig. 11 — adaptive TTL: job-9 (ImageNet train) is force-stopped at t=60 s;
measure when its dataset's cache space is released and job-13's throughput,
under adaptive TTL vs the fixed 600 s default."""
from __future__ import annotations

from repro.core import IGTCache, bundle
from repro.sim import ClusterSim

from .common import build_world, csv_row, scaled_cfg


def run(fixed_ttl, suite, store, cap):
    opts = bundle("igtcache")
    import dataclasses
    opts = dataclasses.replace(opts, fixed_ttl=fixed_ttl)
    eng = IGTCache(store, cap, cfg=scaled_cfg(cap), options=opts)
    sim = ClusterSim(suite, eng, trace_alloc=True, stop_job_at=(9, 60.0))
    res = sim.run(max_time=1500.0)
    # first sample time after t=60 where the imagenet CMU's usage dropped
    # to (near) zero = eviction of the finished job's dataset
    evict_t = None
    peak = 0
    for row in res.alloc_trace:
        used = row.get("imagenet", {}).get("used", 0)
        peak = max(peak, used)
        if row["t"] > 60.0 and peak > 0 and used < 0.1 * peak:
            evict_t = row["t"]
            break
    return res, evict_t


def main(scale: float = 1.0, seed: int = 0):
    rows = []
    suite, store, cap = build_world(scale=scale, seed=seed,
                                    job_filter=[9, 13], cache_ratio=0.30)
    res_a, t_a = run(None, suite, store, cap)       # adaptive
    res_f, t_f = run(600.0, suite, store, cap)      # fixed default
    rows.append(csv_row("fig11.adaptive.evict_start_s",
                        t_a if t_a else "not_observed", "paper=146"))
    rows.append(csv_row("fig11.fixed600.evict_start_s",
                        t_f if t_f else ">600", "paper=660"))
    rows.append(csv_row("fig11.adaptive.job13_jct_s",
                        round(res_a.jct.get(13, float("nan")), 1),
                        f"fixed={res_f.jct.get(13, float('nan')):.1f}"))
    return rows


if __name__ == "__main__":
    main()

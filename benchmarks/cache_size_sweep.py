"""Fig. 16 — CHR vs cache size (fraction of total dataset volume)."""
from __future__ import annotations

from .common import build_world, csv_row, run_sim


def main(scale: float = 1.0, seed: int = 0):
    rows = []
    for frac in (0.2, 0.35, 0.5, 0.75, 1.0):
        suite, store, cap = build_world(scale=scale, seed=seed,
                                        cache_ratio=frac)
        igt, _ = run_sim(suite, store, cap, "igtcache")
        jfs, _ = run_sim(suite, store, cap, "juicefs")
        rows.append(csv_row(f"fig16.cache_{int(frac*100)}pct.igtcache_chr",
                            round(igt.hit_ratio, 3),
                            f"juicefs={jfs.hit_ratio:.3f}"))
    return rows


if __name__ == "__main__":
    main()

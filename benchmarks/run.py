"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (also collected into the return
value).  Usage:  PYTHONPATH=src python -m benchmarks.run  [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scale for CI")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()
    scale = 0.3 if args.quick else 1.0

    from . import (allocation_micro, cache_size_sweep, e2e_cluster,
                   eviction_micro, ks_sensitivity, overhead, prefetch_micro,
                   ttl_adaptive)
    modules = {
        "e2e_cluster": e2e_cluster,            # Fig 8
        "prefetch_micro": prefetch_micro,      # Fig 9 (+Fig 7 ablation)
        "eviction_micro": eviction_micro,      # Fig 10
        "ttl_adaptive": ttl_adaptive,          # Fig 11
        "allocation_micro": allocation_micro,  # Fig 12/13
        "ks_sensitivity": ks_sensitivity,      # Fig 14/15
        "cache_size_sweep": cache_size_sweep,  # Fig 16
        "overhead": overhead,                  # Fig 17
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,value,derived")
    for name, mod in modules.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main(scale=scale)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

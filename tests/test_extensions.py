"""User pin/never-cache controls (§3.3 footnote 8) + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CacheConfig, IGTCache
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset
from repro.train.optimizer import (AdamWConfig, apply_updates, compress_grads,
                                   init_state)

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB)


def mk():
    store = RemoteStore()
    store.add(make_dataset("a", "flat_files", n_files=100,
                           small_file_size=256 * 1024))
    store.add(make_dataset("b", "flat_files", n_files=100,
                           small_file_size=256 * 1024))
    return store


def test_never_cache_passes_through():
    store = mk()
    eng = IGTCache(store, 64 * MB, cfg=CFG)
    eng.never_cache(("b",))
    fa = store.datasets["a"].files[0]
    fb = store.datasets["b"].files[0]
    for t in range(3):
        eng.read(fa.path, 0, fa.size, float(t))
        eng.read(fb.path, 0, fb.size, float(t) + 0.5)
    from repro.core import path_key
    assert eng.cache.resident(path_key(fa.path + ("#0",)))
    assert not eng.cache.resident(path_key(fb.path + ("#0",)))


def test_pin_exempts_from_ttl():
    store = mk()
    eng = IGTCache(store, 8 * MB, cfg=CFG)   # tight: pressure for TTL
    eng.pin(("a", "files"))
    import random
    rng = random.Random(0)
    files = store.datasets["a"].files
    t = 0.0
    for _ in range(300):
        f = files[rng.randrange(len(files))]
        eng.read(f.path, 0, f.size, t)
        t += 0.1
    cmu_path = next((p for p in eng.cache.cmus if p[0] == "a"), None)
    assert cmu_path is not None
    # long idle + pressure from the other dataset
    fb = store.datasets["b"].files
    for i in range(200):
        eng.read(fb[i % len(fb)].path, 0, fb[0].size, t)
        t += 1.0
    eng.tick(t + 1000.0)
    assert cmu_path in eng.cache.cmus        # pinned stream survives TTL


def test_int8_compression_error_bound():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32) * 0.01}
    out = compress_grads(grads, "int8")
    rel = float(jnp.max(jnp.abs(out["w"] - grads["w"])) /
                jnp.max(jnp.abs(grads["w"])))
    assert rel < 1.0 / 127 + 1e-6            # absmax-int8 bound


def test_training_with_compression_converges():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0,
                      grad_compression="int8")
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(grads=grads, params=params,
                                         state=state, cfg=cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2

"""Sharding-variant rules + chunked-CE lowering smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.mesh import make_local_mesh
from repro.launch.variants import apply_variant
from repro.models.config import ShapeSpec
from repro.models.transformer import forward, init_params, lm_loss, lm_loss_chunked
from repro.sharding import DEFAULT_RULES
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.train_step import make_train_step

from conftest import requires_mesh_axis_types

ALL_VARIANTS = ["fsdp_pod", "no_fsdp", "seq_shard", "expert_data",
                "vocab_data", "cache_seq_model", "pure_fsdp",
                "embed_replicated", "decode_weights_stationary",
                "ep_capacity", "ep_only"]


@pytest.mark.parametrize("v", ALL_VARIANTS)
def test_variants_produce_valid_rules(v):
    rules = apply_variant(dict(DEFAULT_RULES), "qwen3-1.7b", "train_4k", v)
    assert isinstance(rules, dict)
    assert set(DEFAULT_RULES) <= set(rules)


def test_unknown_variant_raises():
    with pytest.raises(KeyError):
        apply_variant(dict(DEFAULT_RULES), "x", "train_4k", "nope")


def test_chunked_ce_matches_dense():
    cfg = reduced_config("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = forward(params, cfg, tokens, remat="none")
    dense = lm_loss(logits, tokens)
    x, _ = forward(params, cfg, tokens, remat="none", return_hidden=True)
    for chunk in (64, 100, 256):
        ck = lm_loss_chunked(x, params, cfg, tokens, vocab_chunk=chunk)
        assert float(jnp.abs(dense - ck)) < 1e-3


def test_chunked_ce_grad_matches_dense():
    cfg = reduced_config("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    def dense_loss(p):
        lg, _ = forward(p, cfg, tokens, remat="none")
        return lm_loss(lg, tokens)

    def chunked(p):
        x, _ = forward(p, cfg, tokens, remat="none", return_hidden=True)
        return lm_loss_chunked(x, p, cfg, tokens, vocab_chunk=64)

    g1 = jax.grad(dense_loss)(params)
    g2 = jax.grad(chunked)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


@requires_mesh_axis_types
def test_train_step_chunked_loss_runs():
    cfg = reduced_config("qwen3-1.7b")
    mesh = make_local_mesh()
    step = jax.jit(make_train_step(cfg, AdamWConfig(), mesh, None,
                                   remat="none", loss_impl="chunked"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_state(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    params, opt, m = step(params, opt, {"tokens": tokens, "labels": tokens})
    assert bool(jnp.isfinite(m["loss"]))

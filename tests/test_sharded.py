"""Sharded-engine invariants (PR-2 tentpole).

Routing: same path → same shard, always, across facade instances (the hash
is process-stable).  Batching: ``read_batch`` splits a batch by shard but
returns outcomes in the original request order.  Allocation: the
cross-shard GlobalRebalancer conserves total capacity and every shard's
``sum(quota) == capacity`` invariant — under both move-sizing policies
(``quantum_policy="fixed"`` legacy loop and the PR-7 sketch-fed adaptive
planner).  End-to-end: the paper-suite cluster sim at n=4/8/16 stays
within 2 pp CHR of the unsharded engine (bitwise equivalence at
``n_shards=1`` is pinned in test_equivalence.py).
"""
import random

import pytest

from repro.core import (CacheConfig, GlobalRebalancer, IGTCache, Pattern,
                        ShardedIGTCache, bundle_engine, make_engine,
                        shard_index)
from repro.core.sharded import DemandSummary
from repro.core.types import MB
from repro.sim import ClusterSim, make_paper_suite
from repro.storage import RemoteStore, make_dataset

CFG = CacheConfig(min_share=8 * MB, rebalance_quantum=8 * MB,
                  rebalance_period=5.0, node_cap=300, window=20,
                  reanalyze_every=10)


def mk_store(n_datasets=6):
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=4,
                               files_per_dir=8, small_file_size=512 * 1024))
    return store


# ------------------------------------------------------------------ routing

def test_same_path_same_shard_always():
    store = mk_store()
    a = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    b = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    for ds in store.datasets.values():
        for f in ds.files:
            sid = a.shard_id(f.path)
            # stable across repeated calls, facade instances, and the free
            # function; block paths route with their file
            assert a.shard_id(f.path) == sid
            assert b.shard_id(f.path) == sid
            assert shard_index(f.path, 4) == sid
            assert a.shard_id(f.path + ("#0",)) == sid


def test_routing_hashes_once_per_dataset(monkeypatch):
    """Memoized routing (ISSUE 5 satellite): the CRC-32 runs once per
    top-level component, not once per access — every later access is a
    dict lookup on both drivers (ShardRouting mixin)."""
    import repro.core.sharded as sh
    calls = []
    real = sh.zlib.crc32
    monkeypatch.setattr(sh.zlib, "crc32",
                        lambda data: calls.append(data) or real(data))
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    t = 0.0
    for _ in range(3):
        for ds in store.datasets.values():
            for f in ds.files[:8]:
                eng.read(f.path, 0, f.size, t)
                t += 0.01
    assert len(calls) <= len(store.datasets), \
        f"CRC-32 ran {len(calls)}× for {len(store.datasets)} datasets"


def test_routing_only_uses_top_level_component():
    """A dataset never straddles shards: every stream (directory, file,
    block level) observes exactly its unsharded access sequence."""
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    for ds in store.datasets.values():
        sids = {eng.shard_id(f.path) for f in ds.files}
        assert len(sids) == 1


def test_reads_land_on_routed_shard():
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    f = store.datasets["ds0"].files[0]
    eng.read(f.path, 0, f.size, 0.0)
    sid = eng.shard_id(f.path)
    for i, shard in enumerate(eng.shards):
        expect = 1 if i == sid else 0
        assert shard.stats.accesses == expect


# ----------------------------------------------------------------- batching

def test_read_batch_preserves_request_order():
    store = mk_store()
    mono = IGTCache(store, 64 * MB, cfg=CFG)
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    # interleave datasets so consecutive requests hit different shards
    files = []
    dss = list(store.datasets.values())
    for i in range(8):
        for ds in dss:
            files.append(ds.files[i])
    reqs = [(f.path, 0, f.size) for f in files]
    t = 0.0
    for _ in range(3):
        outs = eng.read_batch(reqs, t)
        ref = mono.read_batch(reqs, t)
        assert len(outs) == len(reqs)
        for (fp, off, sz), out, r in zip(reqs, outs, ref):
            # outcome i describes request i: same block keys as unsharded
            assert [b.key for b in out.blocks] == [b.key for b in r.blocks]
        for o in outs:
            for p, s in o.prefetches:
                eng.complete_prefetch(p, s, t)
        for o in ref:
            for p, s in o.prefetches:
                mono.complete_prefetch(p, s, t)
        t += 0.5


# --------------------------------------------------------------- allocation

def _drive(eng, store, reps=40, t0=0.0, dt=0.05):
    """Skewed traffic on ds0, sequential scan on ds1 — promotes CMUs with
    opposite marginal benefit."""
    t = t0
    hot = store.datasets["ds0"].files[:3]
    for r in range(reps):
        for f in hot:                      # revisit a hot set (skew)
            out = eng.read(f.path, 0, f.size, t)
            t += dt
        f = store.datasets["ds1"].files[r % 32]
        eng.read(f.path, 0, f.size, t)     # one sequential step
        t += dt
    return t


def test_cross_shard_rebalance_conserves_capacity():
    store = mk_store()
    cap = 64 * MB
    eng = ShardedIGTCache(store, cap, cfg=CFG, n_shards=4)
    assert sum(eng.shard_capacities()) == cap
    t = _drive(eng, store)
    for k in range(1, 30):
        eng.tick(t + k * CFG.rebalance_period)
        assert sum(eng.shard_capacities()) == cap
        for s in eng.shards:
            assert s.cache.quota_invariant_ok()
            assert sum(c.quota for c in s.cache.cmus.values()) \
                == s.cache.capacity


def test_global_rebalancer_moves_toward_demand():
    """A skewed CMU with ghost-window demand pulls capacity from another
    shard's idle default pool."""
    store = mk_store()
    s0 = IGTCache(store, 32 * MB, cfg=CFG)
    s1 = IGTCache(store, 32 * MB, cfg=CFG)
    cmu = s0.cache.create_cmu(("ds0",), 128 * MB, now=0.0)
    cmu.flat_pattern = Pattern.SKEWED
    for i in range(50):                      # arrival rate + ghost hits
        cmu.note_access(i * 0.01)
        cmu.buffer_window.on_evict(f"k{i}")
        cmu.buffer_window.probe(f"k{i}")
    reb = GlobalRebalancer(CFG)
    before = (s0.cache.capacity, s1.cache.capacity)
    moves = reb.rebalance_shards([s0, s1], now=CFG.rebalance_period + 1.0)
    assert moves, "expected at least one cross-shard move"
    assert s0.cache.capacity > before[0]
    assert s1.cache.capacity < before[1]
    assert s0.cache.capacity + s1.cache.capacity == sum(before)
    for s in (s0, s1):
        assert sum(c.quota for c in s.cache.cmus.values()) \
            == s.cache.capacity


def test_global_estimate_survives_local_window_reset():
    """Shard-local rounds reset the per-round ghost counters on their own
    read-triggered phase; the global layer must still see a skewed CMU's
    demand (it measures cumulative-counter deltas over its own interval)."""
    store = mk_store()
    s0 = IGTCache(store, 32 * MB, cfg=CFG)
    s1 = IGTCache(store, 32 * MB, cfg=CFG)
    cmu = s0.cache.create_cmu(("ds0",), 128 * MB, now=0.0)
    cmu.flat_pattern = Pattern.SKEWED
    for i in range(50):
        cmu.note_access(i * 0.01)
        cmu.buffer_window.on_evict(f"k{i}")
        cmu.buffer_window.probe(f"k{i}")
    # a local round fired a moment ago and zeroed the per-round window
    cmu.buffer_window.reset_window()
    assert cmu.buffer_window.hit_frequency() == 0.0
    reb = GlobalRebalancer(CFG)
    moves = reb.rebalance_shards([s0, s1], now=CFG.rebalance_period + 1.0)
    assert moves, "reset phase must not hide cross-shard demand"
    # next interval starts at the marks: no new ghost traffic -> no demand
    moves2 = reb.rebalance_shards([s0, s1],
                                  now=2 * CFG.rebalance_period + 2.0)
    assert not moves2


def test_single_shard_never_globally_rebalances():
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=1)
    t = _drive(eng, store)
    eng.tick(t + CFG.rebalance_period + 1.0)
    assert eng.shard_capacities() == [64 * MB]


# ------------------------------------------------------------- constructors

def test_make_engine_dispatch():
    store = mk_store()
    assert isinstance(make_engine(store, 64 * MB, cfg=CFG), IGTCache)
    eng = make_engine(store, 64 * MB, cfg=CFG, n_shards=4)
    assert isinstance(eng, ShardedIGTCache)
    assert eng.n_shards == 4
    jfs = bundle_engine("juicefs", store, 64 * MB, cfg=CFG, n_shards=2)
    assert isinstance(jfs, ShardedIGTCache)
    assert jfs.options.name == "juicefs"
    with pytest.raises(ValueError):
        ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=0)


# ------------------------------------------------------- end-to-end cluster

def _scaled_cfg(capacity, policy="adaptive"):
    share = max(16 * MB, capacity // 128)
    return CacheConfig(min_share=share, rebalance_quantum=share,
                       rebalance_period=10.0,
                       prefetch_budget_bytes=max(64 * MB, capacity // 8),
                       quantum_policy=policy)


@pytest.fixture(scope="module")
def paper_sim():
    """Scaled paper-suite runs shared by the convergence tests: one
    store/suite, results cached per shard count so the n=4/8/16 cases
    pay for one simulation each (plus one unsharded reference)."""
    suite = make_paper_suite(scale=0.15, seed=0,
                             job_filter=[2, 8, 9, 14, 16])
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())
    cache = {}

    def run(n_shards):
        if n_shards not in cache:
            if n_shards == 1:
                eng = IGTCache(store, cap, cfg=_scaled_cfg(cap))
            else:
                eng = ShardedIGTCache(store, cap, cfg=_scaled_cfg(cap),
                                      n_shards=n_shards)
            res = ClusterSim(suite, eng).run()
            if n_shards > 1:
                assert sum(eng.shard_capacities()) == cap
            cache[n_shards] = (eng, res)
        return cache[n_shards]

    return run


@pytest.mark.parametrize("n_shards", [4, 8, 16])
def test_sharded_cluster_sim_chr_converges(paper_sim, n_shards):
    """Paper-suite cluster sim (scaled): sharded CHR within 2 pp of the
    unsharded engine at n=4, *and* — the sketch-rebalance headline — at
    n=8 and n=16, where the fixed-quantum planner used to trail by
    11-16 pp.  One-sided: the global planner may legitimately beat the
    unsharded engine (it sizes demand across shards that the local
    rounds cannot see)."""
    _, mono = paper_sim(1)
    _, shard = paper_sim(n_shards)
    assert shard.hit_ratio >= mono.hit_ratio - 0.02, \
        f"CHR gap at n={n_shards}: unsharded={mono.hit_ratio:.4f} " \
        f"sharded={shard.hit_ratio:.4f}"


def test_rebalance_trace_bounded_summaries(paper_sim):
    """Every cross-shard round's wire payload stays O(KB)/shard — the
    point of shipping sketches instead of per-block counters — and the
    rounds are recorded in SimResult.rebalance_trace."""
    _, shard = paper_sim(8)
    trace = shard.rebalance_trace
    assert trace, "sharded run must record rebalance rounds"
    for row in trace:
        assert row["summary_bytes"] <= 4096 * 8
        assert row["policy"] == "adaptive"
    assert any(r["moves"] > 0 for r in trace)
    assert any(r["ghost_mass"] > 0 for r in trace)


# --------------------------------------------- adaptive planner (properties)

def _rand_rows(rng, n_shards, down=None):
    """Synthetic demand rows across shards, shapes the planner must keep
    capacity-safe: defaults with zero floors, workload CMUs with random
    quota/used/want/floor/benefit.  ``down`` excludes one shard's rows
    entirely (a dead worker contributes nothing — PR-6 freeze)."""
    rows = []
    for sid in range(n_shards):
        if sid == down:
            continue
        dq = rng.randrange(0, 512 * MB, MB)
        rows.append(DemandSummary(
            shard=sid, key=("<default>",), benefit=0.0, wants_more=False,
            can_take=False, quota=dq, headroom=dq, want=0, floor=0,
            free=rng.randrange(0, dq + 1)))
        for i in range(rng.randrange(0, 3)):
            q = rng.randrange(0, 256 * MB, MB)
            rows.append(DemandSummary(
                shard=sid, key=(f"ds{sid}_{i}",),
                benefit=rng.random() * rng.choice([0.0, 1e-6, 1e-3, 1.0]),
                wants_more=rng.random() < 0.5, can_take=True, quota=q,
                headroom=q - 8 * MB,
                demand_limit=(rng.randrange(0, 512 * MB)
                              if rng.random() < 0.5 else None),
                want=rng.randrange(0, 256 * MB, MB),
                floor=rng.choice([0, 8 * MB, 64 * MB]),
                free=rng.randrange(0, q + 1)))
    return rows


@pytest.mark.parametrize("policy", ["adaptive", "fixed"])
def test_plan_moves_conserves_capacity_property(policy):
    """Randomized invariant sweep: whatever rows the planner sees, the
    planned moves conserve total quota, never drive a row negative, and
    never pull a workload donor below min_share."""
    cfg = CacheConfig(min_share=8 * MB, rebalance_quantum=8 * MB,
                      quantum_policy=policy)
    rng = random.Random(1234)
    for trial in range(200):
        n_shards = rng.choice([2, 3, 4, 8])
        down = rng.choice([None, rng.randrange(n_shards)])
        rows = _rand_rows(rng, n_shards, down=down)
        total = sum(r.quota for r in rows)
        reb = GlobalRebalancer(cfg)
        moves = reb.plan_moves(rows)
        assert sum(r.quota for r in rows) == total
        assert sum(a for _, _, a in moves) >= 0
        for d, t, amt in moves:
            assert amt > 0
            assert d.shard != t.shard
            if down is not None:
                assert down not in (d.shard, t.shard)
        for r in rows:
            assert r.quota >= 0
            if r.can_take and not any(r is d for d, _, _ in moves):
                continue    # untouched or taker: no donor floor to check
        for r in rows:
            if r.can_take and any(r is d for d, _, _ in moves):
                assert r.quota >= cfg.min_share or r.headroom <= 0


def test_adaptive_floor_topup_repairs_starvation():
    """A CMU born at quota 0 (defaults drained at creation time) is
    topped up to its floor even though benefit ordering alone would
    never select it — and the top-up retries each round, so it heals
    as soon as any donor has headroom."""
    cfg = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB)
    starving = DemandSummary(shard=0, key=("new",), benefit=0.0,
                             wants_more=False, can_take=True, quota=0,
                             headroom=-16 * MB, want=0, floor=16 * MB,
                             free=0)
    donor = DemandSummary(shard=1, key=("<default>",), benefit=0.0,
                          wants_more=False, can_take=False,
                          quota=128 * MB, headroom=128 * MB, want=0,
                          floor=0, free=128 * MB)
    reb = GlobalRebalancer(cfg)
    moves = reb.plan_moves([starving, donor])
    assert moves and starving.quota >= starving.floor
    assert donor.quota == 128 * MB - sum(a for _, _, a in moves)


def test_adaptive_want_sized_move_beats_one_quantum():
    """With a large measured want and a cold donor, one adaptive round
    moves (almost) the whole want — the fixed policy would need
    O(want/quantum) rounds."""
    cfg = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB)
    taker = DemandSummary(shard=0, key=("hot",), benefit=1.0,
                          wants_more=True, can_take=True, quota=64 * MB,
                          headroom=48 * MB, want=512 * MB, floor=16 * MB,
                          free=0)
    donor = DemandSummary(shard=1, key=("<default>",), benefit=0.0,
                          wants_more=False, can_take=False,
                          quota=1024 * MB, headroom=1024 * MB, want=0,
                          floor=0, free=1024 * MB)
    reb = GlobalRebalancer(cfg)
    moves = reb.plan_moves([taker, donor])
    assert sum(a for _, _, a in moves) == 512 * MB
    assert taker.want == 0


def test_adaptive_flow_cooldown_blocks_reversal():
    """A donor→taker flow must not reverse on the next round even if the
    benefit estimates momentarily flip (ping-pong damping for
    want-sized moves)."""
    cfg = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB)
    reb = GlobalRebalancer(cfg)

    def mk(b_a, b_b, qa, qb, want_a, want_b):
        a = DemandSummary(shard=0, key=("a",), benefit=b_a,
                          wants_more=True, can_take=True, quota=qa,
                          headroom=qa - 16 * MB, want=want_a,
                          floor=16 * MB, free=0)
        b = DemandSummary(shard=1, key=("b",), benefit=b_b,
                          wants_more=True, can_take=True, quota=qb,
                          headroom=qb - 16 * MB, want=want_b,
                          floor=16 * MB, free=0)
        return a, b
    a, b = mk(1.0, 1e-6, 64 * MB, 256 * MB, 128 * MB, 0)
    moves = reb.plan_moves([a, b])
    assert moves and all(d is b for d, _, _ in moves)
    # next round: estimates flip — the fresh b→a flow must not reverse
    a2, b2 = mk(1e-6, 1.0, a.quota, b.quota, 0, 128 * MB)
    moves2 = reb.plan_moves([a2, b2])
    assert not moves2
    # the round after, the cooldown has expired and the move is allowed
    a3, b3 = mk(1e-6, 1.0, a.quota, b.quota, 0, 128 * MB)
    assert reb.plan_moves([a3, b3])


# ---------------------------------------------------- tracker housekeeping

def test_ghost_mark_table_stays_bounded():
    """Long mixed trace with CMU churn: the tracker's ghost-mark and EMA
    tables track only live CMUs — entries for TTL-removed/evicted CMUs
    are pruned on each round, not accumulated forever."""
    store = mk_store()
    eng = IGTCache(store, 64 * MB, cfg=CFG)
    reb = GlobalRebalancer(CFG)
    tracker = reb.tracker
    for gen in range(12):
        cmu = eng.cache.create_cmu((f"ds{gen % 6}", f"g{gen}"), 32 * MB,
                                   now=float(gen))
        cmu.flat_pattern = Pattern.SKEWED
        for i in range(30):
            cmu.note_access(gen + i * 0.01)
            cmu.buffer_window.on_evict(f"k{gen}_{i}")
            cmu.buffer_window.probe(f"k{gen}_{i}")
        tracker.summarize(eng, 0, float(gen) + 0.5)
        live = len(eng.cache.cmus)          # includes the default
        assert len(tracker._ghost_mark) <= live
        assert len(tracker._ema) <= live
        if gen % 2:                          # churn: drop an old CMU
            eng.cache.remove_cmu((f"ds{gen % 6}", f"g{gen}"))
    # marks for the CMUs dropped since the last round disappear with the
    # next summarize (prune happens inside the round, not at removal)
    tracker.summarize(eng, 0, 99.0)
    assert len(tracker._ghost_mark) == len(eng.cache.cmus)
    assert len(tracker._ema) == len(eng.cache.cmus)

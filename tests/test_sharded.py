"""Sharded-engine invariants (PR-2 tentpole).

Routing: same path → same shard, always, across facade instances (the hash
is process-stable).  Batching: ``read_batch`` splits a batch by shard but
returns outcomes in the original request order.  Allocation: the
cross-shard GlobalRebalancer conserves total capacity and every shard's
``sum(quota) == capacity`` invariant.  End-to-end: the paper-suite cluster
sim at ``n_shards=4`` stays within 2 % CHR of the unsharded engine
(bitwise equivalence at ``n_shards=1`` is pinned in test_equivalence.py).
"""
import pytest

from repro.core import (CacheConfig, GlobalRebalancer, IGTCache, Pattern,
                        ShardedIGTCache, bundle_engine, make_engine,
                        shard_index)
from repro.core.types import MB
from repro.sim import ClusterSim, make_paper_suite
from repro.storage import RemoteStore, make_dataset

CFG = CacheConfig(min_share=8 * MB, rebalance_quantum=8 * MB,
                  rebalance_period=5.0, node_cap=300, window=20,
                  reanalyze_every=10)


def mk_store(n_datasets=6):
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=4,
                               files_per_dir=8, small_file_size=512 * 1024))
    return store


# ------------------------------------------------------------------ routing

def test_same_path_same_shard_always():
    store = mk_store()
    a = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    b = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    for ds in store.datasets.values():
        for f in ds.files:
            sid = a.shard_id(f.path)
            # stable across repeated calls, facade instances, and the free
            # function; block paths route with their file
            assert a.shard_id(f.path) == sid
            assert b.shard_id(f.path) == sid
            assert shard_index(f.path, 4) == sid
            assert a.shard_id(f.path + ("#0",)) == sid


def test_routing_hashes_once_per_dataset(monkeypatch):
    """Memoized routing (ISSUE 5 satellite): the CRC-32 runs once per
    top-level component, not once per access — every later access is a
    dict lookup on both drivers (ShardRouting mixin)."""
    import repro.core.sharded as sh
    calls = []
    real = sh.zlib.crc32
    monkeypatch.setattr(sh.zlib, "crc32",
                        lambda data: calls.append(data) or real(data))
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    t = 0.0
    for _ in range(3):
        for ds in store.datasets.values():
            for f in ds.files[:8]:
                eng.read(f.path, 0, f.size, t)
                t += 0.01
    assert len(calls) <= len(store.datasets), \
        f"CRC-32 ran {len(calls)}× for {len(store.datasets)} datasets"


def test_routing_only_uses_top_level_component():
    """A dataset never straddles shards: every stream (directory, file,
    block level) observes exactly its unsharded access sequence."""
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    for ds in store.datasets.values():
        sids = {eng.shard_id(f.path) for f in ds.files}
        assert len(sids) == 1


def test_reads_land_on_routed_shard():
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    f = store.datasets["ds0"].files[0]
    eng.read(f.path, 0, f.size, 0.0)
    sid = eng.shard_id(f.path)
    for i, shard in enumerate(eng.shards):
        expect = 1 if i == sid else 0
        assert shard.stats.accesses == expect


# ----------------------------------------------------------------- batching

def test_read_batch_preserves_request_order():
    store = mk_store()
    mono = IGTCache(store, 64 * MB, cfg=CFG)
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    # interleave datasets so consecutive requests hit different shards
    files = []
    dss = list(store.datasets.values())
    for i in range(8):
        for ds in dss:
            files.append(ds.files[i])
    reqs = [(f.path, 0, f.size) for f in files]
    t = 0.0
    for _ in range(3):
        outs = eng.read_batch(reqs, t)
        ref = mono.read_batch(reqs, t)
        assert len(outs) == len(reqs)
        for (fp, off, sz), out, r in zip(reqs, outs, ref):
            # outcome i describes request i: same block keys as unsharded
            assert [b.key for b in out.blocks] == [b.key for b in r.blocks]
        for o in outs:
            for p, s in o.prefetches:
                eng.complete_prefetch(p, s, t)
        for o in ref:
            for p, s in o.prefetches:
                mono.complete_prefetch(p, s, t)
        t += 0.5


# --------------------------------------------------------------- allocation

def _drive(eng, store, reps=40, t0=0.0, dt=0.05):
    """Skewed traffic on ds0, sequential scan on ds1 — promotes CMUs with
    opposite marginal benefit."""
    t = t0
    hot = store.datasets["ds0"].files[:3]
    for r in range(reps):
        for f in hot:                      # revisit a hot set (skew)
            out = eng.read(f.path, 0, f.size, t)
            t += dt
        f = store.datasets["ds1"].files[r % 32]
        eng.read(f.path, 0, f.size, t)     # one sequential step
        t += dt
    return t


def test_cross_shard_rebalance_conserves_capacity():
    store = mk_store()
    cap = 64 * MB
    eng = ShardedIGTCache(store, cap, cfg=CFG, n_shards=4)
    assert sum(eng.shard_capacities()) == cap
    t = _drive(eng, store)
    for k in range(1, 30):
        eng.tick(t + k * CFG.rebalance_period)
        assert sum(eng.shard_capacities()) == cap
        for s in eng.shards:
            assert s.cache.quota_invariant_ok()
            assert sum(c.quota for c in s.cache.cmus.values()) \
                == s.cache.capacity


def test_global_rebalancer_moves_toward_demand():
    """A skewed CMU with ghost-window demand pulls capacity from another
    shard's idle default pool."""
    store = mk_store()
    s0 = IGTCache(store, 32 * MB, cfg=CFG)
    s1 = IGTCache(store, 32 * MB, cfg=CFG)
    cmu = s0.cache.create_cmu(("ds0",), 128 * MB, now=0.0)
    cmu.flat_pattern = Pattern.SKEWED
    for i in range(50):                      # arrival rate + ghost hits
        cmu.note_access(i * 0.01)
        cmu.buffer_window.on_evict(f"k{i}")
        cmu.buffer_window.probe(f"k{i}")
    reb = GlobalRebalancer(CFG)
    before = (s0.cache.capacity, s1.cache.capacity)
    moves = reb.rebalance_shards([s0, s1], now=CFG.rebalance_period + 1.0)
    assert moves, "expected at least one cross-shard move"
    assert s0.cache.capacity > before[0]
    assert s1.cache.capacity < before[1]
    assert s0.cache.capacity + s1.cache.capacity == sum(before)
    for s in (s0, s1):
        assert sum(c.quota for c in s.cache.cmus.values()) \
            == s.cache.capacity


def test_global_estimate_survives_local_window_reset():
    """Shard-local rounds reset the per-round ghost counters on their own
    read-triggered phase; the global layer must still see a skewed CMU's
    demand (it measures cumulative-counter deltas over its own interval)."""
    store = mk_store()
    s0 = IGTCache(store, 32 * MB, cfg=CFG)
    s1 = IGTCache(store, 32 * MB, cfg=CFG)
    cmu = s0.cache.create_cmu(("ds0",), 128 * MB, now=0.0)
    cmu.flat_pattern = Pattern.SKEWED
    for i in range(50):
        cmu.note_access(i * 0.01)
        cmu.buffer_window.on_evict(f"k{i}")
        cmu.buffer_window.probe(f"k{i}")
    # a local round fired a moment ago and zeroed the per-round window
    cmu.buffer_window.reset_window()
    assert cmu.buffer_window.hit_frequency() == 0.0
    reb = GlobalRebalancer(CFG)
    moves = reb.rebalance_shards([s0, s1], now=CFG.rebalance_period + 1.0)
    assert moves, "reset phase must not hide cross-shard demand"
    # next interval starts at the marks: no new ghost traffic -> no demand
    moves2 = reb.rebalance_shards([s0, s1],
                                  now=2 * CFG.rebalance_period + 2.0)
    assert not moves2


def test_single_shard_never_globally_rebalances():
    store = mk_store()
    eng = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=1)
    t = _drive(eng, store)
    eng.tick(t + CFG.rebalance_period + 1.0)
    assert eng.shard_capacities() == [64 * MB]


# ------------------------------------------------------------- constructors

def test_make_engine_dispatch():
    store = mk_store()
    assert isinstance(make_engine(store, 64 * MB, cfg=CFG), IGTCache)
    eng = make_engine(store, 64 * MB, cfg=CFG, n_shards=4)
    assert isinstance(eng, ShardedIGTCache)
    assert eng.n_shards == 4
    jfs = bundle_engine("juicefs", store, 64 * MB, cfg=CFG, n_shards=2)
    assert isinstance(jfs, ShardedIGTCache)
    assert jfs.options.name == "juicefs"
    with pytest.raises(ValueError):
        ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=0)


# ------------------------------------------------------- end-to-end cluster

def test_sharded_cluster_sim_hit_ratio_within_2pct():
    """Paper-suite cluster sim (scaled): n_shards=4 CHR within 2 % of the
    unsharded engine — capacity partitioning plus the global rebalancer
    must not cost recognition quality (routing keeps datasets whole)."""
    def scaled_cfg(capacity):
        share = max(16 * MB, capacity // 128)
        return CacheConfig(min_share=share, rebalance_quantum=share,
                           rebalance_period=10.0,
                           prefetch_budget_bytes=max(64 * MB, capacity // 8))

    suite = make_paper_suite(scale=0.15, seed=0,
                             job_filter=[2, 8, 9, 14, 16])
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())
    mono = ClusterSim(suite, IGTCache(store, cap, cfg=scaled_cfg(cap))).run()
    eng = ShardedIGTCache(store, cap, cfg=scaled_cfg(cap), n_shards=4)
    shard = ClusterSim(suite, eng).run()
    assert sum(eng.shard_capacities()) == cap
    assert abs(mono.hit_ratio - shard.hit_ratio) <= 0.02, \
        f"CHR drift: unsharded={mono.hit_ratio:.4f} " \
        f"sharded4={shard.hit_ratio:.4f}"

"""AccessStreamTree: structure, compression, pruning, node cap."""
import random

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.access_stream_tree import AccessStreamTree
from repro.core.types import CacheConfig, Pattern


def levels_for(ds, dirname, fname, nfiles_per_dir, ndirs, blocks=1, blk=0):
    return [(ds, 0, 5), (dirname, int(dirname), ndirs),
            (fname, int(fname), nfiles_per_dir), (f"#{blk}", blk, blocks)]


def test_observe_creates_informative_nodes_only():
    t = AccessStreamTree(CacheConfig(window=10))
    # flat small-file dataset: file level informative, block level trivial
    for i in range(30):
        t.observe([("ds", 0, 3), ("files", 0, 1), (f"f{i}", i, 100),
                   ("#0", 0, 1)], time=float(i))
    files_node = t.find(("ds", "files"))
    assert files_node is not None
    assert files_node.accesses == 30
    # no node materialized below the file level (1-block files)
    assert not files_node.children or all(
        not c.children for c in files_node.children.values())
    # single-entry "files" level recorded nothing at the ds node
    ds_node = t.find(("ds",))
    assert ds_node.accesses == 0


def test_child_pruning_bounds_children():
    cfg = CacheConfig(window=16)
    t = AccessStreamTree(cfg)
    for i in range(200):
        t.observe([("ds", 0, 2), (f"f{i}", i, 500), ("#0", 0, 4)],
                  time=float(i))
    node = t.find(("ds",))
    assert len(node.children) <= cfg.window


def test_node_cap():
    cfg = CacheConfig(window=8, node_cap=50)
    t = AccessStreamTree(cfg)
    for i in range(1000):
        t.observe([(f"d{i % 100}", i % 100, 100), (f"f{i}", i % 37, 37),
                   ("#0", 0, 2)], time=float(i))
    assert t.node_count() <= cfg.node_cap


def test_pattern_at_dir_level():
    cfg = CacheConfig(window=50)
    t = AccessStreamTree(cfg)
    # sequential dir traversal, one file per dir (ICOADS shape)
    for d in range(100):
        t.observe([("ds", 0, 2), (f"{d:04d}", d, 200), ("03.csv", 3, 10),
                   ("#0", 0, 1)], time=float(d))
    ds = t.find(("ds",))
    assert ds.pattern.pattern is Pattern.SEQUENTIAL
    anchor = t.shallowest_non_trivial(("ds", "0050", "03.csv"))
    assert anchor is ds


@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 20)),
                min_size=1, max_size=300))
@settings(max_examples=30, deadline=None)
def test_tree_invariants_random_traffic(accesses):
    cfg = CacheConfig(window=10, node_cap=40)
    t = AccessStreamTree(cfg)
    for i, (d, f) in enumerate(accesses):
        t.observe([("ds", 0, 1), (f"d{d}", d, 31), (f"f{f}", f, 21),
                   ("#0", 0, 1)], time=float(i))
    assert t.node_count() <= cfg.node_cap
    # every registered node reachable from root
    for node in t.iter_nodes():
        cur = t.root
        ok = True
        for comp in node.path:
            cur = cur.children.get(comp)
            if cur is None:
                ok = False
                break
        assert ok, f"unreachable node {node.path}"

"""Optimizer / checkpoint / fault-tolerance behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.mesh import make_local_mesh
from repro.models.config import ShapeSpec
from repro.models.transformer import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (FailureInjector, Heartbeat, StragglerDetector,
                               reassign_shards)
from repro.train.optimizer import (AdamWConfig, apply_updates, global_norm,
                                   init_state, schedule)
from repro.train.train_step import make_train_step

from conftest import requires_mesh_axis_types


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}            # d/dw (w^2)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_state(params)
    big = {"w": jnp.full(4, 1e6)}
    _, _, metrics = apply_updates(params, big, state, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


@requires_mesh_axis_types
def test_train_step_reduces_loss_tiny_model():
    cfg = reduced_config("qwen3-1.7b")
    mesh = make_local_mesh()
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-2, warmup_steps=2,
                                                    total_steps=50),
                                   mesh, None, remat="none"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_state(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5    # memorizes the fixed batch


def test_checkpoint_roundtrip_and_resume(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(1, tree, {"step": 1})
    tree2 = jax.tree.map(lambda x: x * 2, tree)
    mgr.save_async(2, tree2, {"step": 2})
    mgr.wait()
    assert mgr.latest_step() == 2
    restored, extra = mgr.restore(tree)
    assert extra["step"] == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree2["a"]))
    # keep=2 gc
    mgr.save(3, tree, {"step": 3})
    mgr.save(4, tree, {"step": 4})
    steps = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert len(steps) == 2


@requires_mesh_axis_types
def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore applies target shardings (elastic: mesh may differ)."""
    mesh = make_local_mesh()
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    tree = {"w": jnp.ones((8, 8))}
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, tree)
    restored, _ = mgr.restore(tree, shardings={"w": sh})
    assert restored["w"].sharding == sh


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.ones(2), "b": jnp.ones(2)})


def test_heartbeat_and_straggler():
    hb = Heartbeat(deadline_s=10.0)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(0, now=8.0)
    assert hb.dead_workers(now=12.0) == [1]

    sd = StragglerDetector(factor=1.5)
    for _ in range(10):
        sd.record(0, 1.0)
        sd.record(1, 1.0)
        sd.record(2, 4.0)
    assert sd.stragglers() == [2]


def test_reassign_shards_stable():
    a = reassign_shards(16, {0, 1, 2, 3})
    b = reassign_shards(16, {0, 1, 3})       # worker 2 died
    assert sum(len(v) for v in b.values()) == 16
    # shards previously on surviving workers move deterministically
    assert set(b) == {0, 1, 3}


def test_failure_injector_restart_from_checkpoint(tmp_path):
    """Crash at step 7 → restart resumes from the last checkpoint (step 5)."""
    mgr = CheckpointManager(tmp_path)
    inj = FailureInjector(crash_at={7: [0]})
    state = {"step": jnp.asarray(0)}
    step = 0
    restarts = 0
    while step < 10:
        if inj.crashed(step) and restarts == 0:
            restarts += 1
            restored, extra = mgr.restore(state)
            step = extra["step"]
            state = restored
            continue
        state = {"step": jnp.asarray(step + 1)}
        if (step + 1) % 5 == 0:
            mgr.save(step + 1, state, {"step": step + 1})
        step += 1
    assert restarts == 1
    assert int(state["step"]) == 10

"""Cache daemon invariants (the PR 8 cache-as-a-service tentpole).

Coverage the ISSUE pins: the shared reply codec round-trips bitwise
(seeded property test), ``open_cache("cache://...")`` satisfies the
client contract (outcomes + bytes equivalent to a direct ``open_cache``
over the same store/trace), two clients racing the same dataset keep
identity-hit accounting exact, and the fault-of-the-client arc leaks
nothing: a client that dies mid-read — silently (lease expiry) or with
an EOF (disconnect mid-``read_batch``) — gets its arena slots freed,
its prefetch-candidate window cancelled, and the executor conservation
identity ``submitted == completed + cancelled + deduped`` holds,
under both the in-process ThreadedExecutor engine and the supervised
multi-process driver.  The chaos harness drives the same arc from a
``ClusterSim`` trace via the new ``client_kill`` strike.

Every test runs under a hard SIGALRM guard: a deadlocked serve thread
or a lost reply must fail the test, not hang tier-1.
"""
import pickle
import random
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import CacheConfig, MB, block_key, open_cache, path_key
from repro.core.igtcache import BlockResult, ReadOutcome
from repro.core.wire import WireOutcome, encode_outcome
from repro.daemon import CacheDaemon, RemoteCacheClient
from repro.daemon.wire import PROTO_VERSION, recv_msg, send_msg
from repro.sim.chaos import ChaosMonkey, plan_strikes
from repro.sim.cluster import ClusterSim
from repro.sim.workloads import make_paper_suite
from repro.storage import RemoteStore, make_dataset

pytestmark = pytest.mark.daemon

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  window=40, reanalyze_every=20, node_cap=500)

HARD_TIMEOUT_S = 180


@pytest.fixture(autouse=True)
def hard_timeout():
    """Socket/lease tests must never hang tier-1."""

    def boom(signum, frame):  # pragma: no cover - only fires on deadlock
        raise TimeoutError(
            f"daemon test exceeded the {HARD_TIMEOUT_S}s hard timeout "
            f"(stuck serve thread / lost reply?)")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def mk_store(n_datasets=2):
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=2,
                               files_per_dir=6, small_file_size=256 * 1024))
    return store


def all_files(store):
    return [f for ds in store.datasets.values() for f in ds.files]


def executor_identity(st):
    return st.completed + st.cancelled + st.deduped


def wait_until(cond, deadline_s=15.0, tick=0.02, what="condition"):
    end = time.monotonic() + deadline_s
    while time.monotonic() < end:
        if cond():
            return
        time.sleep(tick)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# shared codec: seeded round-trip property test
# ---------------------------------------------------------------------------

def test_wire_codec_roundtrip_seeded():
    """encode → pickle → decode must reproduce the ``ReadOutcome``
    bitwise: every block (key, size, hit, prefetched_hit), the byte
    tallies, and the candidate list.  Re-encoding a ``WireOutcome`` is
    the identity (the daemon proxies driver outcomes for free)."""
    rng = random.Random(1234)
    for case in range(200):
        fp = (f"ds{rng.randrange(3)}", f"dir{rng.randrange(4)}",
              f"f{rng.randrange(50)}")
        first = rng.randrange(0, 100)
        n_blocks = rng.randrange(1, 12)
        blocks, prefetches = [], []
        for i in range(n_blocks):
            hit = rng.random() < 0.5
            pf = hit and rng.random() < 0.5
            blocks.append(BlockResult(path_key(block_key(fp, first + i)),
                                      rng.randrange(1, 4 * MB), hit, pf))
        for _ in range(rng.randrange(0, 4)):
            prefetches.append(((fp[0], fp[1], f"p{rng.randrange(9)}",
                                f"#{rng.randrange(8)}"),
                               rng.randrange(1, MB)))
        out = ReadOutcome(blocks, prefetches)
        enc = pickle.loads(pickle.dumps(encode_outcome(out, first)))
        wo = WireOutcome(enc, fp)
        assert [(b.key, b.size, b.hit, b.prefetched_hit)
                for b in wo.blocks] == \
               [(b.key, b.size, b.hit, b.prefetched_hit)
                for b in out.blocks], f"case {case}"
        assert wo.remote_bytes == out.remote_bytes
        assert wo.cached_bytes == out.cached_bytes
        assert wo.prefetches == out.prefetches
        # re-encode of an already-wire outcome: the identity, not a copy
        assert encode_outcome(wo, first) is enc


# ---------------------------------------------------------------------------
# client contract: cache:// equals a direct open_cache
# ---------------------------------------------------------------------------

def test_remote_client_matches_direct_open_cache():
    """The acceptance contract: a seeded mixed trace through
    ``open_cache("cache://...")`` produces per-block outcomes and
    payload bytes identical to a direct ``open_cache`` on the same
    store — the daemon adds transport, never semantics."""
    store = mk_store()
    direct = open_cache(store, 48 * MB, cfg=CFG, executor="sim",
                        fetch_bytes=True)
    files = all_files(store)
    rng = np.random.default_rng(11)
    with CacheDaemon(store, 48 * MB, cfg=CFG, executor="sim") as d, \
            open_cache(d.uri, fetch_bytes=True) as remote:
        t = 0.0
        for rep in range(4):
            picks = rng.integers(0, len(files), 24)
            reqs = []
            for j in picks:
                f = files[int(j)]
                off = int(rng.integers(0, 2)) * 128 * 1024
                reqs.append((f.path, off, f.size - off))
            got = remote.read_batch(reqs, t)
            want = direct.read_batch(reqs, t)
            for g, w in zip(got, want):
                assert [(b.key, b.size, b.hit, b.prefetched_hit)
                        for b in g.blocks] == \
                       [(b.key, b.size, b.hit, b.prefetched_hit)
                        for b in w.blocks]
                assert g.remote_bytes == w.remote_bytes
                assert g.cached_bytes == w.cached_bytes
                assert g.data is not None and w.data is not None
                assert g.data.tobytes() == w.data.tobytes()
            t += 0.5
        assert remote.stats.snapshot() == direct.stats.snapshot()
        assert remote.hit_ratio() == direct.hit_ratio()
    direct.close()


def test_uri_query_params_and_capacity_guard(tmp_path):
    store = mk_store(1)
    with CacheDaemon(store, 16 * MB, cfg=CFG,
                     uds=str(tmp_path / "d.sock")) as d:
        # query params ride the URI into the client constructor
        c = open_cache(d.uri + "?fetch_bytes=true&label=trainer0")
        assert c.fetch_bytes is True
        f = all_files(store)[0]
        r = c.read(f.path, 0, f.size, now=1.0)
        assert r.data is not None and r.data.size == f.size
        c.close()
        # the daemon owns capacity: passing one is a loud error
        with pytest.raises(ValueError, match="owned by the daemon"):
            open_cache(d.uri, 64 * MB)
    # non-cache stores still require capacity
    with pytest.raises(TypeError, match="capacity"):
        open_cache("sim://default")


# ---------------------------------------------------------------------------
# two clients, one cache
# ---------------------------------------------------------------------------

def test_second_client_reads_hit_warm_cache():
    store = mk_store(1)
    files = all_files(store)[:6]
    with CacheDaemon(store, 32 * MB, cfg=CFG) as d:
        with open_cache(d.uri, fetch_bytes=True) as a:
            for f in files:
                r = a.read(f.path, 0, f.size, now=1.0)
                assert r.data.size == f.size
        with open_cache(d.uri, fetch_bytes=True) as b:
            # remote StoreMeta: sizes answered daemon-side
            assert b.meta.file_size(files[0].path) == files[0].size
            assert b.meta.subtree_bytes(()) == \
                sum(f.size for f in all_files(store))
            total = hits = 0
            for f in files:
                r = b.read(f.path, 0, f.size, now=2.0)
                assert r.data.size == f.size
                total += len(r.blocks)
                hits += sum(1 for blk in r.blocks if blk.hit)
            # client A warmed the unified cache; B rides it
            assert hits == total


def test_two_clients_racing_same_dataset_identity_hits():
    """Concurrent sessions hammering the same files through separate
    serve threads: every served block must land in exactly one of
    hits/misses (identity-hit correctness under the kernel guard), and
    both clients must get the right bytes."""
    store = mk_store(1)
    files = all_files(store)[:8]
    with CacheDaemon(store, 64 * MB, cfg=CFG) as d:
        results = {}
        errors = []

        def hammer(name, seed):
            try:
                with open_cache(d.uri, fetch_bytes=True) as c:
                    rng = np.random.default_rng(seed)
                    blocks = 0
                    payload_ok = True
                    for rep in range(6):
                        reqs = [(files[int(j)].path, 0, files[int(j)].size)
                                for j in rng.integers(0, len(files), 8)]
                        for (fp, off, sz), r in zip(reqs,
                                                    c.read_batch(reqs)):
                            blocks += len(r.blocks)
                            if r.data.size != sz:
                                payload_ok = False
                    results[name] = (blocks, payload_ok)
            except BaseException as e:  # pragma: no cover - surfaced below
                errors.append(e)

        ts = [threading.Thread(target=hammer, args=(n, s))
              for n, s in (("a", 1), ("b", 2))]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors, errors
        total_blocks = sum(b for b, _ in results.values())
        assert all(ok for _, ok in results.values())
        st = d.client.stats
        assert st.hits + st.misses == total_blocks
        # one byte check against the store's own synthesis
        f = files[0]
        with open_cache(d.uri, fetch_bytes=True) as c:
            got = c.read(f.path, 0, f.size).data.tobytes()
        want = np.asarray(store.fetch_range(f.path, 0, f.size),
                          dtype=np.uint8).tobytes()
        assert got == want


# ---------------------------------------------------------------------------
# fault of the client: leases, reclamation, conservation
# ---------------------------------------------------------------------------

def _read_some(client, files, now=None):
    reqs = [(f.path, 0, f.size) for f in files]
    return client.read_batch(reqs, now, fetch=True)


def _assert_reclaimed_to_baseline(daemon, *, reaped=None, disconnects=None):
    wait_until(lambda: daemon.daemon_stats()["sessions"] == 0,
               what="session reclaim")
    st = daemon.daemon_stats()
    assert st["arena_free"] == st["arena_total"], st
    assert st["live_slots"] == 0
    if reaped is not None:
        assert st["reaped"] == reaped
    if disconnects is not None:
        assert st["disconnects"] >= disconnects
    # kernel pending-prefetch tables drain once the executor settles
    assert daemon.client.flush(timeout=15.0)
    wait_until(lambda: daemon.daemon_stats()["pending_prefetch"] == 0,
               what="pending-prefetch drain")
    ex = daemon.client.executor.stats
    assert ex.submitted == executor_identity(ex)


def test_client_kill_lease_reclaim_threaded():
    """Silent death under the in-process engine + ThreadedExecutor: the
    socket stays open (no EOF), so only the lease can notice.  After it
    expires the daemon's arena, candidate window, pending tables, and
    executor identity are all back to baseline."""
    store = mk_store()
    with CacheDaemon(store, 48 * MB, cfg=CFG, lease_s=0.3,
                     executor="threaded") as d:
        base = d.daemon_stats()
        assert base["arena_free"] == base["arena_total"]
        victim = RemoteCacheClient(d.uri, fetch_bytes=True, heartbeat=False)
        _read_some(victim, all_files(store)[:10])
        mid = d.daemon_stats()
        assert mid["live_slots"] > 0          # un-freed slots in flight
        assert mid["arena_free"] < mid["arena_total"]
        victim.kill()                          # goes silent mid-session
        _assert_reclaimed_to_baseline(d, reaped=1)
        # daemon still serves new sessions after the reclaim
        with open_cache(d.uri, fetch_bytes=True) as fresh:
            f = all_files(store)[0]
            assert fresh.read(f.path, 0, f.size).data.size == f.size


def test_client_kill_lease_reclaim_process_driver():
    """Same arc with the supervised multi-process driver behind the
    daemon: payload bytes cross worker arena → daemon arena → client,
    and the ProcessExecutor's conservation identity must survive the
    dead session."""
    store = mk_store()
    with CacheDaemon(store, 48 * MB, cfg=CFG, lease_s=0.3,
                     driver="process", n_procs=2, arena_bytes=8 * MB,
                     rpc_timeout_s=15.0) as d:
        victim = RemoteCacheClient(d.uri, fetch_bytes=True, heartbeat=False)
        outs = _read_some(victim, all_files(store)[:10])
        assert all(r.data is not None for r in outs)
        victim.kill()
        _assert_reclaimed_to_baseline(d, reaped=1)
        assert all(s == "up" for s in d.client.shard_states())


def test_disconnect_mid_read_batch_leaks_nothing():
    """The EOF path: a raw client sends a fetching ``read_batch`` and
    closes the socket without ever reading the reply.  The daemon must
    absorb the broken pipe, reclaim the session immediately, and keep
    serving others."""
    store = mk_store(1)
    files = all_files(store)[:6]
    with CacheDaemon(store, 32 * MB, cfg=CFG) as d:
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.connect(d.address.path)
        send_msg(sock, ("hello", (), {"proto": PROTO_VERSION, "shm": True}))
        status, info = recv_msg(sock)
        assert status == "ok"
        reqs = [(f.path, 0, f.size) for f in files]
        send_msg(sock, ("read_batch", (), (reqs, 1.0, True)))
        sock.close()                           # die before the reply
        wait_until(lambda: d.daemon_stats()["disconnects"] >= 1,
                   what="EOF reclaim")
        _assert_reclaimed_to_baseline(d, disconnects=1)
        with open_cache(d.uri, fetch_bytes=True) as c:
            r = c.read(files[0].path, 0, files[0].size)
            assert r.data.size == files[0].size


def test_graceful_close_releases_session_immediately():
    store = mk_store(1)
    with CacheDaemon(store, 16 * MB, cfg=CFG, lease_s=30.0) as d:
        c = open_cache(d.uri, fetch_bytes=True)
        _read_some(c, all_files(store)[:4])
        c.close()                              # bye: no lease wait
        wait_until(lambda: d.daemon_stats()["sessions"] == 0,
                   deadline_s=5.0, what="bye reclaim")
        st = d.daemon_stats()
        assert st["byes"] == 1 and st["reaped"] == 0
        assert st["arena_free"] == st["arena_total"]


def test_lease_expiry_races_reconnect_no_double_free():
    """PR 10 satellite: the old session's lease expires *while the same
    client is already back on a new session*.  A client that loses its
    connection (here: forced down with the old socket held open by a
    dup'd fd, so no EOF ever reaches the daemon) reconnects and keeps
    reading; the abandoned session still owns arena slots until its
    lease runs out.  The reaper must reclaim exactly the old session's
    slots — never the new session's — and the arena must balance to
    baseline afterwards (a double-free or cross-session free would
    corrupt the allocator's accounting)."""
    store = mk_store(1)
    files = all_files(store)[:6]
    with CacheDaemon(store, 32 * MB, cfg=CFG, lease_s=0.6) as d:
        cli = RemoteCacheClient(d.uri, fetch_bytes=True, heartbeat=False,
                                max_backoff_s=0.1, backing=store)
        _read_some(cli, files)                 # old session holds slots
        assert d.daemon_stats()["live_slots"] > 0
        zombie = cli._sock.dup()               # keep the daemon's side open
        cli._mark_down("drill: connection lost")
        wait_until(lambda: cli.state == "up", what="reconnect")
        assert cli.reconnects == 1
        assert d.daemon_stats()["sessions"] == 2   # zombie + successor
        # the new session reads while the old lease runs down
        outs = _read_some(cli, files, now=10.0)
        assert all(r.data is not None for r in outs)
        wait_until(lambda: d.daemon_stats()["reaped"] == 1,
                   what="old-session lease reclaim")
        st = d.daemon_stats()
        assert st["sessions"] == 1             # successor untouched
        # reclaim took only the old session's slots; the new session
        # still serves, and its in-flight slots still account cleanly
        outs = _read_some(cli, files, now=20.0)
        assert all(r.data is not None for r in outs)
        zombie.close()
        cli.close()
        _assert_reclaimed_to_baseline(d, reaped=1)


# ---------------------------------------------------------------------------
# chaos harness: the client_kill strike
# ---------------------------------------------------------------------------

def test_plan_strikes_client_kill_deterministic():
    a = plan_strikes(60, n_shards=4, seed=3, n_strikes=6,
                     kinds=("kill", "client_kill"), n_clients=3)
    b = plan_strikes(60, n_shards=4, seed=3, n_strikes=6,
                     kinds=("kill", "client_kill"), n_clients=3)
    assert a == b
    kinds = {s.kind for s in a}
    assert kinds <= {"kill", "client_kill"}
    for s in a:
        if s.kind == "client_kill":
            assert 0 <= s.sid < 3
    with pytest.raises(ValueError, match="n_clients"):
        plan_strikes(60, n_shards=4, kinds=("client_kill",))


def test_chaos_monkey_client_kill_needs_victims():
    with pytest.raises(TypeError):
        ChaosMonkey(None)                      # nothing at all to strike
    store = mk_store(1)
    with CacheDaemon(store, 16 * MB, cfg=CFG, lease_s=0.3) as d:
        victim = RemoteCacheClient(d.uri, heartbeat=False)
        monkey = ChaosMonkey(None, clients=[victim])
        with pytest.raises(RuntimeError, match="process driver"):
            monkey.kill(0)                     # worker strikes untargeted
        monkey.strike("client_kill", 0)
        assert monkey.strikes[-1]["kind"] == "client_kill"
        wait_until(lambda: d.daemon_stats()["reaped"] == 1,
                   what="monkey-killed client reaped")


def test_cluster_sim_client_kill_strike_mid_trace():
    """The satellite drill: a ``ClusterSim`` trace runs against the
    daemon's own cache while a remote daemon client holds live arena
    slots; a virtual-time ``client_kill`` strike fells it mid-trace and
    the daemon's arena free-bytes and pending-prefetch tables return to
    baseline once the lease expires."""
    suite = make_paper_suite(scale=0.05, seed=0, job_filter=[2, 9])
    store = mk_store(1)
    for ds in suite.datasets.values():
        store.add(ds)
    cap = max(int(0.4 * suite.total_bytes()), 16 * MB)
    with CacheDaemon(store, cap, cfg=CFG, lease_s=0.3) as d:
        baseline = d.daemon_stats()["arena_total"]
        victim = RemoteCacheClient(d.uri, fetch_bytes=True, heartbeat=False)
        _read_some(victim, all_files(store)[:8], now=0.0)
        assert d.daemon_stats()["live_slots"] > 0
        sim = ClusterSim(suite, d.client,
                         chaos_events=[(1.0, "client_kill", 0)],
                         chaos_clients=[victim])
        res = sim.run()
        assert res.jct, "sim completed no jobs"
        assert [e["kind"] for e in res.chaos_log] == ["client_kill"]
        wait_until(lambda: d.daemon_stats()["sessions"] == 0,
                   what="lease reclaim after sim strike")
        st = d.daemon_stats()
        assert st["arena_free"] == baseline
        assert st["reaped"] == 1
        wait_until(lambda: d.daemon_stats()["pending_prefetch"] == 0,
                   what="pending-prefetch baseline")


# ---------------------------------------------------------------------------
# soak (opt-in): many clients, repeated kills
# ---------------------------------------------------------------------------

@pytest.mark.daemon_full
def test_daemon_full_multi_client_soak():
    """Four concurrent sessions, two of them killed mid-run, over a
    longer trace: the daemon ends with zero sessions, a full arena free
    list, drained pending tables, and the conservation identity."""
    store = mk_store(3)
    files = all_files(store)
    with CacheDaemon(store, 96 * MB, cfg=CFG, lease_s=0.4,
                     executor="threaded") as d:
        errors = []
        zombies = []      # keep killed clients alive: GC would close the
                          # zombie socket and turn the reap into an EOF

        def worker(seed, die):
            try:
                c = RemoteCacheClient(d.uri, fetch_bytes=True,
                                      heartbeat=not die)
                if die:
                    zombies.append(c)
                rng = np.random.default_rng(seed)
                for rep in range(30):
                    reqs = [(files[int(j)].path, 0, files[int(j)].size)
                            for j in rng.integers(0, len(files), 6)]
                    c.read_batch(reqs)
                    if die and rep == 15:
                        c.kill()
                        return
                c.close()
            except BaseException as e:  # pragma: no cover
                errors.append(e)

        ts = [threading.Thread(target=worker, args=(s, s % 2 == 0))
              for s in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors, errors
        _assert_reclaimed_to_baseline(d, reaped=2)

"""Marginal-benefit allocation: B formulas, ghost cache, rebalance moves."""
import pytest

from repro.core.allocation import (BufferWindow, FluidAllocator,
                                   QuiverAllocator, Rebalancer,
                                   marginal_benefit)
from repro.core.cache import CacheManageUnit, UnifiedCache
from repro.core.types import CacheConfig, Pattern

MB = 1 << 20
CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  rebalance_period=1.0, block_size=MB)


def mk_cmu(cache, root, pattern, dataset=64 * MB, rate_hz=100.0, n=200,
           ghost_hits=0):
    cmu = cache.create_cmu(root, dataset_bytes=dataset, now=0.0)
    sub = cmu.substream(root, pattern)
    for i in range(n):
        cmu.note_access(i / rate_hz, MB)
    if pattern is Pattern.SKEWED:
        for i in range(ghost_hits):
            cmu.buffer_window.on_evict(f"g{i}")
        for i in range(ghost_hits):
            cmu.buffer_window.probe(f"g{i}")      # hits
        for i in range(ghost_hits):
            cmu.buffer_window.probe(f"m{i}")      # misses
    return cmu


def test_benefit_sequential_zero():
    c = UnifiedCache(256 * MB, CFG)
    cmu = mk_cmu(c, ("s",), Pattern.SEQUENTIAL)
    est = marginal_benefit(cmu, now=2.0, cfg=CFG)
    assert est.benefit == 0.0
    assert not est.wants_more


def test_benefit_random_inverse_epoch():
    c = UnifiedCache(256 * MB, CFG)
    cmu = mk_cmu(c, ("r",), Pattern.RANDOM, dataset=512 * MB, rate_hz=100.0)
    est = marginal_benefit(cmu, now=2.0, cfg=CFG)
    # B = rate / n_units = 100 / 512  (1MB mean access size)
    assert est.benefit == pytest.approx(100 / 512, rel=0.15)
    assert est.wants_more                        # quota < dataset


def test_benefit_random_decays_when_idle():
    c = UnifiedCache(256 * MB, CFG)
    cmu = mk_cmu(c, ("r",), Pattern.RANDOM)
    b_live = marginal_benefit(cmu, now=2.0, cfg=CFG).benefit
    b_idle = marginal_benefit(cmu, now=500.0, cfg=CFG).benefit
    assert b_idle < 0.05 * b_live


def test_benefit_skewed_ghost():
    c = UnifiedCache(256 * MB, CFG)
    cmu = mk_cmu(c, ("k",), Pattern.SKEWED, ghost_hits=50)
    est = marginal_benefit(cmu, now=2.0, cfg=CFG)
    # lam ~100/s, f=0.5, w=100 -> 0.5
    assert est.benefit == pytest.approx(100 * 0.5 / CFG.buffer_window,
                                        rel=0.2)
    assert est.wants_more


def test_rebalancer_moves_toward_benefit():
    c = UnifiedCache(256 * MB, CFG)
    seq = mk_cmu(c, ("s",), Pattern.SEQUENTIAL)
    rnd = mk_cmu(c, ("r",), Pattern.RANDOM, dataset=128 * MB)
    seq.set_quota(64 * MB)
    q_before = rnd.quota
    rb = Rebalancer(CFG)
    moves = rb.rebalance([seq, rnd], now=5.0)
    assert moves, "expected at least one move"
    assert all(d is seq and t is rnd for d, t, _ in moves)
    assert rnd.quota > q_before
    assert seq.quota >= CFG.min_share


def test_rebalancer_seed_for_newcomer():
    c = UnifiedCache(256 * MB, CFG)
    fat = mk_cmu(c, ("s",), Pattern.SEQUENTIAL)
    fat.set_quota(128 * MB)
    new = c.create_cmu(("n",), dataset_bytes=32 * MB, now=0.0)
    new.set_quota(0)
    Rebalancer(CFG).seed(new, [fat, new])
    assert new.quota >= CFG.min_share


def test_buffer_window_bounds():
    bw = BufferWindow(4)
    for i in range(10):
        bw.on_evict(f"k{i}")
    assert len(bw._ghost) == 4
    assert bw.probe("k9") and not bw.probe("k0")


def test_quiver_and_fluid_allocators():
    c = UnifiedCache(256 * MB, CFG)
    rnd = mk_cmu(c, ("r",), Pattern.RANDOM, dataset=128 * MB)
    skw = mk_cmu(c, ("k",), Pattern.SKEWED, ghost_hits=10)
    QuiverAllocator(CFG).rebalance([rnd, skw], now=1.0, capacity=128 * MB)
    assert rnd.quota >= CFG.min_share and skw.quota >= CFG.min_share
    FluidAllocator(CFG).rebalance([rnd, skw], now=2.0, capacity=128 * MB)
    assert rnd.quota >= CFG.min_share and skw.quota >= CFG.min_share

"""Batched-vs-serial engine equivalence + vectorized-vs-scalar analytics.

The PR-1 tentpole rebuilt the hot path (extent batching, chain replay,
ring-buffer windows, matrix classification).  These tests pin the contract:
the batched ``read()`` must reproduce the per-block reference path
``read_serial()`` decision for decision on seeded mixed workloads, and the
vectorized ``classify_batch`` must agree with the scalar ``classify``.
"""
import random

import numpy as np
import pytest

from repro.core import (CacheConfig, IGTCache, Pattern, ShardedIGTCache,
                        bundle, open_cache)
from repro.core.access_stream_tree import AccessStreamTree
from repro.core.pattern import (classify, classify_batch, fit_adaptive_ttl,
                                fit_adaptive_ttl_arr, fit_adaptive_ttl_batch)
from repro.core.types import AccessRecord, MB
from repro.storage import RemoteStore, make_dataset
from repro.sim.workloads import (random_files, seq_blocks, seq_files,
                                 zipf_files)

# small window/cap so non-trivial thresholds, reanalysis, child pruning and
# the node cap all trigger inside a short trace
CFG = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB,
                  rebalance_period=5.0, prefetch_budget_bytes=64 * MB,
                  node_cap=250, window=40, reanalyze_every=20)


def mk_store():
    store = RemoteStore()
    store.add(make_dataset("seqset", "flat_files", n_files=250,
                           small_file_size=256 * 1024))
    store.add(make_dataset("randset", "dir_tree", n_dirs=20, files_per_dir=15,
                           small_file_size=256 * 1024))
    store.add(make_dataset("bigfiles", "big_files", n_files=10,
                           file_size=24 * MB))
    return store


def mixed_trace(store, seed=0):
    """Seeded mixed workload: sequential, random-epoch, skewed and
    multi-block extent reads, interleaved (generators from sim/workloads)."""
    rng = random.Random(seed)
    reqs = []
    for _, batch in seq_files(store.datasets["seqset"], 1, 8, 0.0):
        reqs.extend(batch)
    for _, batch in seq_blocks(store.datasets["bigfiles"], 1, 8, 0.0,
                               file_limit=6):
        reqs.extend(batch)
    for _, batch in random_files(store.datasets["randset"], 3, 8, 0.0,
                                 seed + 1):
        reqs.extend(batch)
    for _, batch in zipf_files(store.datasets["randset"], 1200, 1.3, 8, 0.0,
                               seed + 2):
        reqs.extend(batch)
    # whole-file multi-block extents (4+ blocks per read())
    for f in store.datasets["bigfiles"].files[:4]:
        reqs.append((f.path, 0, f.size))
    rng.shuffle(reqs)
    return reqs


def outcome_tuple(out):
    return ([(b.key, b.size, b.hit, b.prefetched_hit) for b in out.blocks],
            list(out.prefetches))


@pytest.mark.parametrize("seed", [0, 7])
def test_batched_read_matches_serial_reference(seed):
    store = mk_store()
    batched = IGTCache(store, 192 * MB, cfg=CFG)
    serial = IGTCache(store, 192 * MB, cfg=CFG)
    t = 0.0
    for k, (fp, off, sz) in enumerate(mixed_trace(store, seed)):
        ob = batched.read(fp, off, sz, t)
        os_ = serial.read_serial(fp, off, sz, t)
        assert outcome_tuple(ob) == outcome_tuple(os_), \
            f"divergence at access {k}: {fp} off={off}"
        for p, s in ob.prefetches:
            batched.complete_prefetch(p, s, t)
        for p, s in os_.prefetches:
            serial.complete_prefetch(p, s, t)
        t += 0.011
    assert batched.snapshot() == serial.snapshot()
    assert batched.tree.node_count() == serial.tree.node_count()


def test_batched_read_matches_serial_for_baseline_bundle():
    """The non-adaptive baselines ride the same hot path — pin one too."""
    store = mk_store()
    opts = bundle("juicefs")
    batched = IGTCache(store, 128 * MB, cfg=CFG, options=opts)
    serial = IGTCache(store, 128 * MB, cfg=CFG, options=bundle("juicefs"))
    t = 0.0
    for fp, off, sz in mixed_trace(store, 3)[:1500]:
        ob = batched.read(fp, off, sz, t)
        os_ = serial.read_serial(fp, off, sz, t)
        assert outcome_tuple(ob) == outcome_tuple(os_)
        for p, s in ob.prefetches:
            batched.complete_prefetch(p, s, t)
        for p, s in os_.prefetches:
            serial.complete_prefetch(p, s, t)
        t += 0.013
    assert batched.snapshot() == serial.snapshot()


def test_read_batch_matches_reads_between_tick_boundaries():
    """read_batch defers the tick to the end of the batch (that is the
    amortization), so it matches per-request read() exactly as long as no
    maintenance cadence boundary (TTL sweep / allocation round) falls inside
    a batch — pin that contract on a trace inside one cadence window."""
    store = mk_store()
    a = IGTCache(store, 192 * MB, cfg=CFG)
    b = IGTCache(store, 192 * MB, cfg=CFG)
    reqs = mixed_trace(store, 5)[:900]
    t = 0.0
    for i in range(0, len(reqs), 6):
        group = reqs[i:i + 6]
        outs_a = a.read_batch(group, t)
        outs_b = [b.read(fp, off, sz, t) for fp, off, sz in group]
        assert [outcome_tuple(o) for o in outs_a] == \
            [outcome_tuple(o) for o in outs_b]
        for o in outs_a:
            for p, s in o.prefetches:
                a.complete_prefetch(p, s, t)
        for o in outs_b:
            for p, s in o.prefetches:
                b.complete_prefetch(p, s, t)
        t += 0.01        # stays below the 5 s sweep/rebalance cadence
    assert a.snapshot() == b.snapshot()


@pytest.mark.parametrize("seed", [0, 7])
def test_sharded_n1_bitwise_identical_to_engine(seed):
    """ShardedIGTCache(n_shards=1) IS the engine: identical ReadOutcomes,
    stats and tree state on the seeded mixed traces (the facade forwards
    everything to one full-capacity shard and its global layer stays
    inert)."""
    store = mk_store()
    mono = IGTCache(store, 192 * MB, cfg=CFG)
    facade = ShardedIGTCache(store, 192 * MB, cfg=CFG, n_shards=1)
    t = 0.0
    for k, (fp, off, sz) in enumerate(mixed_trace(store, seed)):
        om = mono.read(fp, off, sz, t)
        of = facade.read(fp, off, sz, t)
        assert outcome_tuple(om) == outcome_tuple(of), \
            f"divergence at access {k}: {fp} off={off}"
        for p, s in om.prefetches:
            mono.complete_prefetch(p, s, t)
        for p, s in of.prefetches:
            facade.complete_prefetch(p, s, t)
        t += 0.011
    assert mono.snapshot() == facade.snapshot()
    assert mono.stats.snapshot() == facade.stats.snapshot()
    assert mono.tree.node_count() == facade.node_count()


def test_sharded_n1_read_batch_matches_engine():
    store = mk_store()
    mono = IGTCache(store, 192 * MB, cfg=CFG)
    facade = ShardedIGTCache(store, 192 * MB, cfg=CFG, n_shards=1)
    reqs = mixed_trace(store, 11)[:600]
    t = 0.0
    for i in range(0, len(reqs), 8):
        group = reqs[i:i + 8]
        outs_m = mono.read_batch(group, t)
        outs_f = facade.read_batch(group, t)
        assert [outcome_tuple(o) for o in outs_m] == \
            [outcome_tuple(o) for o in outs_f]
        for outs, eng in ((outs_m, mono), (outs_f, facade)):
            for o in outs:
                for p, s in o.prefetches:
                    eng.complete_prefetch(p, s, t)
        t += 0.01
    assert mono.snapshot() == facade.snapshot()


# ---------------------------------------------------------------------------
# client layer (PR 3): CacheClient+SimExecutor vs the caller-driven loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_client_sim_executor_matches_caller_driven_loop(seed):
    """The caller layer is pure plumbing: a CacheClient with the inline
    SimExecutor over n_shards=1 must produce bitwise-identical
    ReadOutcomes, stats and tree state to the hand-rolled
    read-then-complete loop every consumer used to carry (the cluster
    sim's fetch/admit loop, the pipeline's inline mode, the examples)."""
    store = mk_store()
    loop = IGTCache(store, 192 * MB, cfg=CFG)
    client = open_cache(store, 192 * MB, cfg=CFG, n_shards=1,
                        executor="sim")
    t = 0.0
    for k, (fp, off, sz) in enumerate(mixed_trace(store, seed)):
        res = client.read(fp, off, sz, t)       # executor completes inline
        ol = loop.read(fp, off, sz, t)
        for p, s in ol.prefetches:              # the caller-driven contract
            loop.complete_prefetch(p, s, t)
        assert outcome_tuple(res.outcome) == outcome_tuple(ol), \
            f"divergence at access {k}: {fp} off={off}"
        t += 0.011
    assert client.engine.snapshot() == loop.snapshot()
    assert client.engine.stats.snapshot() == loop.stats.snapshot()
    assert client.engine.tree.node_count() == loop.tree.node_count()
    ex = client.executor.stats
    assert ex.completed == ex.submitted and ex.cancelled == 0


def test_client_read_batch_matches_caller_driven_loop():
    store = mk_store()
    loop = IGTCache(store, 192 * MB, cfg=CFG)
    client = open_cache(store, 192 * MB, cfg=CFG, n_shards=1,
                        executor="sim")
    reqs = mixed_trace(store, 11)[:600]
    t = 0.0
    for i in range(0, len(reqs), 8):
        group = reqs[i:i + 8]
        results = client.read_batch(group, t)
        outs_l = loop.read_batch(group, t)
        for o in outs_l:
            for p, s in o.prefetches:
                loop.complete_prefetch(p, s, t)
        assert [outcome_tuple(r.outcome) for r in results] == \
            [outcome_tuple(o) for o in outs_l]
        t += 0.01
    assert client.engine.snapshot() == loop.snapshot()


@pytest.mark.parametrize("seed", [0, 7])
def test_open_cache_uri_v2_store_matches_instance_client(seed):
    """Acceptance (this PR): ``open_cache("sim://default", ...)`` — the
    URI front door resolving to the v2 ranged/batched store protocol —
    is bitwise-equivalent to the PR-3 store-instance client on the
    seeded mixed traces: identical ReadOutcomes, stats, tree state, and
    identical fetched bytes."""
    ref_store = mk_store()
    ref = open_cache(ref_store, 192 * MB, cfg=CFG, n_shards=1,
                     executor="sim")
    uri = open_cache("sim://default", 192 * MB, cfg=CFG, n_shards=1,
                     executor="sim")
    # register the identical dataset layouts on the URI-created store
    uri.meta.add(make_dataset("seqset", "flat_files", n_files=250,
                              small_file_size=256 * 1024))
    uri.meta.add(make_dataset("randset", "dir_tree", n_dirs=20,
                              files_per_dir=15, small_file_size=256 * 1024))
    uri.meta.add(make_dataset("bigfiles", "big_files", n_files=10,
                              file_size=24 * MB))
    t = 0.0
    for k, (fp, off, sz) in enumerate(mixed_trace(ref_store, seed)):
        want = k % 97 == 0       # spot-check the byte path too
        ru = uri.read(fp, off, sz, t, fetch=want)
        rr = ref.read(fp, off, sz, t, fetch=want)
        assert outcome_tuple(ru.outcome) == outcome_tuple(rr.outcome), \
            f"divergence at access {k}: {fp} off={off}"
        if want and ru.blocks:
            assert np.array_equal(ru.data, rr.data), \
                f"byte divergence at access {k}: {fp} off={off}"
        t += 0.011
    assert uri.engine.snapshot() == ref.engine.snapshot()
    assert uri.engine.stats.snapshot() == ref.engine.stats.snapshot()
    assert uri.engine.tree.node_count() == ref.engine.tree.node_count()
    for c in (uri, ref):
        ex = c.executor.stats
        assert ex.completed == ex.submitted and ex.cancelled == 0


# ---------------------------------------------------------------------------
# vectorized analytics vs the scalar reference implementations
# ---------------------------------------------------------------------------

def _windows(seed, n_windows=200):
    """Randomized windows across all regimes the classifier distinguishes."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_windows):
        kind = rng.integers(0, 5)
        n = int(rng.integers(2, 101))
        c = int(rng.integers(2, 400))
        if kind == 0:       # sequential-ish
            start = int(rng.integers(0, 5))
            stride = int(rng.integers(1, 4))
            idx = start + stride * np.arange(n)
            c = max(c, int(idx.max()) + 1)
        elif kind == 1:     # permutation (random pattern)
            c = max(c, n)
            idx = rng.permutation(c)[:n]
        elif kind == 2:     # zipf-hot (skewed)
            idx = (rng.zipf(1.4, n) - 1) % c
        elif kind == 3:     # uniform with replacement
            idx = rng.integers(0, c, n)
        else:               # degenerate / tiny index space
            c = int(rng.integers(1, 4))
            idx = rng.integers(0, c, n)
        out.append((np.asarray(idx, dtype=np.int64), c))
    return out


def test_classify_batch_agrees_with_scalar_classify():
    cfg = CacheConfig(window=100)
    windows = _windows(0)
    got = classify_batch(windows, cfg)
    for (idx, c), res in zip(windows, got):
        records = [AccessRecord(index=int(i), total=c, time=float(k),
                                child_key=str(int(i)))
                   for k, i in enumerate(idx)]
        ref = classify(records, c, cfg)
        assert res.pattern is ref.pattern, \
            f"label mismatch: vec={res.pattern} scalar={ref.pattern} " \
            f"(n={len(idx)}, c={c})"
        if ref.d_critical:
            assert res.d_stat == pytest.approx(ref.d_stat, abs=1e-12)
            assert res.d_critical == pytest.approx(ref.d_critical, abs=1e-12)
        if ref.pattern is Pattern.SEQUENTIAL:
            assert res.stride == ref.stride


def test_classify_batch_rows_independent_of_batching():
    """A window must classify identically alone and inside a matrix batch."""
    cfg = CacheConfig(window=100)
    windows = _windows(1, n_windows=64)
    together = classify_batch(windows, cfg)
    alone = [classify_batch([w], cfg)[0] for w in windows]
    for a, b in zip(together, alone):
        assert a.pattern is b.pattern
        assert a.d_stat == b.d_stat
        assert a.d_critical == b.d_critical
        assert a.stride == b.stride
        assert a.seq_fraction == b.seq_fraction


def test_fit_adaptive_ttl_arr_matches_scalar():
    cfg = CacheConfig()
    rng = np.random.default_rng(2)
    for n in (0, 1, 2, 3, 10, 100):
        times = np.cumsum(rng.exponential(2.0, n))
        ref = fit_adaptive_ttl([float(t) for t in times], cfg)
        got = fit_adaptive_ttl_arr(times, cfg)
        if ref is None:
            assert got is None
        else:
            assert got == pytest.approx(ref, rel=1e-9)


def test_fit_adaptive_ttl_batch_matches_arr():
    """The one-matrix-pass TTL fit (all due-random nodes per classify pass)
    agrees with the per-window reference, including degenerate windows and
    out-of-order timestamps mid-batch."""
    cfg = CacheConfig()
    rng = np.random.default_rng(3)
    windows = []
    for n in (0, 1, 2, 3, 4, 10, 37, 100):
        windows.append(np.cumsum(rng.exponential(2.0, n)))
    shuffled = rng.exponential(2.0, 20)      # negative diffs get filtered
    windows.append(shuffled)
    got = fit_adaptive_ttl_batch(windows, cfg)
    assert len(got) == len(windows)
    for w, g in zip(windows, got):
        ref = fit_adaptive_ttl_arr(np.asarray(w, dtype=np.float64), cfg)
        if ref is None:
            assert g is None
        else:
            assert g == pytest.approx(ref, rel=1e-9)
    assert fit_adaptive_ttl_batch([], cfg) == []


def test_node_cap_leaf_lru_detaches_childless_first():
    cfg = CacheConfig(window=8, node_cap=60)
    t = AccessStreamTree(cfg)
    for i in range(2000):
        t.observe([(f"d{i % 30}", i % 30, 40), (f"f{i % 90}", i % 90, 90),
                   ("#0", 0, 4)], time=float(i))
        assert t.node_count() <= cfg.node_cap
    # interior nodes (the 30 live directories) must have survived: victims
    # are always taken from the childless leaf LRU first
    alive_dirs = sum(1 for n in t.iter_nodes() if n.children)
    assert alive_dirs > 0
    assert t.node_count() <= cfg.node_cap

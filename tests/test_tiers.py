"""Tiered storage: RAM + spill-to-disk tiers, pattern-aware placement,
the ``s3://``/``mock-s3://`` object-store scheme, and their composition
with ``faulty+`` fault injection and the process-driver store specs.

Markers: ``tier`` tests run in tier-1; ``tier_full`` is the slow
durability/benchmark matrix (opt-in via ``-m tier_full``).
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import CacheConfig, open_cache
from repro.core.types import MB, Pattern
from repro.storage import (FaultyStore, MemStore, MockS3Server, RetryPolicy,
                           S3Store, StoreError, TieredStore,
                           TransientStoreError, open_store)
from repro.storage.api import resolve_store_spec, store_spec
from repro.storage.s3 import mock_object_bytes
from repro.storage.tiers import DiskTier

BS = 64 * 1024          # block size for every store in this file

pytestmark = pytest.mark.tier


def _mem_world(n_files=6, blocks_per_file=3, seed=0):
    mem = MemStore(block_size=BS)
    rng = np.random.default_rng(seed)
    data = {}
    for i in range(n_files):
        b = rng.integers(0, 256, BS * blocks_per_file,
                         dtype=np.uint8).tobytes()
        mem.add_file(("ds", f"f{i:02d}"), b)
        data[i] = b
    return mem, data


def _tiered(mem, tmp_path, *, ram_blocks=4, disk_blocks=64, **kw):
    return TieredStore(mem, ram_bytes=ram_blocks * BS,
                       disk_bytes=disk_blocks * BS,
                       spill_dir=str(tmp_path / "spill"), **kw)


class _CountingInner:
    """v2 wrapper counting inner fetches (tier-hit tests prove the inner
    store was *not* consulted)."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.lock = threading.Lock()

    def capabilities(self):
        return self.inner.capabilities()

    def fetch_range(self, path, offset, length):
        with self.lock:
            self.calls += 1
        return self.inner.fetch_range(path, offset, length)

    def fetch_many(self, requests):
        with self.lock:
            self.calls += len(requests)
        return self.inner.fetch_many(requests)

    def __getattr__(self, name):
        return getattr(self.inner, name)


# ---------------------------------------------------------------------------
# bytes mode: fills, slices, spills, promotes
# ---------------------------------------------------------------------------

def test_whole_block_fill_and_partial_slice(tmp_path):
    mem, data = _mem_world()
    ts = _tiered(mem, tmp_path)
    # full-block miss → fetched from inner, admitted
    got = ts.fetch_range(("ds", "f00", "#1"), 0, BS)
    assert bytes(got) == data[0][BS:2 * BS]
    assert ts.tier_stats()["ram_blocks"] == 1
    # partial read of the resident block → served by slicing RAM
    counting = _CountingInner(mem)
    ts2 = TieredStore(counting, ram_bytes=4 * BS, disk_bytes=16 * BS,
                      spill_dir=str(tmp_path / "s2"))
    assert bytes(ts2.fetch_range(("ds", "f00", "#1"), 0, BS)) == \
        data[0][BS:2 * BS]
    before = counting.calls
    part = ts2.fetch_range(("ds", "f00", "#1"), 100, 300)
    assert bytes(part) == data[0][BS + 100:BS + 400]
    assert counting.calls == before          # no inner fetch: RAM slice
    assert ts2.tier_stats()["ram_hits"] == 1
    # partial miss (block not resident) passes through uncached
    part2 = ts2.fetch_range(("ds", "f01", "#0"), 10, 50)
    assert bytes(part2) == data[1][10:60]
    snap = ts2.tier_stats()
    assert snap["pass_through"] >= 1
    assert snap["ram_blocks"] == 1           # nothing new admitted


def test_fetch_many_serves_resident_and_batches_misses(tmp_path):
    mem, data = _mem_world()
    counting = _CountingInner(mem)
    ts = TieredStore(counting, ram_bytes=8 * BS, disk_bytes=16 * BS,
                     spill_dir=str(tmp_path / "spill"))
    reqs = [(("ds", "f00", "#0"), 0, BS), (("ds", "f01", "#0"), 0, BS)]
    out = ts.fetch_many(reqs)
    assert bytes(out[0]) == data[0][:BS] and bytes(out[1]) == data[1][:BS]
    before = counting.calls
    out2 = ts.fetch_many(reqs + [(("ds", "f02", "#0"), 0, BS)])
    assert counting.calls == before + 1      # only the new block fetched
    assert bytes(out2[2]) == data[2][:BS]
    assert ts.tier_stats()["ram_hits"] == 2


def test_ram_spills_to_disk_and_promotes_exact_bytes(tmp_path):
    mem, data = _mem_world(n_files=8, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=2)
    for i in range(8):
        ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
    snap = ts.tier_stats()
    assert snap["ram_blocks"] == 2
    assert snap["spills"] == 6 and snap["disk_blocks"] == 6
    assert os.listdir(ts.spill_dir)          # real files on disk
    # disk hit: exact bytes, no inner fetch, promoted back to RAM
    counting = ts.inner  # noqa: F841
    got = ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got) == data[0][:BS]
    snap = ts.tier_stats()
    assert snap["disk_hits"] == 1 and snap["promotes"] == 1
    # partial slice of a disk-resident block also returns exact bytes
    got2 = ts.fetch_range(("ds", "f01", "#0"), 1000, 123)
    assert bytes(got2) == data[1][1000:1123]


def test_kernel_eviction_spills_payload(tmp_path):
    """The engine's evict hook moves a RAM-resident payload to disk."""
    mem, data = _mem_world(n_files=4, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=8)
    for i in range(4):
        ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
    assert ts.tier_stats()["ram_blocks"] == 4
    ts.note_evicted("ds/f00/#0", BS)
    snap = ts.tier_stats()
    assert snap["ram_blocks"] == 3 and snap["disk_blocks"] == 1
    got = ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got) == data[0][:BS]
    assert ts.tier_stats()["disk_hits"] == 1


# ---------------------------------------------------------------------------
# pattern-aware placement
# ---------------------------------------------------------------------------

def test_sequential_writes_through_to_disk_not_ram(tmp_path):
    mem, data = _mem_world()
    ts = _tiered(mem, tmp_path)
    ts.note_pattern("ds", Pattern.SEQUENTIAL.value, False)
    got = ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got) == data[0][:BS]
    snap = ts.tier_stats()
    assert snap["ram_blocks"] == 0           # streamed: never RAM-resident
    assert snap["disk_blocks"] == 1          # but disk-eligible
    # a re-scan hits disk and *streams* (no promote for sequential)
    got2 = ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got2) == data[0][:BS]
    snap = ts.tier_stats()
    assert snap["disk_hits"] == 1 and snap["promotes"] == 0
    assert snap["ram_blocks"] == 0


def test_skewed_blocks_pin_in_ram_under_pressure(tmp_path):
    mem = MemStore(block_size=BS)
    rng = np.random.default_rng(0)
    for top in ("hot", "cold"):
        for i in range(4):
            mem.add_file((top, f"f{i}"),
                         rng.integers(0, 256, BS, dtype=np.uint8).tobytes())
    ts = TieredStore(mem, ram_bytes=4 * BS, disk_bytes=32 * BS,
                     spill_dir=str(tmp_path / "spill"))
    ts.note_pattern("hot", Pattern.SKEWED.value, True)
    for i in range(2):
        ts.fetch_range(("hot", f"f{i}", "#0"), 0, BS)
    # pressure from non-sticky traffic: sticky blocks must survive
    for i in range(4):
        ts.fetch_range(("cold", f"f{i}", "#0"), 0, BS)
    resident = set(ts._ram)
    assert {"hot/f0/#0", "hot/f1/#0"} <= resident
    assert ts.tier_stats()["ram_evictions"] >= 2  # cold blocks churned


def test_target_hit_rate_gates_random_admission(tmp_path):
    mem, data = _mem_world(n_files=8, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=2, target_hit_rate=0.5,
                 hit_window=16)
    ts.note_pattern("ds", Pattern.RANDOM.value, False)
    # fill RAM, then drive the windowed hit rate above target
    ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    ts.fetch_range(("ds", "f01", "#0"), 0, BS)
    for _ in range(20):
        ts.fetch_range(("ds", "f00", "#0"), 0, BS)
        ts.fetch_range(("ds", "f01", "#0"), 0, BS)
    assert ts._recent_rate is not None and ts._recent_rate >= 0.5
    before = dict(ts.tier_stats())
    ts.fetch_range(("ds", "f02", "#0"), 0, BS)   # would evict a RAM block
    snap = ts.tier_stats()
    assert snap["admission_skips"] == before["admission_skips"] + 1
    assert set(ts._ram) == {"ds/f00/#0", "ds/f01/#0"}  # no churn
    # SEQUENTIAL placement is structural: never gated
    ts.note_pattern("seq", Pattern.SEQUENTIAL.value, False)
    assert not ts._admission_gated("sequential")


# ---------------------------------------------------------------------------
# durability edges
# ---------------------------------------------------------------------------

def test_warm_restart_reindexes_spill_dir(tmp_path):
    mem, data = _mem_world(n_files=6, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=2)
    for i in range(6):
        ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
    spilled = ts.tier_stats()["disk_blocks"]
    assert spilled == 4
    # "restart": a fresh store over the same spill dir re-adopts the files
    counting = _CountingInner(mem)
    ts2 = TieredStore(counting, ram_bytes=2 * BS, disk_bytes=64 * BS,
                      spill_dir=ts.spill_dir)
    snap = ts2.tier_stats()
    assert snap["restored"] == spilled and snap["disk_blocks"] == spilled
    got = ts2.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got) == data[0][:BS]
    assert counting.calls == 0               # served from the warm spill dir
    assert ts2.tier_stats()["disk_hits"] == 1


def test_corrupt_spill_file_degrades_to_clean_miss(tmp_path):
    mem, data = _mem_world(n_files=4, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=1)
    for i in range(4):
        ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
    # truncate f00's spill file and bit-flip f01's payload
    trunc = os.path.join(ts.spill_dir, ts.disk._fname("ds/f00/#0"))
    with open(trunc, "r+b") as f:
        f.truncate(os.path.getsize(trunc) // 2)
    flip = os.path.join(ts.spill_dir, ts.disk._fname("ds/f01/#0"))
    raw = bytearray(open(flip, "rb").read())
    raw[-1] ^= 0xFF
    with open(flip, "wb") as f:
        f.write(raw)
    # the truncated block reads back exact inner bytes — never corrupt
    got = ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got) == data[0][:BS]
    assert ts.tier_stats()["checksum_failures"] == 1
    assert not os.path.exists(trunc)         # bad file dropped on detection
    # ditto the bit-flipped one
    got = ts.fetch_range(("ds", "f01", "#0"), 0, BS)
    assert bytes(got) == data[1][:BS]
    snap = ts.tier_stats()
    assert snap["checksum_failures"] == 2
    # every other read still round-trips
    for i in range(4):
        got = ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
        assert bytes(got) == data[i][:BS]


def test_corrupt_files_dropped_at_reindex(tmp_path):
    mem, _ = _mem_world(n_files=3, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=1)
    for i in range(3):
        ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
    bad = os.path.join(ts.spill_dir, "junk.blk")
    with open(bad, "wb") as f:
        f.write(b"not a spill header at all")
    ts2 = TieredStore(mem, ram_bytes=BS, disk_bytes=64 * BS,
                      spill_dir=ts.spill_dir)
    assert not os.path.exists(bad)           # unparseable file deleted
    assert ts2.tier_stats()["restored"] == 2


def test_spill_dir_full_falls_back_to_ram_only(tmp_path, monkeypatch):
    mem, data = _mem_world(n_files=16, blocks_per_file=1)
    ts = _tiered(mem, tmp_path, ram_blocks=2)

    def fail_replace(src, dst):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr(os, "replace", fail_replace)
    for i in range(12):
        got = ts.fetch_range(("ds", f"f{i:02d}", "#0"), 0, BS)
        assert bytes(got) == data[i][:BS]    # reads keep working
    snap = ts.tier_stats()
    assert snap["spill_errors"] >= 8
    assert snap["disk_disabled"] is True     # stopped hammering the disk
    assert snap["disk_blocks"] == 0
    monkeypatch.undo()
    # RAM tier still serves
    got = ts.fetch_range(("ds", "f11", "#0"), 0, BS)
    assert bytes(got) == data[11][:BS]
    assert ts.tier_stats()["ram_hits"] >= 1


def test_disk_tier_capacity_evicts_lru(tmp_path):
    stats_dir = str(tmp_path / "d")
    tier = DiskTier(3 * BS, stats_dir, payload=True)
    blob = np.zeros(BS, dtype=np.uint8)
    for i in range(5):
        assert tier.put(f"k{i}", BS, blob)
    assert len(tier.index) == 3 and tier.used == 3 * BS
    assert tier.stats.disk_evictions == 2
    assert "k0" not in tier and "k4" in tier


# ---------------------------------------------------------------------------
# URI composition + worker respawn specs
# ---------------------------------------------------------------------------

def test_tiered_uri_and_query_knobs(tmp_path):
    st = open_store(f"tiered+mem://?ram_mb=1&disk_mb=4&block_size={BS}"
                    f"&target_hit_rate=0.7&mode=bytes"
                    f"&spill_dir={tmp_path / 'sp'}")
    assert isinstance(st, TieredStore)
    assert st.ram_bytes == 1 * MB and st.disk_bytes == 4 * MB
    assert st.target_hit_rate == 0.7
    assert st.inner.block_size == BS
    assert st.uri.startswith("tiered+mem://")
    # RAM-only configuration: disk tier absent, no spill dir required
    ram_only = open_store(f"tiered+mem://?ram_mb=1&disk_mb=0"
                          f"&block_size={BS}")
    assert ram_only.disk_bytes == 0 and ram_only.spill_dir is None


def test_wrapper_spec_round_trip_keeps_fault_injection(tmp_path):
    """The registry double-wrap fix: ``store_spec`` on a ``faulty+`` (or
    ``tiered+``) wrapper must return the *composed* URI, so a respawned
    worker reconstructs the whole stack — previously the wrapper
    delegated ``uri`` from the inner store and the fault injector was
    silently dropped on respawn."""
    root = tmp_path / "data"
    root.mkdir()
    (root / "a.bin").write_bytes(b"\x01" * 4096)
    uri = f"faulty+file://{root}?fail_rate=0.25&seed=7&block_size={BS}"
    st = open_store(uri)
    assert isinstance(st, FaultyStore)
    kind, payload = store_spec(st)
    assert (kind, payload) == ("uri", uri)
    clone = resolve_store_spec((kind, payload))
    assert isinstance(clone, FaultyStore)
    assert clone.fail_rate == 0.25 and clone._rng is not None
    # tiered+ wrapper: same contract
    turi = (f"tiered+file://{root}?ram_mb=1&disk_mb=2&block_size={BS}"
            f"&spill_dir={tmp_path / 'sp'}")
    tst = open_store(turi)
    assert store_spec(tst) == ("uri", turi)
    tclone = resolve_store_spec(store_spec(tst))
    assert isinstance(tclone, TieredStore) and tclone.ram_bytes == 1 * MB
    # a tiered store over a non-reopenable inner travels as the object
    mem_tiered = open_store(f"tiered+mem://?ram_mb=1&block_size={BS}")
    assert store_spec(mem_tiered)[0] == "object"


def test_faulty_tiered_composition(tmp_path):
    mem, data = _mem_world(n_files=2, blocks_per_file=1)
    # tiered over faulty: a tier hit masks the injector entirely
    faulty = FaultyStore(mem, fail_rate=0.0)
    ts = TieredStore(faulty, ram_bytes=4 * BS, disk_bytes=8 * BS,
                     spill_dir=str(tmp_path / "sp"))
    assert bytes(ts.fetch_range(("ds", "f00", "#0"), 0, BS)) == data[0][:BS]
    faulty.fail_rate = 1.0                   # store goes dark
    got = ts.fetch_range(("ds", "f00", "#0"), 0, BS)   # tier hit: no fault
    assert bytes(got) == data[0][:BS]
    with pytest.raises(TransientStoreError):
        ts.fetch_range(("ds", "f01", "#0"), 0, BS)     # tier miss: surfaces


def test_mock_s3_spec_reopens_identical_server():
    uri = f"mock-s3://spec/bkt?dirs=1&files=2&file_kb=16&block_size={BS}"
    a = open_store(uri)
    clone = resolve_store_spec(store_spec(a))
    assert isinstance(clone, S3Store)
    p = ("bkt", "00", "001.bin")
    assert clone.file_size(p) == 16 * 1024
    assert np.array_equal(clone.fetch_range(p, 5, 100),
                          a.fetch_range(p, 5, 100))


# ---------------------------------------------------------------------------
# the object-store scheme
# ---------------------------------------------------------------------------

def test_mock_s3_metadata_and_ranged_bytes():
    st = open_store(f"mock-s3://t/b1?dirs=2&files=3&file_kb=8"
                    f"&block_size=4096")
    assert st.listing(("b1",)) == ["00", "01"]
    assert st.listing(("b1", "01")) == ["000.bin", "001.bin", "002.bin"]
    p = ("b1", "01", "002.bin")
    assert st.file_size(p) == 8192
    got = st.fetch_range(p, 123, 456)
    assert np.array_equal(got, mock_object_bytes("b1", "01/002.bin",
                                                 123, 456))
    # block-relative addressing resolves through block_size
    blk = st.fetch_range(p + ("#1",), 10, 20)
    assert np.array_equal(blk, mock_object_bytes("b1", "01/002.bin",
                                                 4096 + 10, 20))
    # batched fetch preserves request order over one connection
    outs = st.fetch_many([(p, 0, 10), (p, 100, 10), (p + ("#1",), 0, 10)])
    assert np.array_equal(outs[1], mock_object_bytes("b1", "01/002.bin",
                                                     100, 10))
    caps = st.capabilities()
    assert caps.ranges and caps.batching


def test_s3_explicit_server_and_errors():
    srv = MockS3Server()
    try:
        srv.add_object("bkt", "dir/obj.bin", data=bytes(range(256)) * 16)
        st = open_store(srv.uri("bkt") + "?block_size=1024")
        p = ("bkt", "dir", "obj.bin")
        assert st.file_size(p) == 4096
        got = st.fetch_range(p, 250, 20)
        assert bytes(got) == (bytes(range(256)) * 16)[250:270]
        with pytest.raises(StoreError):
            st.fetch_range(("bkt", "dir", "missing.bin"), 0, 10)
        with pytest.raises(StoreError):
            st.fetch_range(p, 4000, 500)     # past EOF: permanent
    finally:
        srv.close()
    # server gone: transport error surfaces as transient (retryable).
    # Drop the keep-alive socket first — an already-established handler
    # thread would otherwise keep serving it after shutdown.
    st._drop_conn()
    with pytest.raises(TransientStoreError):
        st.fetch_range(p, 0, 16)


def test_mock_s3_round_trips_under_retry_and_breaker():
    """Acceptance: mock-s3 returns exact ranged bytes under fault
    injection, through the client's RetryPolicy/CircuitBreaker."""
    uri = (f"faulty+mock-s3://rt/b2?dirs=1&files=4&file_kb=32"
           f"&fail_rate=0.35&seed=3&block_size=8192")
    st = open_store(uri)
    assert isinstance(st, FaultyStore)
    retry = RetryPolicy(max_attempts=8, backoff_s=0.0,
                        sleep=lambda s: None)
    for i in range(4):
        p = ("b2", "00", f"{i:03d}.bin")
        got = retry.call(st.fetch_range, p, 1000, 2000)
        assert np.array_equal(
            got, mock_object_bytes("b2", f"00/{i:03d}.bin", 1000, 2000))
    assert st.injected_transient > 0


def test_open_cache_over_mock_s3_end_to_end():
    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=16 * 1024)
    client = open_cache("mock-s3://e2e/corpus?dirs=2&files=3&file_kb=32"
                        "&block_size=16384", 4 * MB, cfg=cfg,
                        executor="sim", fetch_bytes=True)
    files = [("corpus", f"{d:02d}", f"{i:03d}.bin")
             for d in range(2) for i in range(3)]
    t = 0.0
    for rel in files:
        res = client.read(rel, 0, client.meta.file_size(rel), t)
        t += 0.1
        assert bytes(res.data) == bytes(
            mock_object_bytes("corpus", "/".join(rel[1:]), 0, 32 * 1024))
    client.close()


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _cfg():
    return CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                       block_size=BS)


def test_client_spills_and_serves_from_disk_tier(tmp_path):
    mem, data = _mem_world(n_files=12, blocks_per_file=3, seed=1)
    ts = _tiered(mem, tmp_path, ram_blocks=16, disk_blocks=128)
    client = open_cache(ts, 1 * MB, cfg=_cfg(), executor="sim",
                        fetch_bytes=True)
    files = [("ds", f"f{i:02d}") for i in range(12)]
    t = 0.0
    for _ in range(2):
        for i, rel in enumerate(files):
            res = client.read(rel, 0, client.meta.file_size(rel), t)
            t += 0.1
            assert bytes(res.data) == data[i]
    snap = client.snapshot()
    tiers = snap["store"]["tiers"]
    assert tiers["disk_hits"] + tiers["ram_hits"] > 0
    assert tiers["spills"] > 0               # kernel evictions spilled
    client.close()


def test_tiered_client_is_equivalent_to_flat(tmp_path):
    """RAM-only acceptance: wrapping the store in tiers never changes
    kernel outcomes — hits/misses/evictions/bytes are bitwise equal."""
    mem, _ = _mem_world(n_files=10, blocks_per_file=3, seed=2)

    def trace(store):
        client = open_cache(store, 1 * MB, cfg=_cfg(), executor="sim")
        t = 0.0
        for _ in range(3):
            for i in range(10):
                client.read(("ds", f"f{i:02d}"), 0,
                            client.meta.file_size(("ds", f"f{i:02d}")), t)
                t += 0.1
        s = client.snapshot()
        client.close()
        return {k: s[k] for k in ("hits", "misses", "evictions",
                                  "prefetch_hits", "bytes_from_remote",
                                  "bytes_from_cache")}

    flat = trace(mem)
    tiered = trace(_tiered(mem, tmp_path, ram_blocks=8, disk_blocks=64))
    assert flat == tiered


def test_engine_pushes_placement_hints(tmp_path):
    mem = MemStore(block_size=BS)
    rng = np.random.default_rng(3)
    for i in range(40):
        mem.add_file(("scan", f"f{i:03d}"),
                     rng.integers(0, 256, BS, dtype=np.uint8).tobytes())
    ts = _tiered(mem, tmp_path, ram_blocks=8, disk_blocks=64)
    client = open_cache(ts, 2 * MB, cfg=_cfg(), executor="sim")
    t = 0.0
    for _ in range(4):                       # sequential scan epochs
        for i in range(40):
            client.read(("scan", f"f{i:03d}"), 0, BS, t)
            t += 0.5
    pats = ts.tier_stats()["patterns"]
    assert pats.get("scan", ("", False))[0] == "sequential"
    client.close()


@pytest.mark.tier_full
def test_cluster_sim_tier_accounting():
    """Index mode under the discrete-event sim: disk hits shortcut the
    link, accounting lands in SimResult.tier_stats/link_bytes."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    from common import build_world, scaled_cfg
    from repro.core.baselines import bundle_client
    from repro.sim.cluster import ClusterSim

    suite, store, cap = build_world(0.02, 0, cache_ratio=0.5)
    ram = int(cap * 0.8)
    ts = TieredStore(store, mode="index", disk_bytes=cap - ram)
    client = bundle_client("igtcache", ts, ram, cfg=scaled_cfg(ram))
    res = ClusterSim(suite, client).run()
    t = res.tier_stats
    assert t["mode"] == "index"
    assert t["disk_hits"] > 0
    assert res.link_bytes > 0
    kh, km = res.stats["hits"], res.stats["misses"]
    combined = (kh + t["disk_hits"]) / max(1, kh + km)
    assert combined > res.hit_ratio          # the tier added real hits
    assert t["patterns"]                     # placement verdicts arrived


def test_index_mode_needs_no_spill_dir():
    mem, _ = _mem_world(n_files=2, blocks_per_file=1)
    ts = TieredStore(mem, mode="index", disk_bytes=4 * BS)
    assert ts.spill_dir is None
    assert ts.sim_read("ds/f00/#0", BS) is False    # miss → admitted
    assert ts.sim_read("ds/f00/#0", BS) is True     # now disk-resident
    # non-sequential hit promotes: entry leaves the index
    assert ts.sim_read("ds/f00/#0", BS) is False


def test_tiered_store_pickles(tmp_path):
    import pickle
    mem, data = _mem_world(n_files=2, blocks_per_file=1)
    ts = _tiered(mem, tmp_path)
    ts.fetch_range(("ds", "f00", "#0"), 0, BS)
    clone = pickle.loads(pickle.dumps(ts))
    got = clone.fetch_range(("ds", "f00", "#0"), 0, BS)
    assert bytes(got) == data[0][:BS]
    assert clone.tier_stats()["ram_hits"] >= 1

"""The unified storage API (this PR's tentpole).

Covers the v2 ``BackingStore`` protocol (ranged reads, batched
``fetch_many``, capability negotiation), the URI scheme registry
(``sim:// / file:// / mem:// / faulty+...``), the real ``LocalFSStore``
round-trip against an on-disk tree, the legacy one-method shim, and the
fault contract the client layer promises: transient errors retried with
accounting, permanent errors propagated with clean candidate
cancellation (no kernel pending-table leak).
"""
import os
import threading

import numpy as np
import pytest

from repro.core import CacheConfig, IGTCache, CacheClient, open_cache
from repro.core.client import SimExecutor, ThreadedExecutor
from repro.core.types import MB, block_key, split_block_key
from repro.storage import (FaultyStore, LegacyStoreAdapter, LocalFSStore,
                           MemStore, RemoteStore, RetryPolicy,
                           StoreCapabilities, StoreError, TransientStoreError,
                           as_backing_store, make_dataset, open_store,
                           registered_schemes)

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  window=40, reanalyze_every=20)


# ---------------------------------------------------------------------------
# block-key helpers (satellite: one construction point)
# ---------------------------------------------------------------------------

def test_block_key_roundtrip():
    p = ("ds", "train", "a.bin")
    assert block_key(p, 3) == ("ds", "train", "a.bin", "#3")
    assert split_block_key(block_key(p, 3)) == (p, 3)
    assert split_block_key(p) == (p, None)
    assert split_block_key(()) == ((), None)
    # a real file can be named "#something" — that is not a block key
    assert split_block_key(("ds", "#notes")) == (("ds", "#notes"), None)


# ---------------------------------------------------------------------------
# URI registry
# ---------------------------------------------------------------------------

def test_open_store_registry_schemes():
    assert {"sim", "file", "mem"} <= set(registered_schemes())
    sim = open_store("sim://default?latency_s=0.2")
    assert isinstance(sim, RemoteStore)
    assert sim.transfer.latency_s == pytest.approx(0.2)
    mem = open_store("mem://?block_size=65536")
    assert isinstance(mem, MemStore) and mem.block_size == 65536
    with pytest.raises(ValueError):
        open_store("warp://nope")
    with pytest.raises(ValueError):
        open_store("no-scheme-at-all")


def test_cache_scheme_registry_roundtrip():
    """cache:// resolves through the same registry as the stores, but
    yields a daemon *address* (not a store): open_cache dispatches on it
    to build a RemoteCacheClient instead of a kernel."""
    from repro.daemon import DaemonAddress, format_cache_uri

    assert "cache" in registered_schemes()
    uds = open_store("cache:///tmp/igt.sock")
    assert isinstance(uds, DaemonAddress)
    assert uds.is_cache_address
    assert uds.kind == "uds" and uds.path == "/tmp/igt.sock"
    assert uds.connect_args() == ("uds", "/tmp/igt.sock")
    tcp = open_store("cache://127.0.0.1:7171?label=trainer")
    assert tcp.kind == "tcp" and tcp.connect_args() == \
        ("tcp", ("127.0.0.1", 7171))
    assert tcp.params == {"label": "trainer"}
    # the address remembers its URI, and format round-trips
    assert uds.uri.startswith("cache://")
    assert open_store(format_cache_uri(uds)).connect_args() == \
        uds.connect_args()
    with pytest.raises(ValueError):
        open_store("cache://")            # no endpoint at all


def test_open_store_faulty_wrapper():
    st = open_store("faulty+sim://default?fail_rate=1.0&seed=3")
    assert isinstance(st, FaultyStore)
    assert isinstance(st.inner, RemoteStore)
    st.inner.add(make_dataset("d", "big_files", n_files=1, file_size=8 * MB))
    bp = block_key(st.inner.datasets["d"].files[0].path, 0)
    with pytest.raises(TransientStoreError):
        st.fetch_range(bp, 0, 16)
    assert st.injected_transient == 1
    # metadata passes through untouched
    assert st.subtree_bytes(("d",)) == 8 * MB


# ---------------------------------------------------------------------------
# RemoteStore v2: ranged synthesis (satellite: hoisted digest)
# ---------------------------------------------------------------------------

def test_remote_store_ranged_synthesis_consistent():
    store = RemoteStore()
    store.add(make_dataset("big", "big_files", n_files=2, file_size=9 * MB))
    f = store.datasets["big"].files[0]
    bp = block_key(f.path, 1)
    whole = store.fetch_block(bp, 1 * MB)
    # any sub-range equals the sliced prefix — no over-synthesis needed
    for off, ln in ((0, 17), (3, 64), (1000, 4096), (1 * MB - 5, 5)):
        assert np.array_equal(store.fetch_range(bp, off, ln),
                              whole[off:off + ln]), (off, ln)
    # distinct blocks and files produce distinct content
    assert not np.array_equal(store.fetch_block(block_key(f.path, 0), 256),
                              store.fetch_block(bp, 256))
    other = store.datasets["big"].files[1]
    assert not np.array_equal(
        store.fetch_block(block_key(other.path, 1), 256),
        store.fetch_block(bp, 256))
    # deterministic across store instances (the seed cache is pure)
    fresh = RemoteStore()
    assert np.array_equal(fresh.fetch_range(bp, 100, 100),
                          store.fetch_range(bp, 100, 100))
    # file-path and block-path addressing are coherent (one content
    # stream per file, like the real stores)
    assert np.array_equal(store.fetch_range(f.path, 4 * MB + 100, 16),
                          store.fetch_range(bp, 100, 16))
    # fetch_many preserves request order
    reqs = [(bp, 5, 10), (block_key(f.path, 0), 0, 10), (bp, 0, 10)]
    got = store.fetch_many(reqs)
    for (p, o, n), data in zip(reqs, got):
        assert np.array_equal(data, store.fetch_range(p, o, n))
    assert store.capabilities().ranges


# ---------------------------------------------------------------------------
# LocalFSStore round-trip (satellite)
# ---------------------------------------------------------------------------

def _make_tree(root):
    """Real directory tree: two 'datasets', nested dirs, multi-block and
    tail-odd file sizes (block_size=4096 in the tests below)."""
    rng = np.random.default_rng(42)
    layout = {
        ("alpha", "a.bin"): 10_000,        # 3 blocks, short tail
        ("alpha", "sub", "b.bin"): 4096,   # exactly one block
        ("alpha", "sub", "c.bin"): 100,    # sub-block file
        ("beta", "d.bin"): 13_000,
    }
    contents = {}
    for rel, size in layout.items():
        fs = os.path.join(str(root), *rel)
        os.makedirs(os.path.dirname(fs), exist_ok=True)
        data = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
        with open(fs, "wb") as f:
            f.write(data)
        contents[rel] = data
    return contents


def test_local_fs_meta_matches_real_tree(tmp_path):
    contents = _make_tree(tmp_path)
    store = LocalFSStore(str(tmp_path), block_size=4096)
    # listings: sorted names, dirs and files interleaved
    assert store.listing(()) == ["alpha", "beta"]
    assert store.listing(("alpha",)) == ["a.bin", "sub"]
    assert store.child_index(("alpha",), "sub") == 1
    assert store.is_file(("alpha", "a.bin"))
    assert not store.is_file(("alpha", "sub"))
    # sizes and subtree totals agree with the filesystem
    for rel, data in contents.items():
        assert store.file_size(rel) == len(data)
    assert store.subtree_bytes(()) == sum(map(len, contents.values()))
    assert store.subtree_bytes(("alpha",)) == 10_000 + 4096 + 100
    # block enumeration covers every byte exactly once
    keys = list(store.iter_block_keys(("alpha",)))
    assert sum(sz for _, sz in keys) == store.subtree_bytes(("alpha",))
    assert (block_key(("alpha", "a.bin"), 2), 10_000 - 8192) in keys
    # flat index spans the dataset
    ordinal, total = store.flat_block_index(("alpha", "sub", "b.bin"), 0)
    assert 0 <= ordinal < total == 3 + 1 + 1


def test_local_fs_serves_real_bytes(tmp_path):
    contents = _make_tree(tmp_path)
    store = LocalFSStore(str(tmp_path), block_size=4096)
    data = contents[("alpha", "a.bin")]
    # ranged reads address block-relative offsets
    got = store.fetch_range(block_key(("alpha", "a.bin"), 2), 10, 100)
    assert bytes(got) == data[8192 + 10:8192 + 110]
    # file-path addressing works too
    assert bytes(store.fetch_range(("alpha", "a.bin"), 0, 64)) == data[:64]
    # batched fetch groups by file, results in request order
    reqs = [(block_key(("beta", "d.bin"), 1), 0, 50),
            (("alpha", "sub", "c.bin"), 5, 20),
            (block_key(("beta", "d.bin"), 0), 100, 10)]
    got = store.fetch_many(reqs)
    assert bytes(got[0]) == contents[("beta", "d.bin")][4096:4146]
    assert bytes(got[1]) == contents[("alpha", "sub", "c.bin")][5:25]
    assert bytes(got[2]) == contents[("beta", "d.bin")][100:110]
    # error taxonomy: missing file is permanent, bad components rejected
    with pytest.raises(StoreError):
        store.fetch_range(("alpha", "missing.bin"), 0, 1)
    with pytest.raises(StoreError):
        store.fetch_range(("..", "escape"), 0, 1)
    caps = store.capabilities()
    assert caps.ranges and caps.batching


@pytest.mark.parametrize("executor", ["sim", "threaded"])
def test_local_fs_end_to_end_open_cache(tmp_path, executor):
    """Acceptance: open_cache over a real directory → read(fetch=True)
    returns the on-disk bytes, second pass is served as cache hits —
    under both the inline SimExecutor and the ThreadedExecutor."""
    contents = _make_tree(tmp_path)
    cfg = CacheConfig(min_share=64 * 1024, rebalance_quantum=64 * 1024,
                      block_size=4096, window=40, reanalyze_every=20)
    client = open_cache(f"file://{tmp_path}", 8 * MB, cfg=cfg,
                        executor=executor, fetch_bytes=True)
    assert isinstance(client.meta, LocalFSStore)
    assert client.meta.block_size == 4096      # synced from cfg
    try:
        t = 0.0
        for rel, data in sorted(contents.items()):
            res = client.read(rel, 0, len(data), t)
            assert bytes(res.data) == data, rel
            t += 0.01
        # partial-extent read: exact sub-range, spanning a block boundary
        res = client.read(("alpha", "a.bin"), 4000, 300, t)
        assert bytes(res.data) == contents[("alpha", "a.bin")][4000:4300]
        # second pass: all hits, identical bytes
        for rel, data in sorted(contents.items()):
            res = client.read(rel, 0, len(data), t)
            assert all(b.hit for b in res.blocks), rel
            assert bytes(res.data) == data, rel
            t += 0.01
        # batched read with a mix of hits and fresh misses
        batch = [(("alpha", "a.bin"), 0, 10_000),
                 (("beta", "d.bin"), 4096, 4096)]
        results = client.read_batch(batch, t, fetch=True)
        assert bytes(results[0].data) == contents[("alpha", "a.bin")]
        assert bytes(results[1].data) == \
            contents[("beta", "d.bin")][4096:8192]
        assert client.flush(timeout=10.0)
    finally:
        client.close()
    st = client.executor.stats
    assert st.completed + st.cancelled + st.deduped == st.submitted
    assert st.fetch_errors == 0


# ---------------------------------------------------------------------------
# mem:// store
# ---------------------------------------------------------------------------

def test_mem_store_roundtrip_and_client():
    store = MemStore(block_size=1024)
    payload = bytes(range(256)) * 20        # 5120 bytes = 5 blocks
    store.add_file(("ds", "x.bin"), payload)
    store.add_file(("ds", "y.bin"), b"tiny")
    assert store.listing(()) == ["ds"]
    assert store.listing(("ds",)) == ["x.bin", "y.bin"]
    assert store.file_size(("ds", "x.bin")) == 5120
    assert bytes(store.fetch_range(block_key(("ds", "x.bin"), 1), 10, 20)) \
        == payload[1034:1054]
    with pytest.raises(StoreError):
        store.fetch_range(("ds", "x.bin"), 5000, 1000)   # past the end
    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=1024, window=40)
    client = open_cache(store, 4 * MB, cfg=cfg, fetch_bytes=True)
    res = client.read(("ds", "x.bin"), 100, 2000, 1.0)
    assert bytes(res.data) == payload[100:2100]


# ---------------------------------------------------------------------------
# legacy shim
# ---------------------------------------------------------------------------

class _OneMethodStore:
    """A third-party PR-3 style store: fetch_block only."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = []

    def fetch_block(self, path, size):
        self.calls.append((path, size))
        return self.inner.fetch_block(path, size)


def test_legacy_fetch_block_store_adapts():
    store = RemoteStore()
    store.add(make_dataset("big", "big_files", n_files=1, file_size=8 * MB))
    legacy = _OneMethodStore(store)
    adapted = as_backing_store(legacy)
    assert isinstance(adapted, LegacyStoreAdapter)
    assert adapted.capabilities() == StoreCapabilities(
        ranges=False, batching=False, concurrency=1)
    bp = block_key(store.datasets["big"].files[0].path, 0)
    got = adapted.fetch_range(bp, 100, 50)
    assert np.array_equal(got, store.fetch_range(bp, 100, 50))
    # the adapter over-fetched the prefix through the one legacy method
    assert legacy.calls == [(bp, 150)]
    # a v2 store passes through untouched; meta-only objects stay None
    assert as_backing_store(store) is store
    assert as_backing_store(object()) is None
    assert as_backing_store(None) is None


def test_legacy_store_through_client_bytes():
    store = RemoteStore()
    store.add(make_dataset("big", "big_files", n_files=1, file_size=8 * MB))
    legacy = _OneMethodStore(store)
    client = open_cache(store, 64 * MB, cfg=CFG, backing=legacy,
                        fetch_bytes=True)
    f = store.datasets["big"].files[0]
    res = client.read(f.path, 1 * MB, 2 * MB, 1.0)
    ref = np.concatenate([store.fetch_block(block_key(f.path, 0), 4 * MB)])
    assert np.array_equal(res.data, ref[1 * MB:3 * MB])


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

def test_retry_policy_semantics():
    sleeps = []
    policy = RetryPolicy(max_attempts=4, backoff_s=0.01, multiplier=2.0,
                         sleep=sleeps.append)
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise TransientStoreError("blip")
        return "ok"

    retried = []
    assert policy.call(flaky, on_retry=lambda a, e: retried.append(a)) == "ok"
    assert attempts["n"] == 3 and retried == [1, 2]
    assert sleeps == [0.01, 0.02]            # exponential backoff

    def always_transient():
        raise TransientStoreError("down")

    with pytest.raises(TransientStoreError):
        policy.call(always_transient)

    def permanent():
        attempts["n"] += 1
        raise StoreError("gone")

    attempts["n"] = 0
    with pytest.raises(StoreError):
        policy.call(permanent)
    assert attempts["n"] == 1                # no retry on permanent errors


# ---------------------------------------------------------------------------
# fault injection through the client (satellite)
# ---------------------------------------------------------------------------

def _sim_world():
    store = RemoteStore()
    store.add(make_dataset("flat", "flat_files", n_files=120,
                           small_file_size=256 * 1024))
    store.add(make_dataset("big", "big_files", n_files=4, file_size=16 * MB))
    return store


def test_transient_faults_absorbed_with_retry_accounting():
    """Seeded transient faults on the demand path: reads still return
    correct bytes, and the executor's retry counter matches the
    injector's transient count exactly."""
    store = _sim_world()
    faulty = FaultyStore(store, fail_rate=0.3, seed=11)
    retry = RetryPolicy(max_attempts=10, sleep=lambda s: None)
    client = open_cache(store, 128 * MB, cfg=CFG, backing=faulty,
                        retry=retry, fetch_bytes=True, executor="sim")
    f = store.datasets["big"].files[0]
    t = 1.0
    for off in range(0, 8 * MB, 1 * MB):
        res = client.read(f.path, off, 64 * 1024, t)
        ref = store.fetch_range(block_key(f.path, off // (4 * MB)),
                                off % (4 * MB), 64 * 1024)
        assert np.array_equal(res.data, ref)
        t += 0.01
    st = client.executor.stats
    assert st.retries > 0, "a 30% fail rate over 8 fetches must retry"
    assert st.retries == faulty.injected_transient
    assert st.fetch_errors == 0


def test_permanent_failure_no_pending_table_leak():
    """Acceptance for the fault contract: with a permanently failing
    backend, demand reads raise, background candidates are *cancelled*
    (never silently dropped), the executor identity holds, and the
    kernel's pending table is empty after close."""
    store = _sim_world()
    faulty = FaultyStore(store, permanent_rate=1.0, seed=5)
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    ex = ThreadedExecutor(queue_depth=4096, max_fetch_bytes=4096)
    retry = RetryPolicy(max_attempts=3, sleep=lambda s: None)
    client = CacheClient(engine, backing=faulty, executor=ex, retry=retry)
    # a demand read that needs bytes propagates the permanent error
    f = store.datasets["big"].files[0]
    with pytest.raises(StoreError):
        client.read(f.path, 0, 64 * 1024, 0.5, fetch=True)
    assert all(w.is_alive() for w in ex._workers)
    # drive a sequential scan so the kernel issues prefetch candidates;
    # every background fetch fails permanently → cancel, not drop
    t = 1.0
    for fl in store.datasets["flat"].files:
        client.read(fl.path, 0, fl.size, t)
        t += 0.01
    assert client.flush(timeout=15.0)
    client.close()
    st = ex.stats
    assert st.submitted > 0, "scan generated no candidates"
    assert st.cancelled > 0 and st.completed == 0
    assert st.completed + st.cancelled + st.deduped == st.submitted
    assert st.fetch_errors > 0
    assert not engine._pending_prefetch, "pending-table leak"


def test_transient_faults_under_threaded_executor():
    """Background candidates retried through the shard workers; the
    identity and the pending table stay clean under a flaky backend."""
    store = _sim_world()
    faulty = FaultyStore(store, fail_rate=0.4, seed=7)
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    ex = ThreadedExecutor(queue_depth=4096, max_fetch_bytes=2048)
    retry = RetryPolicy(max_attempts=12, sleep=lambda s: None)
    client = CacheClient(engine, backing=faulty, executor=ex, retry=retry)
    t = 1.0
    for fl in store.datasets["flat"].files:
        client.read(fl.path, 0, fl.size, t)
        t += 0.01
    assert client.flush(timeout=20.0)
    client.close()
    st = ex.stats
    assert st.submitted > 0 and st.completed > 0
    assert st.completed + st.cancelled + st.deduped == st.submitted
    assert st.retries > 0
    assert not engine._pending_prefetch


# ---------------------------------------------------------------------------
# batched demand funnel
# ---------------------------------------------------------------------------

class _CountingStore:
    """v2 wrapper counting fetch_many calls and their sizes."""

    def __init__(self, inner):
        self.inner = inner
        self.many_calls = []
        self.lock = threading.Lock()

    def capabilities(self):
        return self.inner.capabilities()

    def fetch_range(self, path, offset, length):
        return self.inner.fetch_range(path, offset, length)

    def fetch_many(self, requests):
        with self.lock:
            self.many_calls.append(len(requests))
        return self.inner.fetch_many(requests)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_read_batch_funnels_demand_through_one_fetch_many():
    store = _sim_world()
    counting = _CountingStore(store)
    client = open_cache(store, 128 * MB, cfg=CFG, backing=counting,
                        fetch_bytes=True, executor="sim")
    f0, f1 = store.datasets["big"].files[:2]
    reqs = [(f0.path, 0, 64 * 1024), (f1.path, 0, 64 * 1024),
            (f0.path, 4 * MB, 64 * 1024)]
    results = client.read_batch(reqs, 1.0)
    for (fp, off, sz), res in zip(reqs, results):
        b = off // (4 * MB)
        ref = store.fetch_range(block_key(fp, b), off % (4 * MB), sz)
        assert np.array_equal(res.data, ref)
    # all three demand misses travelled in ONE batched fetch_many call
    assert counting.many_calls == [3]
    assert client.executor.stats.demand_fetches == 3

"""Kernel oracles vs Pallas (interpret=True) — shape/dtype sweeps."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (decode_attention_ref,
                                           flash_attention_pallas,
                                           flash_attention_ref)
from repro.kernels.rmsnorm import (gated_rmsnorm_ref, rmsnorm_pallas,
                                   rmsnorm_ref)
from repro.kernels.ssd import ssd_chunk_pallas, ssd_decode_ref, ssd_ref
from repro.kernels.ssd.ref import segsum


def naive_attention(q, k, v, causal=True, q_offset=0):
    groups = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, groups, axis=2)
    vv = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        qp = q_offset + jnp.arange(q.shape[1])
        kp = jnp.arange(k.shape[1])
        s = jnp.where((qp[:, None] >= kp[None, :])[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 32),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 192, 6, 1, 64),     # MQA, non-pow2 seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_ref_sweep(B, S, H, KV, hd, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    want = naive_attention(q, k, v)
    got = flash_attention_ref(q, k, v, block_kv=64).astype(jnp.float32)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (1, 256, 4, 2, 64, 128, 128),
    (2, 256, 4, 4, 128, 64, 128),
])
def test_flash_pallas_interpret(B, S, H, KV, hd, bq, bk):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    want = naive_attention(q, k, v)
    got = flash_attention_pallas(q, k, v, block_q=bq, block_kv=bk,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_decode_attention_matches_last_row():
    rng = np.random.default_rng(2)
    B, S, H, KV, hd = 2, 64, 8, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    want = naive_attention(q, k, v)[:, -1:]
    got = decode_attention_ref(q[:, -1:], k, v, kv_len=S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 96), (2, 2, 2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    w = jnp.asarray(rng.normal(size=shape[-1:]), dtype)
    got = rmsnorm_pallas(x, w, interpret=True, block_rows=8)
    want = rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def _ssd_seq_oracle(x, a, B, C):
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_decode_ref(x[:, t], a[:, t], B[:, t], C[:, t], state)
        ys.append(y)
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunked_vs_sequential(chunk):
    rng = np.random.default_rng(4)
    b, s, h, p, n = 2, 64, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32) * 0.5
    a = -jnp.abs(jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32) * 0.5
    Cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32) * 0.5
    y_ref, st_ref = ssd_ref(x, a, Bm, Cm, chunk=chunk)
    y_seq, st_seq = _ssd_seq_oracle(x, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref), np.asarray(st_seq),
                               atol=1e-4)


def test_ssd_pallas_chunk_kernel():
    rng = np.random.default_rng(5)
    b, s, h, p, n = 1, 64, 2, 16, 8
    chunk = 16
    c = s // chunk
    x = jnp.asarray(rng.normal(size=(b, c, chunk, h, p)), jnp.float32) * 0.5
    a = -jnp.abs(jnp.asarray(rng.normal(size=(b, c, chunk, h)),
                             jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.normal(size=(b, c, chunk, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, c, chunk, n)), jnp.float32)
    y, st = ssd_chunk_pallas(x, a, Bm, Cm, interpret=True)
    aT = a.transpose(0, 3, 1, 2)
    L = jnp.exp(segsum(aT))
    y_want = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cm, Bm, L, x)
    acum = jnp.cumsum(aT, -1)
    dec = jnp.exp(acum[..., -1:] - acum)
    st_want = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bm, dec, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_want), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_want), atol=1e-4)


def test_gated_rmsnorm_finite():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    w = jnp.ones((32,), jnp.float32)
    out = gated_rmsnorm_ref(x, g, w)
    assert bool(jnp.isfinite(out).all())

"""Eviction policies: per-policy semantics + capacity-style invariants."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.eviction import (ARC, EagerEviction, FIFO, LFU, LRU, SIEVE,
                                 UniformCache, make_policy)


def test_lru_order():
    p = LRU()
    for k in "abc":
        p.record_insert(k)
    p.record_access("a", hit=True)
    assert p.choose_victim() == "b"
    p.record_remove("b")
    assert p.choose_victim() == "c"


def test_fifo_order():
    p = FIFO()
    for k in "abc":
        p.record_insert(k)
    p.record_access("a", hit=True)      # no effect for FIFO
    assert p.choose_victim() == "a"


def test_lfu_prefers_cold():
    p = LFU()
    for k in "abc":
        p.record_insert(k)
    for _ in range(3):
        p.record_access("a", hit=True)
    p.record_access("b", hit=True)
    assert p.choose_victim() == "c"
    p.record_remove("c")
    assert p.choose_victim() == "b"


def test_uniform_never_evicts_to_admit():
    p = UniformCache()
    for k in "abc":
        p.record_insert(k)
    assert p.choose_victim() is None
    assert p.force_victim() in set("abc")  # only under quota shrink


def test_eager_prefers_consumed_then_newest_unread():
    p = EagerEviction()
    for k in "abcd":
        p.record_insert(k)
    assert p.choose_victim() == "d"          # newest unread
    p.record_access("b", hit=True)
    assert p.choose_victim() == "b"          # consumed first


def test_sieve_second_chance():
    p = SIEVE()
    for k in "abc":
        p.record_insert(k)
    p.record_access("a", hit=True)
    v = p.choose_victim()
    assert v == "b"                          # 'a' got its second chance


def test_arc_adapts_to_frequency():
    p = ARC(capacity=4)
    # fill with one-hit wonders, then re-reference a stable set
    for i in range(4):
        p.record_insert(f"x{i}")
    for i in range(4):
        p.record_access(f"x{i}", hit=True)   # promote to T2
    assert len(p.t2) == 4


@given(st.lists(st.tuples(st.sampled_from("irah"),
                          st.integers(0, 20)), max_size=200),
       st.sampled_from(["lru", "fifo", "lfu", "sieve", "arc", "uniform",
                        "eager"]))
@settings(max_examples=60, deadline=None)
def test_policy_resident_consistency(ops, name):
    """Invariant: victims are always currently-resident keys; resident set
    tracks inserts/removes exactly."""
    p = make_policy(name, capacity_blocks=8)
    resident = set()
    for op, k in ops:
        key = f"k{k}"
        if op == "i" and key not in resident:
            p.record_insert(key)
            resident.add(key)
        elif op == "r" and key in resident:
            p.record_remove(key)
            resident.discard(key)
        elif op == "a" and key in resident:
            p.record_access(key, hit=True)
        elif op == "h":
            v = p.choose_victim()
            if v is not None:
                assert v in resident
    assert p.resident == resident

"""End-to-end behaviour of the paper's system: the unified cache serving a
real JAX training pipeline + the serving engine, plus the headline
adaptivity claims at miniature scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import CacheConfig, IGTCache, bundle
from repro.core.types import MB
from repro.data.pipeline import CachedTokenPipeline, make_token_dataset
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import init_params
from repro.storage import RemoteStore
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.train_step import make_train_step

from conftest import requires_mesh_axis_types


@pytest.fixture(scope="module")
def world():
    store = RemoteStore()
    store.add(make_token_dataset("corpus", n_shards=4, shard_bytes=8 * MB))
    cfg = CacheConfig(min_share=2 * MB, rebalance_quantum=2 * MB,
                      rebalance_period=5.0, block_size=1 * MB)
    return store, cfg


@requires_mesh_axis_types
def test_pipeline_trains_through_cache(world):
    store, ccfg = world
    engine = IGTCache(store, 16 * MB, cfg=ccfg)
    cfg = reduced_config("qwen3-1.7b")
    pipe = CachedTokenPipeline(store, engine, "corpus", seq_len=32, batch=2,
                               vocab=cfg.vocab, background_prefetch=False)
    mesh = make_local_mesh()
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=5e-3, warmup_steps=2,
                                                    total_steps=100),
                                   mesh, None, remat="none"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_state(params)
    losses = []
    it = pipe.batches(epochs=3)
    for i, b in enumerate(it):
        if i >= 12:
            break
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]            # it learns something
    assert pipe.stats.batches >= 12
    pipe.close()


def test_pipeline_epoch2_hits_cache(world):
    store, ccfg = world
    engine = IGTCache(store, 64 * MB, cfg=ccfg)   # corpus (32MB) fits
    pipe = CachedTokenPipeline(store, engine, "corpus", seq_len=32, batch=4,
                               vocab=1000, background_prefetch=False)
    n = len(pipe._samples) // 4
    it = pipe.batches(epochs=2)
    for i, _ in enumerate(it):
        if i >= 2 * n - 1:
            break
    assert engine.hit_ratio() > 0.45          # epoch 2 ~fully cached
    pipe.close()


def test_serving_engine_with_rag_cache(world):
    from repro.serve.engine import Request, ServingEngine
    from repro.storage import make_dataset
    store, ccfg = world
    store.add(make_dataset("knowledge", "flat_files", n_files=200,
                           small_file_size=64 * 1024))
    engine = IGTCache(store, 8 * MB, cfg=ccfg)
    cfg = reduced_config("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = ServingEngine(params, cfg, batch=2, max_seq=64,
                        cache_engine=engine, knowledge_dataset="knowledge",
                        retrieval_k=3)
    rng = np.random.default_rng(0)
    for rid in range(6):
        srv.submit(Request(rid, rng.integers(0, cfg.vocab, 4,
                                             dtype=np.int32), max_new=4))
    done = srv.run(max_steps=200)
    assert len(done) == 6
    assert all(len(r.output) == 4 for r in done)
    assert engine.stats.accesses > 0          # retrieval went through cache


def test_adaptive_beats_fixed_on_mixed_traffic(world):
    """The paper's core claim in miniature: adaptivity wins when sequential +
    random streams share one cache."""
    from repro.storage import make_dataset
    store = RemoteStore()
    store.add(make_dataset("scan", "flat_files", n_files=600,
                           small_file_size=128 * 1024))
    store.add(make_dataset("train", "flat_files", n_files=300,
                           small_file_size=128 * 1024))
    ccfg = CacheConfig(min_share=2 * MB, rebalance_quantum=2 * MB,
                       rebalance_period=2.0)
    import random as _r

    def run(name):
        eng = IGTCache(store, 24 * MB, cfg=ccfg, options=bundle(name))
        rng = _r.Random(0)
        scan_files = store.datasets["scan"].files
        train_files = store.datasets["train"].files
        t = 0.0
        si = 0
        for epoch in range(3):
            order = list(range(len(train_files)))
            rng.shuffle(order)
            for j in order:
                for f in (scan_files[si % len(scan_files)], train_files[j]):
                    out = eng.read(f.path, 0, f.size, t)
                    for pth, sz in out.prefetches:
                        eng.complete_prefetch(pth, sz, t)
                    t += 0.01
                si += 1
        return eng.hit_ratio()

    assert run("igtcache") > run("juicefs")

"""End-to-end IGTCache engine behaviour on controlled access streams."""
import random

import numpy as np
import pytest

from repro.core import CacheConfig, IGTCache, Pattern, bundle
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset

CFG = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB,
                  rebalance_period=5.0,
                  prefetch_budget_bytes=64 * MB)


def mk_store():
    store = RemoteStore()
    store.add(make_dataset("seqset", "flat_files", n_files=800,
                           small_file_size=256 * 1024))
    store.add(make_dataset("randset", "dir_tree", n_dirs=40, files_per_dir=20,
                           small_file_size=256 * 1024))
    store.add(make_dataset("bigfiles", "big_files", n_files=60,
                           file_size=16 * MB))
    return store


def drain(eng, out, t):
    for p, s in out.prefetches:
        eng.complete_prefetch(p, s, t)


def test_sequential_stream_prefetch_hits():
    store = mk_store()
    eng = IGTCache(store, 256 * MB, cfg=CFG)
    ds = store.datasets["seqset"]
    t = 0.0
    for f in ds.files:
        out = eng.read(f.path, 0, f.size, t)
        drain(eng, out, t)
        t += 0.05
    anchor = eng.tree.shallowest_non_trivial(ds.files[0].path)
    assert anchor.pattern.pattern is Pattern.SEQUENTIAL
    s = eng.snapshot()
    # after the 100-access window everything should be prefetched ahead
    assert s["hit_ratio"] > 0.7
    assert s["prefetch_hits"] > 500


def test_random_stream_uniform_and_statistical_prefetch():
    store = mk_store()
    eng = IGTCache(store, 512 * MB, cfg=CFG)   # dataset 200MB fits
    ds = store.datasets["randset"]
    files = list(ds.files)
    rng = random.Random(0)
    t = 0.0
    for epoch in range(2):
        order = list(range(len(files)))
        rng.shuffle(order)
        for i in order:
            out = eng.read(files[i].path, 0, files[i].size, t)
            drain(eng, out, t)
            t += 0.01
    cmu = eng.cache.cmus.get(("randset",))
    assert cmu is not None
    assert cmu.effective_pattern() is Pattern.RANDOM
    assert eng.snapshot()["hit_ratio"] > 0.8     # stat prefetch + pinning


def test_skewed_stream_lru():
    store = mk_store()
    eng = IGTCache(store, 64 * MB, cfg=CFG)
    ds = store.datasets["randset"]
    files = list(ds.files)
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(files))
    t = 0.0
    for _ in range(3000):
        i = int(perm[(rng.zipf(1.4) - 1) % len(files)])
        out = eng.read(files[i].path, 0, files[i].size, t)
        drain(eng, out, t)
        t += 0.01
    cmu = eng.cache.cmus.get(("randset",))
    assert cmu.effective_pattern() is Pattern.SKEWED
    assert eng.snapshot()["hit_ratio"] > 0.6


def test_block_level_readahead_big_files():
    store = mk_store()
    eng = IGTCache(store, 256 * MB, cfg=CFG)
    ds = store.datasets["bigfiles"]
    t = 0.0
    bs = CFG.block_size
    for f in ds.files:
        for b in range(f.size // bs):
            out = eng.read(f.path, b * bs, bs, t)
            drain(eng, out, t)
            t += 0.02
    # ~100-access warm-up window misses; the rest should be prefetched
    assert eng.snapshot()["hit_ratio"] > 0.4
    assert eng.stats.prefetch_hits > 80


def test_baseline_bundles_differ():
    store = mk_store()
    ds = store.datasets["seqset"]

    def run(name):
        eng = IGTCache(store, 128 * MB, cfg=CFG, options=bundle(name))
        t = 0.0
        for f in ds.files:
            out = eng.read(f.path, 0, f.size, t)
            drain(eng, out, t)
            t += 0.05
        return eng.snapshot()["hit_ratio"]

    igt = run("igtcache")
    none = run("prefetch_none")
    assert igt > none + 0.3     # file-level prefetch vs nothing


def test_no_cache_capacity_zero():
    store = mk_store()
    eng = IGTCache(store, 0, cfg=CFG, options=bundle("prefetch_none"))
    ds = store.datasets["seqset"]
    for i, f in enumerate(ds.files[:200]):
        eng.read(f.path, 0, f.size, float(i))
    assert eng.snapshot()["hit_ratio"] == 0.0

"""Logical sharding rules: mapping, divisibility fallback, duplicates."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.models.params import p, tree_abstract, tree_init
from repro.sharding import DEFAULT_RULES, apply_rules, shardings_for
from repro.sharding.context import constrain, sharding_ctx
from conftest import requires_mesh_axis_types

pytestmark = requires_mesh_axis_types


def test_apply_rules_local_mesh_all_replicated_when_indivisible():
    mesh = make_local_mesh()
    spec = apply_rules(("embed", "heads"), (7, 13), mesh)
    # axes of size 1 divide everything; spec may name them — sizes are 1
    for s in spec:
        if s is not None:
            assert all(mesh.shape[a] == 1 for a in
                       ((s,) if isinstance(s, str) else s))


def test_divisibility_fallback():
    import numpy as np
    devs = np.array(jax.devices() * 1)  # 1 device
    mesh = make_local_mesh()
    # dim 6 % 4 != 0 on a 4-wide axis → dropped; emulate via fake shape calc
    spec = apply_rules(("kv_heads",), (6,), mesh)
    assert isinstance(spec, P)


def test_duplicate_axis_not_reused():
    mesh = make_local_mesh()
    spec = apply_rules(("heads", "act_heads"), (4, 4), mesh)
    named = [s for s in spec if s is not None]
    flat = []
    for s in named:
        flat.extend((s,) if isinstance(s, str) else s)
    assert len(flat) == len(set(flat))


def test_shardings_for_paramspec_tree():
    mesh = make_local_mesh()
    specs = {"w": p((8, 16), ("embed", "ffn")),
             "b": p((16,), ("ffn",), init="zeros")}
    sh = shardings_for(specs, mesh)
    assert sh["w"].mesh == mesh


def test_constrain_noop_outside_ctx():
    x = jax.numpy.ones((4, 4))
    y = constrain(x, ("batch", "act_embed"))
    assert (y == x).all()


def test_constrain_inside_ctx():
    mesh = make_local_mesh()
    x = jax.numpy.ones((4, 4))
    with sharding_ctx(mesh, None):
        y = constrain(x, ("batch", "act_embed"))
    assert (y == x).all()


def test_tree_init_matches_abstract():
    specs = {"w": p((4, 6), ("embed", "ffn")),
             "n": p((6,), ("norm",), init="ones")}
    ab = tree_abstract(specs)
    real = tree_init(specs, jax.random.PRNGKey(0))
    assert ab["w"].shape == real["w"].shape
    assert ab["w"].dtype == real["w"].dtype
    assert float(real["n"].sum()) == 6.0

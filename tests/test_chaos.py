"""Fault matrix for the fault-tolerant cache runtime (the chaos tentpole).

Every scenario the ISSUE pins, as seeded, count-driven chaos runs:

  * worker SIGKILL mid ``read_batch`` → typed partial error, degraded
    direct-store reads with byte-exact results, supervised respawn;
  * kill with a prefetch batch in flight → executor conservation
    identity (``submitted == completed + cancelled + deduped``) survives
    the drain;
  * kill during a rebalance round → cluster capacity stays conserved
    with the dead shard's share frozen;
  * store hang hitting the retry deadline (client side) and the RPC
    deadline (worker side — hung worker killed and respawned, reader
    served from the store);
  * restart-budget exhaustion → permanent DOWN, reads keep flowing
    degraded;
  * SIGSTOP wedge → heartbeat stall detection kills and respawns;
  * chaos e2e: mixed-workload cluster sim loses a worker mid-trace —
    the run completes with zero hung or errored reads and the windowed
    post-recovery CHR lands within 5 % of the fault-free run.

Every test runs under a hard SIGALRM guard: "no hung calls" is asserted
by the alarm, not hoped for.  The fast subset is marked ``chaos`` (tier-1
default); the extended seeded sweep is ``chaos_full`` (opt-in).
"""
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import CacheConfig, open_cache
from repro.core.client import CacheClient
from repro.core.faults import SHARD_DOWN, SHARD_UP, ShardUnavailableError
from repro.core.procdriver import ProcessExecutor, ProcessShardedCache
from repro.core.types import MB
from repro.sim import ChaosMonkey, ChaosSchedule, ClusterSim, plan_strikes
from repro.sim.workloads import make_paper_suite
from repro.storage import MemStore, RemoteStore, RetryPolicy, make_dataset
from repro.storage.api import DeadlineError, FaultyStore

pytestmark = pytest.mark.chaos

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  window=40, reanalyze_every=20, node_cap=500)

HARD_TIMEOUT_S = 180


@pytest.fixture(autouse=True)
def hard_timeout():
    """Chaos tests must never hang tier-1: a lost reply, a stuck respawn
    or an unreleased SIGSTOP raises here instead of wedging the job."""

    def boom(signum, frame):  # pragma: no cover - only fires on deadlock
        raise TimeoutError(
            f"chaos test exceeded the {HARD_TIMEOUT_S}s hard timeout "
            f"(hung call / lost reply / stuck respawn?)")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def mk_byte_world(n_jobs=6, file_bytes=3 * MB + 12345, seed=0):
    """MemStore with real payloads under distinct top-level dirs.  Shard
    routing hashes the top-level component: with 2 shards, job0-3 land
    on one and job4-5 on the other, so batches genuinely span shards."""
    store = MemStore(block_size=1 * MB)
    rng = np.random.default_rng(seed)
    payloads = {}
    for j in range(n_jobs):
        p = (f"job{j}", "data")
        data = rng.integers(0, 256, size=file_bytes, dtype=np.uint8)
        store.add_file(p, data)
        payloads[p] = data
    return store, payloads


def wait_all_up(client, timeout=20.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if all(s == SHARD_UP for s in client.shard_states()):
            return True
        time.sleep(0.02)
    return False


def wait_event(client, kind, timeout=20.0):
    """Poll the fault log for an event kind.  Needed because the
    supervisor flips the shard to UP *before* appending the respawn
    event (the recovery stamp covers the control replay too), so
    wait_all_up can win the race against the log append."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        evs = [e for e in client.fault_stats()["events"]
               if e["kind"] == kind]
        if evs:
            return evs
        time.sleep(0.02)
    return []


def executor_identity(st):
    return st.completed + st.cancelled + st.deduped


def assert_identity(client):
    st = client.executor.stats
    assert st.submitted == executor_identity(st), (
        f"lost candidates: submitted={st.submitted} "
        f"completed={st.completed} cancelled={st.cancelled} "
        f"deduped={st.deduped}")


# ---------------------------------------------------------------------------
# kill mid read_batch: degraded bytes, typed partial error, respawn
# ---------------------------------------------------------------------------

def test_kill_mid_read_batch_serves_degraded_bytes_and_recovers():
    store, payloads = mk_byte_world()
    with open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                    arena_bytes=16 * MB, fetch_bytes=True,
                    rpc_timeout_s=10.0) as c:
        reqs = [((f"job{j}", "data"), 0, 2 * MB) for j in range(6)]
        c.read_batch(reqs)                         # warm both shards
        target = c.engine.shard_id(("job0", "data"))
        monkey = ChaosMonkey(c)
        monkey.kill(target)
        # the very next batch hits the dead shard: the client must still
        # hand back byte-exact results for every request
        outs = c.read_batch(reqs)
        for (p, off, sz), r in zip(reqs, outs):
            assert bytes(r.data) == bytes(payloads[p][off:off + sz])
        assert c.client_stats.degraded_reads > 0
        assert c.client_stats.degraded_bytes > 0
        # supervisor brings the shard back within budget
        assert wait_all_up(c), f"states: {c.shard_states()}"
        assert any(e["kind"] == "kill" for e in c.fault_stats()["events"])
        respawns = wait_event(c, "respawn")
        assert respawns, "no respawn event after recovery"
        assert respawns[0]["recovery_s"] > 0
        # post-recovery reads go through the (cold) kernel again
        r = c.read(("job0", "data"), 512, 1 * MB)
        assert bytes(r.data) == \
            bytes(payloads[("job0", "data")][512:512 + 1 * MB])
        assert_identity(c)


def test_kill_without_degraded_mode_raises_typed_partial_error():
    store, _ = mk_byte_world()
    with open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                    arena_bytes=16 * MB, fetch_bytes=True, degraded=False,
                    rpc_timeout_s=10.0) as c:
        reqs = [((f"job{j}", "data"), 0, 1 * MB) for j in range(6)]
        c.read_batch(reqs)
        target = c.engine.shard_id(("job0", "data"))
        ChaosMonkey(c).kill(target)
        with pytest.raises(ShardUnavailableError) as ei:
            c.read_batch(reqs)
        e = ei.value
        # the error carries the healthy shards' outcomes + the holes
        assert e.indices, "partial error names no failed positions"
        assert e.partial is not None and len(e.partial) == len(reqs)
        served = sum(1 for o in e.partial if o is not None)
        assert served + len(e.indices) == len(reqs)
        assert served > 0, "surviving shard's outcomes were dropped"
        wait_all_up(c)


# ---------------------------------------------------------------------------
# kill with in-flight prefetch batch: conservation identity survives
# ---------------------------------------------------------------------------

def test_kill_with_inflight_prefetch_batch_conserves_candidates():
    store = RemoteStore()
    for name in ("flat0", "flat1"):
        store.add(make_dataset(name, "flat_files", n_files=120,
                               small_file_size=256 * 1024))
    with open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                    rpc_timeout_s=10.0) as c:
        files = [f for ds in store.datasets.values() for f in ds.files]
        t = 0.0
        killed = False
        for i, f in enumerate(files):           # sequential scans →
            c.read(f.path, 0, f.size, t)        # readahead candidates
            t += 0.01
            if i == 80 and not killed:
                # strike while the coalesced prefetch pump has batches
                # in flight on both channels
                ChaosMonkey(c).kill(c.engine.shard_id(f.path))
                killed = True
        assert killed
        wait_all_up(c)
        c.flush(timeout=30.0)
        st = c.executor.stats
        assert st.submitted > 0, "trace produced no prefetch candidates"
    # close() drained everything; no candidate may be lost or double-done
    assert st.submitted == executor_identity(st), (
        f"submitted={st.submitted} completed={st.completed} "
        f"cancelled={st.cancelled} deduped={st.deduped}")


# ---------------------------------------------------------------------------
# kill during rebalance: capacity conservation with a frozen shard
# ---------------------------------------------------------------------------

def test_kill_during_rebalance_round_conserves_capacity():
    store, _ = mk_byte_world(n_jobs=6, file_bytes=2 * MB)
    cap = 64 * MB
    with open_cache(store, cap, cfg=CFG, driver="process", n_procs=2,
                    rpc_timeout_s=10.0) as c:
        d = c.engine
        assert sum(d.shard_capacities()) == cap
        # skew demand so the rebalancer has moves to plan
        t = 0.0
        for rep in range(3):
            for j in range(6):
                c.read((f"job{j}", "data"), 0, 2 * MB, t)
                t += 0.05
        ChaosMonkey(c).kill(0)
        moved = d.rebalance_now(t)              # dead shard mid-round
        caps = d.shard_capacities()
        assert sum(caps) == cap, (
            f"capacity leaked in a faulted rebalance: {caps} (moved "
            f"{moved} quanta)")
        wait_all_up(c)
        # post-recovery round still conserves
        d.rebalance_now(t + 100.0)
        assert sum(d.shard_capacities()) == cap


# ---------------------------------------------------------------------------
# store hang: client-side retry deadline, worker-side RPC deadline
# ---------------------------------------------------------------------------

def test_store_hang_hits_retry_deadline():
    """An endlessly-flaky, hanging store costs a *bounded* wait: the
    retry deadline converts the stall into DeadlineError instead of
    sleeping through the full backoff ladder."""
    inner = MemStore(block_size=1 * MB)
    inner.add_file(("a", "f"), np.zeros(1 * MB, dtype=np.uint8))
    flaky = FaultyStore(inner, fail_rate=1.0, hang_rate=1.0, hang_s=0.05,
                        seed=3)
    pol = RetryPolicy(max_attempts=100, backoff_s=0.01,
                      deadline_s=0.25)
    t0 = time.monotonic()
    with pytest.raises(DeadlineError):
        pol.call(flaky.fetch_range, ("a", "f#b0"), 0, 1024)
    assert time.monotonic() - t0 < 5.0, "deadline did not bound the wait"


def test_worker_store_hang_trips_rpc_deadline_and_degrades():
    """A worker whose backing store hangs past ``rpc_timeout_s`` is
    killed and respawned; the blocked reader is served from the store
    directly — bytes arrive, nothing hangs."""
    store, payloads = mk_byte_world(n_jobs=2)
    # the *workers* fetch through a hanging store; the client's degraded
    # path fetches from the pristine one (open_cache shares one backing,
    # so wire the two layers by hand)
    hang = FaultyStore(store, hang_rate=1.0, hang_s=30.0, seed=1)
    eng = ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=2,
                              arena_bytes=16 * MB, backing=hang,
                              rpc_timeout_s=1.0)
    try:
        c = CacheClient(eng, backing=store, executor=ProcessExecutor(),
                        fetch_bytes=True)
        # the worker-side store is the hanging one: its fetch RPC must
        # blow the 1 s deadline, not wedge the reader for 30 s
        p = ("job0", "data")
        t0 = time.monotonic()
        r = c.read(p, 0, 1 * MB)
        elapsed = time.monotonic() - t0
        assert bytes(r.data) == bytes(payloads[p][:1 * MB])
        assert elapsed < 30.0, "reader waited out the full store hang"
        fs = c.fault_stats()
        assert any(e["kind"] == "kill" for e in fs["events"]), (
            "hung fetch did not trip the RPC deadline")
        assert (c.client_stats.fallback_fetches > 0
                or c.client_stats.degraded_reads > 0)
        wait_all_up(c)
        c.close()
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# restart budget exhaustion: permanent DOWN, reads keep flowing
# ---------------------------------------------------------------------------

def test_budget_exhaustion_marks_shard_down_but_reads_flow():
    store, payloads = mk_byte_world()
    with open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                    arena_bytes=16 * MB, fetch_bytes=True,
                    restart_budget=2, restart_window_s=300.0,
                    rpc_timeout_s=10.0) as c:
        target = c.engine.shard_id(("job0", "data"))
        monkey = ChaosMonkey(c)

        def shard(c):
            return c.fault_stats()["shards"][target]

        for _ in range(3):                      # budget is 2: third kill
            wait_all_up(c, timeout=20.0)        # is the permanent one
            if c.shard_states()[target] == SHARD_DOWN:
                break
            gen0 = shard(c)["generation"]
            monkey.kill(target)
            # wait until this kill is *registered* (respawn bumps the
            # generation, or the budget marks the shard down) — a kill
            # fired before the previous death is even noticed would be a
            # no-op on an already-dead process
            deadline = time.monotonic() + 20.0
            while time.monotonic() < deadline:
                s = shard(c)
                if s["generation"] > gen0 or s["state"] == SHARD_DOWN:
                    break
                time.sleep(0.02)
        deadline = time.monotonic() + 20.0
        while (c.shard_states()[target] != SHARD_DOWN
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert c.shard_states()[target] == SHARD_DOWN
        assert any(e["kind"] == "down" for e in c.fault_stats()["events"])
        # capacity total is conserved with the shard permanently out
        assert sum(c.engine.shard_capacities()) == 64 * MB
        # every key still reads correctly — dead shard's keys degraded,
        # surviving shard's keys through its kernel
        reqs = [((f"job{j}", "data"), 0, 2 * MB) for j in range(6)]
        for rep in range(2):
            outs = c.read_batch(reqs)
            for (p, off, sz), r in zip(reqs, outs):
                assert bytes(r.data) == bytes(payloads[p][off:off + sz])
        assert c.client_stats.degraded_reads > 0


# ---------------------------------------------------------------------------
# SIGSTOP wedge: heartbeat stall detection
# ---------------------------------------------------------------------------

def test_suspended_worker_detected_by_heartbeat_and_respawned():
    store, payloads = mk_byte_world(n_jobs=2)
    with open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                    arena_bytes=16 * MB, fetch_bytes=True,
                    heartbeat_s=1.0, rpc_timeout_s=20.0) as c:
        monkey = ChaosMonkey(c)
        try:
            p = ("job0", "data")
            c.read(p, 0, 1 * MB)                # channel warm + beating
            target = c.engine.shard_id(p)
            monkey.suspend(target)
            # the wedged worker holds the pipe open — only the heartbeat
            # can notice.  The read blocks until the supervisor kills the
            # stalled worker, then degrades; it must NOT wait rpc_timeout.
            t0 = time.monotonic()
            r = c.read(p, 0, 1 * MB)
            elapsed = time.monotonic() - t0
            assert bytes(r.data) == bytes(payloads[p][:1 * MB])
            assert elapsed < 15.0
            assert any(e["kind"] == "kill" for e in
                       c.fault_stats()["events"])
            assert wait_all_up(c)
            r = c.read(p, 0, 1 * MB)            # respawned kernel serves
            assert bytes(r.data) == bytes(payloads[p][:1 * MB])
        finally:
            monkey.resume_all()


# ---------------------------------------------------------------------------
# chaos e2e: mixed cluster sim loses a worker mid-trace
# ---------------------------------------------------------------------------

def _sim_world():
    suite = make_paper_suite(scale=0.15, seed=0, job_filter=[2, 8, 9])
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())
    return suite, store, cap


def _run_sim(suite, store, cap, chaos_events=(), probes=()):
    client = open_cache(store, cap, cfg=CFG, driver="process", n_procs=2,
                        rpc_timeout_s=15.0)
    try:
        sim = ClusterSim(suite, client, chaos_events=list(chaos_events))
        snaps = {}
        for name, t in probes:
            sim.at(t, lambda s, name=name:
                   snaps.__setitem__(name, s.engine.stats.snapshot()))
        res = sim.run()
        snaps["end"] = client.stats.snapshot()
        return res, snaps, client.fault_stats(), \
            client.client_stats.snapshot()
    finally:
        client.close()


def _window_chr(snaps, start_key):
    s0, s1 = snaps[start_key], snaps["end"]
    hits = s1["hits"] - s0["hits"]
    total = hits + s1["misses"] - s0["misses"]
    return hits / total if total else 0.0


def test_chaos_e2e_cluster_sim_survives_worker_kill():
    """Acceptance: kill a shard worker mid-trace on the mixed cluster
    sim.  The run completes (SIGALRM guards against hangs) with zero
    errored reads, the shard respawns within budget, and windowed
    post-recovery CHR lands within 5 % of the fault-free run."""
    suite, store, cap = _sim_world()
    base_res, base_snaps, _, _ = _run_sim(suite, store, cap)
    assert base_res.jct, "baseline sim completed no jobs"
    kill_at = base_res.makespan / 3.0
    window_from = 2.0 * base_res.makespan / 3.0
    probes = [("w", window_from)]

    suite2, store2, cap2 = _sim_world()
    # re-probe the baseline at the same virtual time for the window
    base_res2, base_snaps2, _, _ = _run_sim(suite, store, cap,
                                            probes=probes)
    res, snaps, fault, cstats = _run_sim(
        suite2, store2, cap2,
        chaos_events=[(kill_at, "kill", 0)], probes=probes)

    # completed with the same job set, nothing hung or errored
    assert set(res.jct) == set(base_res2.jct)
    assert res.chaos_log and res.chaos_log[0]["kind"] == "kill"
    # the worker came back within the restart budget
    assert any(e["kind"] == "respawn" for e in fault["events"])
    assert all(s["state"] == SHARD_UP for s in fault["shards"].values())
    # degraded reads happened while the shard was out — and every one of
    # them returned an outcome instead of raising into the sim loop
    assert cstats["degraded_reads"] >= 0
    # post-recovery convergence: windowed CHR within 5 % of fault-free
    chr_base = _window_chr(base_snaps2, "w")
    chr_chaos = _window_chr(snaps, "w")
    assert abs(chr_base - chr_chaos) <= 0.05, (
        f"post-recovery CHR diverged: base={chr_base:.4f} "
        f"chaos={chr_chaos:.4f}")


# ---------------------------------------------------------------------------
# extended seeded matrix (opt-in: pytest -m chaos_full)
# ---------------------------------------------------------------------------

@pytest.mark.chaos_full
@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_full_matrix_seeded_strikes(seed):
    """Randomized-but-reproducible sweep: a planned schedule of kills
    and suspends lands mid-trace; every read stays byte-exact, nothing
    hangs, and the executor identity holds at close."""
    store, payloads = mk_byte_world(n_jobs=6, file_bytes=2 * MB, seed=seed)
    n_steps = 40
    with open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                    arena_bytes=16 * MB, fetch_bytes=True,
                    heartbeat_s=1.0, rpc_timeout_s=10.0,
                    restart_budget=10, restart_window_s=300.0) as c:
        monkey = ChaosMonkey(c)
        sched = ChaosSchedule(monkey, plan_strikes(
            n_steps, n_shards=2, seed=seed, n_strikes=3,
            kinds=("kill", "suspend")))
        rng = np.random.default_rng(seed)
        try:
            for i in range(n_steps):
                sched.on_step(i)
                picks = rng.integers(0, 6, 4)
                reqs = [((f"job{int(j)}", "data"), 0, 1 * MB)
                        for j in picks]
                outs = c.read_batch(reqs)
                for (p, off, sz), r in zip(reqs, outs):
                    assert bytes(r.data) == \
                        bytes(payloads[p][off:off + sz]), \
                        f"step {i}: wrong bytes for {p}"
        finally:
            sched.close()
        assert sched.fired, "schedule fired no strikes"
        wait_all_up(c, timeout=30.0)
        c.flush(timeout=30.0)
        assert_identity(c)

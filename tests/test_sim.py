"""Cluster simulator: timing semantics, single-flight, baseline ordering."""
import pytest

from repro.core import CacheConfig, IGTCache, bundle
from repro.core.types import MB
from repro.sim import ClusterSim, SharedLink, make_paper_suite
from repro.storage import RemoteStore


def scaled_cfg(capacity):
    share = max(16 * MB, capacity // 128)
    return CacheConfig(min_share=share, rebalance_quantum=share,
                       rebalance_period=10.0,
                       prefetch_budget_bytes=max(64 * MB, capacity // 8))


def test_link_priority_and_latency():
    link = SharedLink(bandwidth_Bps=100.0, latency_s=1.0)
    got = []
    link.enqueue(100, "bg", demand=False, callback=None)
    link.enqueue(100, "demand", demand=True, callback=None)
    finish, t = link.pump(0.0)
    got.append(t.key)
    assert finish == pytest.approx(2.0)     # 1s busy + 1s latency
    finish2, t2 = link.pump(link.free_at)
    got.append(t2.key)
    assert got == ["demand", "bg"]


def test_link_promote():
    link = SharedLink(100.0, 0.0)
    link.enqueue(100, "a", demand=False, callback=("x", 1))
    assert link.promote("a")
    finish, t = link.pump(0.0)
    assert t.demand and t.key == "a"


def _run(bundle_name, suite, store, cap):
    eng = IGTCache(store, cap, cfg=scaled_cfg(cap),
                   options=bundle(bundle_name))
    return ClusterSim(suite, eng).run()


@pytest.fixture(scope="module")
def small_world():
    suite = make_paper_suite(scale=0.15, seed=0, job_filter=[2, 8, 9, 16])
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())
    return suite, store, cap


def test_sim_deterministic(small_world):
    suite, store, cap = small_world
    r1 = _run("igtcache", suite, store, cap)
    r2 = _run("igtcache", suite, store, cap)
    assert r1.jct == r2.jct
    assert r1.hit_ratio == r2.hit_ratio


def test_cache_beats_nocache(small_world):
    suite, store, cap = small_world
    with_cache = _run("juicefs", suite, store, cap)
    eng = IGTCache(store, 0, cfg=scaled_cfg(cap),
                   options=bundle("prefetch_none"))
    no_cache = ClusterSim(suite, eng).run()
    assert with_cache.avg_jct < no_cache.avg_jct
    assert with_cache.hit_ratio > 0.2


def test_igt_beats_juicefs_on_chr(small_world):
    suite, store, cap = small_world
    igt = _run("igtcache", suite, store, cap)
    jfs = _run("juicefs", suite, store, cap)
    assert igt.hit_ratio > jfs.hit_ratio


def test_all_jobs_finish(small_world):
    suite, store, cap = small_world
    res = _run("igtcache", suite, store, cap)
    assert set(res.jct) == {j.job_id for j in suite.jobs}
    assert all(v > 0 for v in res.jct.values())

import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY
# for the dry-run entry point, per the assignment).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

# Capability gate for the explicit-mesh-axis-type tests: the image's jax
# predates ``jax.sharding.AxisType`` (used by repro.launch.mesh), which is a
# toolchain gap, not a cache regression — skip with a reason instead of
# hard-erroring (the pre-PR-2 state was 9 hard failures).  The cache core
# itself needs only numpy, so a jax-less environment must still collect and
# run the rest of the suite.
try:
    import jax  # noqa: E402
    HAS_MESH_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
except ImportError:
    HAS_MESH_AXIS_TYPES = False
requires_mesh_axis_types = pytest.mark.skipif(
    not HAS_MESH_AXIS_TYPES,
    reason="installed jax lacks jax.sharding.AxisType (explicit mesh axis "
           "types required by repro.launch.mesh.make_local_mesh)")

"""Property tests for the demand sketches (PR-7 tentpole).

CountMinSketch: point queries never under-count; over-count bounded by
2/width of the per-row mass at the seeded geometry; merge() is
element-wise addition and associative; serialize/deserialize round-trips
bitwise.  SpaceSaving: any key with true count > total/k is present
(guaranteed containment); counts over-estimate by at most the recorded
err; merge keeps both properties.  DemandSketch: ghost-hit feeding via
BufferWindow.sink, distinct_under prefix accounting, O(KB) payloads.
"""
import random

import pytest

from repro.core.allocation import BufferWindow
from repro.core.sketch import (CountMinSketch, DemandSketch, SpaceSaving,
                               stable_hash64)
from repro.core.types import CacheConfig


def zipf_stream(rng, n_keys=2000, n_draws=20000, s=1.2):
    weights = [1.0 / (i + 1) ** s for i in range(n_keys)]
    keys = [f"ds/blk#{i}" for i in range(n_keys)]
    return rng.choices(keys, weights=weights, k=n_draws)


def exact_counts(stream):
    from collections import Counter
    return Counter(stream)


# ------------------------------------------------------------------- hashing

def test_stable_hash64_is_process_stable_and_spread():
    # pinned values: the hash must never change across runs/processes
    # (routing and sketch compatibility depend on it)
    assert stable_hash64("a") == stable_hash64("a")
    assert stable_hash64("a") != stable_hash64("b")
    vals = {stable_hash64(f"k{i}") & 0xFFFF for i in range(4096)}
    assert len(vals) > 3000          # low-bit spread after mixing


# ----------------------------------------------------------------------- CMS

def test_cms_never_undercounts_and_bounds_overestimate():
    rng = random.Random(7)
    stream = zipf_stream(rng)
    truth = exact_counts(stream)
    cms = CountMinSketch(width=512, depth=3, seed=0)
    cms.update_batch(stream)
    assert cms.total == len(stream)
    # epsilon = 2/width of the stream mass (classic CM bound, per query
    # with failure prob 2^-depth; conservative update only tightens it).
    # Check the bound holds for the overwhelming majority and never
    # under-counts for any key.
    eps_mass = 2.0 * len(stream) / 512
    violations = 0
    for k, c in truth.items():
        est = cms.query(k)
        assert est >= c, f"under-count: {k} est={est} true={c}"
        if est > c + eps_mass:
            violations += 1
    assert violations <= max(1, len(truth) // 100), \
        f"{violations}/{len(truth)} queries exceeded the CM bound"


def test_cms_update_orders_agree_with_single_updates():
    """Batched conservative update must never under-count relative to
    truth regardless of batching; single-key and batched paths agree on
    totals."""
    rng = random.Random(11)
    stream = zipf_stream(rng, n_keys=200, n_draws=3000)
    a = CountMinSketch(width=256, depth=3, seed=5)
    b = CountMinSketch(width=256, depth=3, seed=5)
    for k in stream:
        a.update(k)
    b.update_batch(stream)
    truth = exact_counts(stream)
    for k, c in truth.items():
        assert a.query(k) >= c
        assert b.query(k) >= c
    assert a.total == b.total == len(stream)


def test_cms_merge_associative_and_overestimates_union():
    rng = random.Random(13)
    parts = [zipf_stream(rng, n_keys=500, n_draws=4000) for _ in range(3)]

    def mk(stream):
        c = CountMinSketch(width=512, depth=3, seed=1)
        c.update_batch(stream)
        return c

    # (a+b)+c == a+(b+c): tables identical element-wise
    left = mk(parts[0]).merge(mk(parts[1])).merge(mk(parts[2]))
    bc = mk(parts[1]).merge(mk(parts[2]))
    right = mk(parts[0]).merge(bc)
    assert (left.table == right.table).all()
    assert left.total == right.total == sum(len(p) for p in parts)
    truth = exact_counts([k for p in parts for k in p])
    for k, c in truth.items():
        assert left.query(k) >= c


def test_cms_merge_rejects_incompatible():
    a = CountMinSketch(width=512, depth=3, seed=0)
    with pytest.raises(ValueError):
        a.merge(CountMinSketch(width=256, depth=3, seed=0))
    with pytest.raises(ValueError):
        a.merge(CountMinSketch(width=512, depth=3, seed=1))


def test_cms_serde_round_trip_and_bounded_payload():
    rng = random.Random(17)
    cms = CountMinSketch(width=512, depth=3, seed=0)
    cms.update_batch(zipf_stream(rng))
    blob = cms.serialize()
    back = CountMinSketch.deserialize(blob)
    assert back.compatible(cms)
    assert back.total == cms.total
    assert (back.table == cms.table).all()
    # O(KB): a 512x3 uint64 table is 12 KiB raw; zlib keeps the wire
    # payload at or below that even when fully populated
    assert len(blob) <= 16 * 1024
    with pytest.raises(ValueError):
        CountMinSketch.deserialize(b"XXXX" + blob[4:])


# ---------------------------------------------------------------- SpaceSaving

def test_spacesaving_guaranteed_containment_and_error_bounds():
    rng = random.Random(23)
    stream = zipf_stream(rng, n_keys=3000, n_draws=30000, s=1.1)
    truth = exact_counts(stream)
    k = 64
    ss = SpaceSaving(k=k)
    ss.update_batch(stream)
    assert ss.total == len(stream)
    assert len(ss.counts) <= k
    threshold = len(stream) / k
    for key, c in truth.items():
        if c > threshold:
            assert key in ss.counts, \
                f"heavy hitter missing: {key} true={c} > {threshold:.0f}"
    for key, est, err in ss.items():
        true = truth.get(key, 0)
        assert est >= true, "SpaceSaving count must over-estimate"
        assert est - err <= true, "err must bound the over-estimate"


def test_spacesaving_merge_keeps_bounds():
    rng = random.Random(29)
    s1 = zipf_stream(rng, n_keys=1500, n_draws=15000, s=1.1)
    s2 = zipf_stream(rng, n_keys=1500, n_draws=15000, s=1.1)
    a, b = SpaceSaving(k=64), SpaceSaving(k=64)
    a.update_batch(s1)
    b.update_batch(s2)
    a.merge(b)
    truth = exact_counts(s1 + s2)
    assert a.total == len(s1) + len(s2)
    assert len(a.counts) <= 64
    for key, c in truth.items():
        if c > a.total / 64 * 2:     # mergeable-summaries: 2x slack
            assert key in a.counts
    for key, est, err in a.items():
        assert est >= truth.get(key, 0)
    with pytest.raises(ValueError):
        a.merge(SpaceSaving(k=32))


def test_spacesaving_serde_round_trip():
    rng = random.Random(31)
    ss = SpaceSaving(k=64)
    ss.update_batch(zipf_stream(rng, n_draws=5000))
    blob = ss.serialize()
    back = SpaceSaving.deserialize(blob)
    assert back.k == ss.k and back.total == ss.total
    assert back.counts == ss.counts and back.errs == ss.errs
    assert len(blob) <= 8 * 1024     # 64 entries -> well under a KB-scale cap


# --------------------------------------------------------------- DemandSketch

def test_demand_sketch_feeds_from_buffer_window_sink():
    cfg = CacheConfig()
    sk = DemandSketch(cfg)
    bw = BufferWindow(w=100)
    bw.sink = sk.note
    for i in range(50):
        bw.on_evict(f"hot/part0#{i % 5}")
        assert bw.probe(f"hot/part0#{i % 5}")     # ghost hit -> noted
        bw.on_evict(f"cold/x#{i}")                # never probed -> not noted
    sk.fold()
    assert sk.noted == 50
    head, head_mass = sk.distinct_under("hot/")
    assert head == 5
    assert head_mass <= 50
    assert sk.distinct_under("cold/") == (0, 0)
    assert sk.distinct_under("other/") == (0, 0)


def test_demand_sketch_interval_reset_and_payloads():
    sk = DemandSketch(CacheConfig())
    for i in range(10000):
        sk.note(f"ds/blk#{i % 700}")
    cms_blob, topk_blob = sk.serialize()
    assert 0 < len(cms_blob) <= 16 * 1024
    assert 0 < len(topk_blob) <= 8 * 1024
    assert sk.noted == 10000
    sk.reset()
    assert sk.noted == 0
    assert sk.distinct_under("ds/") == (0, 0)
    assert sk.cms.total == 0 and sk.topk.total == 0

"""Survivable cache service (the PR 10 tentpole).

Coverage the ISSUE pins, layer by layer:

* **journal** — CRC-framed records round-trip; a torn log tail (crash
  mid-append) is truncated to the clean prefix, never replayed as
  garbage; snapshots commit atomically (tmp → fsync → ``os.replace``)
  and reset the log; replay is idempotent.
* **warm restart** — ``warm_state()`` / ``warm_admit()`` round-trip the
  kernel's residency manifest (single and sharded); a daemon rebuilt
  over the same journal dir re-admits its hot set, replays sticky
  pins, and serves first-pass hits a cold daemon cannot.
* **client resilience** — a dead daemon marks the connection down via
  heartbeat or mid-call failure (typed ``DaemonUnavailableError``, no
  hung callers — the RPC deadline guarantees it), degraded reads flow
  from the backing store, ``flush``/``close`` short-circuit promptly,
  and reconnection re-establishes a session + replays pins.
* **supervision** — ``DaemonSupervisor`` respawns a crashed daemon on
  the same socket path within its restart budget; exhaustion converges
  to a stable ``down`` with degraded reads still flowing.
* **chaos drill** — ``daemon_kill`` mid-trace on the cluster sim:
  zero hung/errored reads, respawn within budget, post-recovery
  windowed CHR within 5 % of the fault-free run.

Every test runs under a hard SIGALRM guard: "never hangs a blocked
caller" is asserted by the alarm, not hoped for.  Fast subset is marked
``restart`` (tier-1); the kill/recovery soak is ``restart_full``.
"""
import os
import signal
import socket
import tempfile
import threading
import time

import pytest

from repro.core import CacheConfig, MB, open_cache
from repro.core.faults import DaemonUnavailableError, SHARD_DOWN, SHARD_UP
from repro.daemon import (CacheDaemon, CacheJournal, DaemonSupervisor,
                          RemoteCacheClient)
from repro.daemon.journal import LOG_NAME, SNAP_NAME
from repro.daemon.wire import PROTO_VERSION, recv_msg, send_msg
from repro.sim.cluster import ClusterSim
from repro.sim.workloads import make_paper_suite
from repro.storage import RemoteStore, make_dataset

pytestmark = pytest.mark.restart

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  window=40, reanalyze_every=20, node_cap=500)

HARD_TIMEOUT_S = 180


@pytest.fixture(autouse=True)
def hard_timeout():
    """Recovery tests must never hang tier-1."""

    def boom(signum, frame):  # pragma: no cover - only fires on deadlock
        raise TimeoutError(
            f"restart test exceeded the {HARD_TIMEOUT_S}s hard timeout "
            f"(hung reconnect / lost wakeup?)")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def mk_store(n_datasets=2):
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=2,
                               files_per_dir=6, small_file_size=256 * 1024))
    return store


def all_files(store):
    return [f for ds in store.datasets.values() for f in ds.files]


def wait_until(cond, deadline_s=15.0, tick=0.02, what="condition"):
    end = time.monotonic() + deadline_s
    while time.monotonic() < end:
        if cond():
            return
        time.sleep(tick)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# journal: framing, torn tails, atomic snapshots
# ---------------------------------------------------------------------------

def test_journal_records_roundtrip(tmp_path):
    j = CacheJournal(str(tmp_path))
    records = [("pin", ("ds0",)), ("never_cache", ("tmp", "scratch")),
               ("verdict", "ds1", "SEQUENTIAL", True)]
    for r in records:
        j.append(r)
    j.close()
    j2 = CacheJournal(str(tmp_path))
    snap, replayed = j2.load()
    assert snap is None and replayed == records
    assert j2.stats.replayed_records == 3
    assert j2.stats.truncated_bytes == 0
    j2.close()


def test_journal_torn_tail_truncated_in_place(tmp_path):
    j = CacheJournal(str(tmp_path))
    j.append(("pin", ("a",)))
    j.append(("pin", ("b",)))
    j.close()
    log = tmp_path / LOG_NAME
    clean = log.stat().st_size
    # crash mid-append: a partial frame (and then some garbage) lands
    with open(log, "ab") as f:
        f.write(b"\x00\x00\x01\x00\xde\xad")
    j2 = CacheJournal(str(tmp_path))
    snap, replayed = j2.load()
    assert replayed == [("pin", ("a",)), ("pin", ("b",))]
    assert j2.stats.truncated_bytes == 6
    assert log.stat().st_size == clean       # tail gone from disk too
    # the next append lands on a frame boundary and replays cleanly
    j2.append(("pin", ("c",)))
    j2.close()
    j3 = CacheJournal(str(tmp_path))
    assert list(j3.iter_records()) == [("pin", ("a",)), ("pin", ("b",)),
                                       ("pin", ("c",))]
    j3.close()


def test_journal_corrupt_record_stops_replay(tmp_path):
    j = CacheJournal(str(tmp_path))
    j.append(("pin", ("a",)))
    j.append(("pin", ("b",)))
    j.close()
    log = tmp_path / LOG_NAME
    blob = bytearray(log.read_bytes())
    blob[-1] ^= 0xFF                         # flip a byte in the last frame
    log.write_bytes(bytes(blob))
    j2 = CacheJournal(str(tmp_path))
    _, replayed = j2.load()
    assert replayed == [("pin", ("a",))]     # clean prefix only
    assert j2.stats.truncated_bytes > 0
    j2.close()


def test_journal_snapshot_resets_log_and_commits_atomically(tmp_path):
    j = CacheJournal(str(tmp_path))
    j.append(("pin", ("old",)))
    j.write_snapshot({"pins": [("old",)], "resident": [("k", 4)]})
    j.append(("pin", ("new",)))
    j.close()
    # a stale tmp file from a crash mid-snapshot must be ignored
    (tmp_path / (SNAP_NAME + ".999.tmp")).write_bytes(b"garbage")
    j2 = CacheJournal(str(tmp_path))
    snap, replayed = j2.load()
    assert snap == {"pins": [("old",)], "resident": [("k", 4)]}
    assert replayed == [("pin", ("new",))]   # pre-snapshot records folded
    # replay is idempotent: loading twice changes nothing
    snap2, replayed2 = j2.load()
    assert snap2 == snap and replayed2 == replayed
    j2.close()


def test_journal_unreadable_snapshot_degrades_to_cold(tmp_path):
    j = CacheJournal(str(tmp_path))
    j.write_snapshot({"pins": []})
    j.close()
    (tmp_path / SNAP_NAME).write_bytes(b"IGTJ\x01not-a-frame")
    j2 = CacheJournal(str(tmp_path))
    snap, replayed = j2.load()
    assert snap is None and replayed == []
    j2.close()


# ---------------------------------------------------------------------------
# kernel warm restart: warm_state / warm_admit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 4])
def test_warm_state_round_trip(n_shards):
    """The residency manifest survives a kernel swap: a fresh engine
    fed ``warm_state()`` re-admits the hot set, pins, bans, and
    verdicts — first-pass reads hit without the store ever moving."""
    store = mk_store()
    files = [f.path for f in all_files(store)][:8]
    a = open_cache(store, 48 * MB, cfg=CFG, executor="sim",
                   n_shards=n_shards)
    for t in range(3):
        for i, fp in enumerate(files):
            a.read(fp, 0, 128 * 1024, float(t * len(files) + i))
    a.pin(("ds0",))
    a.never_cache(("ds1", "dir1"))
    state = a.engine.warm_state()
    assert state["resident"] and state["pins"] == [("ds0",)]
    resident_keys = {k for k, _s in state["resident"]}

    b = open_cache(store, 48 * MB, cfg=CFG, executor="sim",
                   n_shards=n_shards)
    restored = b.engine.warm_admit(state, now=100.0)
    assert restored["blocks"] > 0
    assert restored["pins"] == 1
    new_state = b.engine.warm_state()
    assert {k for k, _s in new_state["resident"]} >= resident_keys - {
        k for k in resident_keys if k.startswith("ds1/dir1")}
    assert new_state["pins"] == [("ds0",)]
    assert new_state["never_cache"] == [("ds1", "dir1")]
    # re-admission is visible to the read path: first pass hits
    r = b.read(files[0], 0, 128 * 1024, 101.0)
    assert all(blk.hit for blk in r.blocks)
    # idempotent: a second admit of the same state re-inserts nothing
    again = b.engine.warm_admit(state, now=102.0)
    assert again["blocks"] == 0
    a.close()
    b.close()


# ---------------------------------------------------------------------------
# daemon warm restart: journal → restore → first-pass hits
# ---------------------------------------------------------------------------

def test_daemon_warm_restart_beats_cold(tmp_path):
    """A daemon rebuilt over its journal dir re-admits the hot set: the
    restarted daemon serves first-pass hits on the journaled keys,
    while a cold daemon (no journal) misses every one of them."""
    store = mk_store(1)
    files = [f.path for f in all_files(store)][:8]
    sock = str(tmp_path / "d.sock")
    jdir = str(tmp_path / "journal")

    def first_pass_hits(daemon):
        with open_cache(daemon.uri) as c:
            hits = total = 0
            for i, fp in enumerate(files):
                r = c.read(fp, 0, 128 * 1024, float(1000 + i))
                for blk in r.blocks:
                    hits += bool(blk.hit)
                    total += 1
            return hits, total

    with CacheDaemon(store, 32 * MB, cfg=CFG, uds=sock,
                     journal_dir=jdir) as d:
        with open_cache(d.uri) as c:
            c.pin(("ds0", "dir0"))
            for t in range(2):
                for i, fp in enumerate(files):
                    c.read(fp, 0, 128 * 1024, float(t * 10 + i))
        assert d.write_snapshot()
        assert d.journal.stats.snapshots >= 1

    # warm: same journal dir — restore re-admits the manifest
    with CacheDaemon(store, 32 * MB, cfg=CFG, uds=sock,
                     journal_dir=jdir) as warm:
        rs = warm.restore_stats
        assert rs["mode"] == "warm" and rs["blocks"] > 0
        assert rs["restore_s"] < 5.0
        w_hits, w_total = first_pass_hits(warm)
        st = warm.daemon_stats()
        assert st["restore"]["blocks"] == rs["blocks"]
        # sticky pin survived the restart (snapshot carried it)
        assert ("ds0", "dir0") in warm.client.engine.warm_state()["pins"]

    # cold: fresh journal dir — nothing to restore
    with CacheDaemon(store, 32 * MB, cfg=CFG, uds=sock,
                     journal_dir=str(tmp_path / "j2")) as cold:
        assert cold.restore_stats["mode"] == "cold"
        c_hits, _ = first_pass_hits(cold)

    assert w_hits == w_total, f"warm restart missed: {w_hits}/{w_total}"
    assert c_hits == 0
    # a third daemon over the same journal warm-starts from the warm
    # daemon's close() snapshot (clean-shutdown path)
    with CacheDaemon(store, 32 * MB, cfg=CFG, uds=sock,
                     journal_dir=jdir) as again:
        assert again.restore_stats["mode"] == "warm"


def test_sigterm_drain_sends_going_down_and_snapshots(tmp_path):
    """The graceful path: ``drain()`` (the SIGTERM handler's body)
    notifies live sessions out-of-band, writes a final snapshot, and
    closes.  The client sees the notice as a typed unavailability, not
    a mystery EOF."""
    store = mk_store(1)
    files = [f.path for f in all_files(store)][:4]
    jdir = str(tmp_path / "j")
    d = CacheDaemon(store, 32 * MB, cfg=CFG, uds=str(tmp_path / "d.sock"),
                    journal_dir=jdir).start()
    cli = RemoteCacheClient(d.uri, heartbeat=False, reconnect=False,
                            degraded=False)
    for i, fp in enumerate(files):
        cli.read(fp, 0, 64 * 1024, float(i))
    snaps_before = d.journal.stats.snapshots
    d.drain(timeout=5.0)
    assert d.journal.stats.snapshots >= snaps_before  # final snapshot
    # the queued going_down frame surfaces as the typed error
    with pytest.raises(DaemonUnavailableError):
        cli.read(files[0], 0, 64 * 1024, 99.0)
    assert cli.state == "down"
    cli.close()
    # and the journal it left behind warm-starts a successor
    with CacheDaemon(store, 32 * MB, cfg=CFG,
                     uds=str(tmp_path / "d.sock"), journal_dir=jdir) as d2:
        assert d2.restore_stats["mode"] == "warm"
        assert d2.restore_stats["blocks"] > 0


# ---------------------------------------------------------------------------
# client resilience: typed errors, no hangs, degraded reads, reconnect
# ---------------------------------------------------------------------------

def test_degraded_false_raises_typed_error_and_never_hangs():
    store = mk_store(1)
    f = all_files(store)[0]
    d = CacheDaemon(store, 16 * MB, cfg=CFG).start()
    cli = RemoteCacheClient(d.uri, heartbeat=False, reconnect=False,
                            degraded=False)
    assert cli.read(f.path, 0, 64 * 1024, 0.0).blocks
    d.crash()
    t0 = time.monotonic()
    with pytest.raises(DaemonUnavailableError) as ei:
        for i in range(10):                  # first call marks down,
            cli.read(f.path, 0, 64 * 1024, float(i))  # rest short-circuit
    assert time.monotonic() - t0 < 10.0
    assert isinstance(ei.value, ConnectionError)   # legacy handlers work
    assert cli.state == "down"
    # stats need the daemon: typed error, immediately
    with pytest.raises(DaemonUnavailableError):
        cli.hit_ratio()
    cli.close()
    d.close()


def test_degraded_reads_flow_from_backing_store():
    store = mk_store(1)
    files = all_files(store)[:4]
    d = CacheDaemon(store, 16 * MB, cfg=CFG).start()
    cli = RemoteCacheClient(d.uri, fetch_bytes=True, heartbeat=False,
                            reconnect=False, backing=store)
    direct = {f.path: cli.read(f.path, 0, f.size, 0.0).data.tobytes()
              for f in files}
    # geometry memoized while up: degraded outcomes stay exact
    for f in files:
        assert cli.meta.file_size(f.path) == f.size
    d.crash()
    for f in files:
        r = cli.read(f.path, 0, f.size, 1.0)
        assert r.blocks and not any(blk.hit for blk in r.blocks)
        assert r.data is not None and r.data.tobytes() == direct[f.path]
    cs = cli.client_stats.snapshot()
    assert cs["degraded_reads"] == len(files)
    assert cs["degraded_bytes"] == sum(f.size for f in files)
    # batch path degrades too
    outs = cli.read_batch([(f.path, 0, f.size) for f in files], 2.0)
    assert all(r.data is not None for r in outs)
    cli.close()
    d.close()


def test_heartbeat_marks_connection_dead_not_silent():
    """Satellite: the heartbeat thread must mark the connection down on
    failure (waking future callers with the typed error) instead of
    swallowing the exception and exiting."""
    store = mk_store(1)
    d = CacheDaemon(store, 16 * MB, cfg=CFG, lease_s=0.4).start()
    cli = RemoteCacheClient(d.uri, heartbeat=True, reconnect=False,
                            degraded=False)
    assert cli.state == "up"
    d.crash()
    # no reads issued: only the heartbeat can notice
    wait_until(lambda: cli.state == "down", deadline_s=10.0,
               what="heartbeat-driven down transition")
    with pytest.raises(DaemonUnavailableError):
        cli.heartbeat()
    cli.close()
    d.close()


def test_flush_and_close_short_circuit_on_dead_daemon():
    store = mk_store(1)
    d = CacheDaemon(store, 16 * MB, cfg=CFG).start()
    cli = RemoteCacheClient(d.uri, heartbeat=False, reconnect=False)
    assert cli.flush(timeout=5.0) in (True, False)   # live flush works
    d.crash()
    t0 = time.monotonic()
    assert cli.flush(timeout=30.0) is False          # no 30 s wait
    cli.close()                                      # no bye round-trip
    assert time.monotonic() - t0 < 5.0
    assert cli.state == "closed"
    d.close()


def test_rpc_deadline_wakes_caller_blocked_on_silent_daemon():
    """A daemon that accepts the session then goes mute (wedged, not
    crashed — no EOF ever comes) cannot hang a caller: the RPC deadline
    trips and surfaces the typed error."""
    lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    path = os.path.join(tempfile.mkdtemp(prefix="igt-mute-"), "s.sock")
    lst.bind(path)
    lst.listen(1)

    def mute_server():
        conn, _ = lst.accept()
        op, _, payload = recv_msg(conn)
        send_msg(conn, ("ok", {"proto": PROTO_VERSION, "session": 0,
                               "lease_s": 5.0, "block_size": 4 * MB,
                               "shm": None, "server_pid": 0}))
        # read the next request and never answer
        try:
            recv_msg(conn)
            time.sleep(30.0)
        except Exception:
            pass

    t = threading.Thread(target=mute_server, daemon=True)
    t.start()
    cli = RemoteCacheClient(f"cache://{path}", heartbeat=False,
                            reconnect=False, degraded=False,
                            rpc_timeout_s=1.0)
    t0 = time.monotonic()
    with pytest.raises(DaemonUnavailableError):
        cli.read(("ds0", "f"), 0, 1024, 0.0)
    assert time.monotonic() - t0 < 5.0
    assert cli.state == "down"
    cli.close()
    lst.close()


def test_client_reconnects_and_replays_pins(tmp_path):
    """Kill → supervisor respawn → client auto-reconnect: a fresh
    session on the same socket path, stale frees dropped, and the
    locally tracked pins replayed into the (journal-less) new daemon."""
    store = mk_store(1)
    files = [f.path for f in all_files(store)][:4]
    sock = str(tmp_path / "d.sock")

    def factory():
        return CacheDaemon(store, 32 * MB, cfg=CFG, uds=sock,
                           lease_s=1.0).start()

    sup = DaemonSupervisor(factory, restart_budget=3)
    cli = RemoteCacheClient(sup.uri, fetch_bytes=True, backing=store,
                            max_backoff_s=0.5)
    try:
        cli.pin(("ds0", "dir0"))
        for i, fp in enumerate(files):
            assert cli.read(fp, 0, 64 * 1024, float(i)).data is not None
        sup.kill_daemon()
        # degraded service while the daemon is away — zero errors
        for i, fp in enumerate(files):
            r = cli.read(fp, 0, 64 * 1024, float(10 + i))
            assert r.data is not None
        wait_until(lambda: sup.state == SHARD_UP and cli.state == "up",
                   what="respawn + reconnect")
        assert cli.reconnects == 1
        # pins replayed by the *client* (this daemon has no journal)
        assert ("ds0", "dir0") in \
            sup.daemon.client.engine.warm_state()["pins"]
        # the new session serves normally, and stats flow again
        r = cli.read(files[0], 0, 64 * 1024, 50.0)
        assert r.data is not None
        assert cli.daemon_stats()["sessions"] == 1
        assert cli.connection_stats()["reconnects"] == 1
    finally:
        cli.close()
        sup.close()


def test_supervisor_budget_exhaustion_stays_down_degraded(tmp_path):
    store = mk_store(1)
    f = all_files(store)[0]
    sock = str(tmp_path / "d.sock")

    def factory():
        return CacheDaemon(store, 16 * MB, cfg=CFG, uds=sock).start()

    sup = DaemonSupervisor(factory, restart_budget=1, restart_window_s=60.0)
    cli = RemoteCacheClient(sup.uri, fetch_bytes=True, backing=store,
                            max_backoff_s=0.2)
    try:
        assert cli.read(f.path, 0, 64 * 1024, 0.0).data is not None
        sup.kill_daemon()
        wait_until(lambda: sup.restarts == 1 and cli.state == "up",
                   what="first respawn")
        sup.kill_daemon()                     # budget (1) now exhausted
        wait_until(lambda: sup.state == SHARD_DOWN, what="budget exhaustion")
        assert any(e["kind"] == "budget_exhausted" for e in sup.events)
        # stable degraded state: reads still flow, nothing hangs
        for i in range(5):
            r = cli.read(f.path, 0, 64 * 1024, float(i))
            assert r.data is not None
        assert cli.client_stats.degraded_reads >= 5
        st = sup.supervisor_stats()
        assert st["state"] == SHARD_DOWN and st["restarts"] == 1
    finally:
        cli.close()
        sup.close()


# ---------------------------------------------------------------------------
# chaos drill: daemon_kill mid-trace on the cluster sim
# ---------------------------------------------------------------------------

def _sim_world():
    suite = make_paper_suite(scale=0.12, seed=0, job_filter=[2, 8])
    store = RemoteStore()
    for ds in suite.datasets.values():
        store.add(ds)
    cap = int(0.35 * suite.total_bytes())
    return suite, store, cap


def _run_remote_sim(tmp_path, tag, *, strike=None, recover_by=None,
                    window_from=None, poll_s=0.05):
    """One ClusterSim pass in remote mode: supervised daemon on a UDS,
    the sim driving a ``RemoteCacheClient``.  ``strike=(t, kind)``
    schedules a daemon strike at virtual time ``t`` plus a probe at
    virtual time ``recover_by`` (default just after the strike) that
    *wall-blocks* until respawn + reconnect — virtual time cannot race
    past the recovery, and every read event the sim pumps before
    ``recover_by`` exercises the degraded path; ``window_from``
    snapshots kernel stats at that virtual time for windowed-CHR
    comparison.  ``poll_s`` is the supervisor's crash-detection cadence
    (a slower poll widens the degraded window the drill drives reads
    through)."""
    suite, store, cap = _sim_world()
    sock = str(tmp_path / f"{tag}.sock")
    jdir = str(tmp_path / f"{tag}-journal")

    def factory():
        return CacheDaemon(store, cap, cfg=CFG, uds=sock,
                           journal_dir=jdir, snapshot_every_s=0.1,
                           lease_s=2.0).start()

    sup = DaemonSupervisor(factory, restart_budget=3, poll_s=poll_s)
    cli = RemoteCacheClient(sup.uri, backing=store, max_backoff_s=0.25)
    snaps = {}
    try:
        chaos_events = []
        probes = []
        if strike is not None:
            strike_at, kind = strike
            chaos_events = [(strike_at, kind, 0)]

            def await_recovery(sim):
                # wall-clock pause inside virtual time: the drill's
                # post-recovery window must contain post-recovery reads
                wait_until(lambda: sup.restarts >= 1, what="daemon respawn")

                def client_ok():
                    try:
                        cli.heartbeat()     # forces down-detection too
                        return True
                    except ConnectionError:
                        return False

                wait_until(client_ok, what="client reconnect")

            probes.append((recover_by if recover_by is not None
                           else strike_at + 1.0, await_recovery))
        if window_from is not None:
            probes.append((window_from,
                           lambda sim: snaps.__setitem__(
                               "w", sim.client.stats.snapshot())))
        sim = ClusterSim(suite, cli, chaos_events=chaos_events,
                         chaos_daemon=sup)
        for t, fn in probes:
            sim.at(t, fn)
        res = sim.run()
        snaps["end"] = cli.stats.snapshot()
        return res, snaps, sup.supervisor_stats(), \
            cli.client_stats.snapshot(), cli.connection_stats()
    finally:
        cli.close()
        sup.close()


def _window_chr(snaps):
    s0, s1 = snaps["w"], snaps["end"]
    hits = s1["hits"] - s0["hits"]
    total = hits + s1["misses"] - s0["misses"]
    return hits / total if total else 0.0


def test_chaos_daemon_kill_drill(tmp_path):
    """Acceptance: kill the daemon mid-trace.  The run completes with
    zero hung or errored reads (SIGALRM guards hangs; an exception
    would abort the sim loop), the supervisor respawns within budget,
    the client reconnects, and post-recovery windowed CHR lands within
    5 % of the fault-free run."""
    base_res, _, base_sup, base_cstats, _ = _run_remote_sim(
        tmp_path, "base")
    assert base_res.jct, "baseline sim completed no jobs"
    assert base_sup["restarts"] == 0 and base_cstats["degraded_reads"] == 0
    kill_at = base_res.makespan / 3.0
    window_from = 2.0 * base_res.makespan / 3.0

    _, base_snaps, _, _, _ = _run_remote_sim(
        tmp_path, "basew", window_from=window_from)

    res, snaps, sup_stats, cstats, conn = _run_remote_sim(
        tmp_path, "chaos", strike=(kill_at, "daemon_kill"),
        recover_by=(kill_at + window_from) / 2.0,
        window_from=window_from, poll_s=0.3)

    assert set(res.jct) == set(base_res.jct)      # same jobs completed
    assert res.chaos_log and res.chaos_log[0]["kind"] == "daemon_kill"
    assert sup_stats["restarts"] == 1 and sup_stats["state"] == SHARD_UP
    assert any(e["kind"] == "respawn_done" for e in sup_stats["events"])
    assert conn["reconnects"] >= 1
    assert cstats["degraded_reads"] > 0           # reads flowed while down
    chr_base = _window_chr(base_snaps)
    chr_chaos = _window_chr(snaps)
    assert abs(chr_base - chr_chaos) <= 0.05, (
        f"post-recovery CHR diverged: base={chr_base:.4f} "
        f"chaos={chr_chaos:.4f}")


def test_chaos_daemon_graceful_restart_drill(tmp_path):
    """``daemon_restart``: the SIGTERM-shaped roll mid-trace — drain,
    final snapshot, immediate respawn.  The successor warm-starts and
    the trace completes with zero errors."""
    probe_res, _, _, _, _ = _run_remote_sim(tmp_path, "probe")
    res, _, sup_stats, _, conn = _run_remote_sim(
        tmp_path, "roll",
        strike=(probe_res.makespan / 2.0, "daemon_restart"))
    assert set(res.jct) == set(probe_res.jct)
    assert sup_stats["restarts"] == 1
    done = [e for e in sup_stats["events"] if e["kind"] == "respawn_done"]
    assert done and done[0]["restore"]["mode"] == "warm"


# ---------------------------------------------------------------------------
# opt-in soak: repeated kill/recover cycles (pytest -m restart_full)
# ---------------------------------------------------------------------------

@pytest.mark.restart_full
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_full_repeated_kill_recover_cycles(tmp_path, seed):
    import random
    store = mk_store(1)
    files = [f.path for f in all_files(store)]
    sock = str(tmp_path / "d.sock")
    jdir = str(tmp_path / "j")

    def factory():
        return CacheDaemon(store, 32 * MB, cfg=CFG, uds=sock,
                           journal_dir=jdir, snapshot_every_s=0.1,
                           lease_s=1.0).start()

    sup = DaemonSupervisor(factory, restart_budget=10, restart_window_s=600)
    cli = RemoteCacheClient(sup.uri, fetch_bytes=True, backing=store,
                            max_backoff_s=0.25)
    rng = random.Random(seed)
    try:
        for cycle in range(3):
            for i in range(30):
                fp = files[rng.randrange(len(files))]
                r = cli.read(fp, 0, 64 * 1024, float(cycle * 100 + i))
                assert r.data is not None and r.data.size == 64 * 1024
            time.sleep(0.25)                  # let a snapshot land
            if rng.random() < 0.5:
                sup.kill_daemon()
            else:
                sup.drain_restart()
            for i in range(10):               # degraded or fresh: no errors
                fp = files[rng.randrange(len(files))]
                assert cli.read(fp, 0, 64 * 1024,
                                float(cycle * 100 + 50 + i)).data is not None
            wait_until(lambda: sup.restarts == cycle + 1
                       and cli.state == "up",
                       what=f"recovery cycle {cycle}")
        assert sup.supervisor_stats()["restarts"] == 3
    finally:
        cli.close()
        sup.close()

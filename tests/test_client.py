"""CacheClient / PrefetchExecutor semantics (the PR-3 caller layer).

Covers the executor contract the ISSUE pins: cancellation on queue
overflow and on shutdown (never silently dropping a candidate the kernel
is tracking), in-queue candidate dedup, demand-miss priority, racing
``complete_prefetch`` against demand misses under the ThreadedExecutor,
per-shard worker routing, the client byte path against the backing
store, and the pipeline's executor-visible prefetch accounting.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (CacheClient, CacheConfig, IGTCache, NullExecutor,
                        ShardedIGTCache, SimExecutor, ThreadedExecutor,
                        path_key, open_cache)
from repro.core.types import MB
from repro.data.pipeline import CachedTokenPipeline, make_token_dataset
from repro.storage import RemoteStore, make_dataset

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  window=40, reanalyze_every=20)


def mk_store():
    store = RemoteStore()
    store.add(make_dataset("flat", "flat_files", n_files=120,
                           small_file_size=256 * 1024))
    store.add(make_dataset("big", "big_files", n_files=6, file_size=24 * MB))
    return store


class GatedStore:
    """BackingStore wrapper whose fetches block until released — makes
    worker progress controllable so queue overflow/shutdown/dedup tests
    are deterministic."""

    def __init__(self, store):
        self.store = store
        self.gate = threading.Event()
        self.fetches = 0

    def fetch_block(self, path, size):
        self.gate.wait(timeout=10.0)
        self.fetches += 1
        return self.store.fetch_block(path, size)

    # StoreMeta passthrough so the engine can also be built on it if needed
    def __getattr__(self, name):
        return getattr(self.store, name)


def seq_candidates(store, engine, n=64):
    """Kernel-issued prefetch candidates: drive a sequential whole-file
    scan until the engine classifies the stream (window=40) and emits
    readahead, and return the issued candidates (kernel pending-table
    entries included)."""
    cands = []
    t = 0.0
    for f in store.datasets["flat"].files:
        out = engine.read(f.path, 0, f.size, t)
        cands.extend(out.prefetches)
        t += 0.01
        if len(cands) >= n:
            break
    return cands


def executor_identity(stats):
    return stats.completed + stats.cancelled + stats.deduped


# ---------------------------------------------------------------------------
# cancellation: overflow + shutdown
# ---------------------------------------------------------------------------

def test_overflow_cancels_on_kernel_not_drops():
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    gated = GatedStore(store)
    ex = ThreadedExecutor(queue_depth=2, max_fetch_bytes=4096)
    client = CacheClient(engine, backing=gated, executor=ex)
    cands = seq_candidates(store, engine, n=24)
    assert len(cands) >= 8, "workload failed to generate candidates"
    issued = {path_key(p) for p, _ in cands}
    assert issued <= engine._pending_prefetch

    ex.submit(cands, 1.0)      # worker blocked: 1 in flight + 2 queued max
    assert ex.stats.cancelled >= len(cands) - 3
    # cancelled candidates must be released from the kernel pending table
    # (a silently dropped candidate would block that block's re-issue)
    gated.gate.set()
    assert client.flush(timeout=10.0)
    client.close()
    assert executor_identity(ex.stats) == ex.stats.submitted
    leaked = engine._pending_prefetch & issued
    assert not leaked, f"pending-table leak: {sorted(leaked)[:3]}"


def test_shutdown_cancels_queued_candidates():
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    gated = GatedStore(store)
    ex = ThreadedExecutor(queue_depth=4096, max_fetch_bytes=4096)
    client = CacheClient(engine, backing=gated, executor=ex)
    cands = seq_candidates(store, engine, n=24)
    assert len(cands) >= 8
    ex.submit(cands, 1.0)
    assert ex.stats.cancelled == 0          # deep queue: nothing overflowed
    gated.gate.set()                        # let the in-flight one finish
    client.close(cancel_pending=True)       # everything still queued: cancel
    assert ex.stats.cancelled > 0
    assert executor_identity(ex.stats) == ex.stats.submitted
    issued = {path_key(p) for p, _ in cands}
    assert not (engine._pending_prefetch & issued)


def test_dedup_drops_requeued_candidate():
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    gated = GatedStore(store)
    ex = ThreadedExecutor(queue_depth=4096, max_fetch_bytes=4096)
    client = CacheClient(engine, backing=gated, executor=ex)
    cands = seq_candidates(store, engine, n=8)[:4]
    ex.submit(cands, 1.0)
    ex.submit(cands, 1.1)       # same blocks, still queued → dedup
    assert ex.stats.deduped >= len(cands) - 1   # first may be in flight
    gated.gate.set()
    assert client.flush(timeout=10.0)
    client.close()
    assert executor_identity(ex.stats) == ex.stats.submitted


def test_null_executor_cancels_everything():
    store = mk_store()
    client = open_cache(store, 128 * MB, cfg=CFG, executor="none")
    engine = client.engine
    t = 0.0
    for f in store.datasets["flat"].files:
        client.read(f.path, 0, f.size, t)
        t += 0.01
    st = client.executor.stats
    assert st.submitted > 0
    assert st.cancelled == st.submitted
    assert not engine._pending_prefetch


def test_open_cache_rejects_unknown_executor():
    store = mk_store()
    with pytest.raises(ValueError):
        open_cache(store, 64 * MB, cfg=CFG, executor="warp-drive")


def test_submit_after_close_raises_and_releases():
    """Close-vs-submit race (ISSUE 5 satellite): a submit that loses the
    race against close() must raise cleanly instead of enqueueing into a
    dead queue — but only after releasing every candidate on the kernel
    (the pending table must not leak just because the caller was late)."""
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    ex = ThreadedExecutor(queue_depth=64)
    client = CacheClient(engine, backing=store, executor=ex)
    cands = seq_candidates(store, engine, n=8)
    client.close()
    before = ex.stats.cancelled
    with pytest.raises(RuntimeError):
        ex.submit(cands, 1.0)   # late offer: executor is closed
    assert ex.stats.cancelled >= before + len(cands)
    assert executor_identity(ex.stats) == ex.stats.submitted
    issued = {path_key(p) for p, _ in cands}
    assert not (engine._pending_prefetch & issued)


class FailingStore:
    """BackingStore that errors until told otherwise (real object-store
    adapters fail; the shard worker must survive and the blocked reader
    must see the error)."""

    def __init__(self, store):
        self.store = store
        self.fail = True

    def fetch_block(self, path, size):
        if self.fail:
            raise IOError("backend down")
        return self.store.fetch_block(path, size)


def test_demand_fetch_after_close_raises_instead_of_hanging():
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    ex = ThreadedExecutor()
    client = CacheClient(engine, backing=store, executor=ex,
                         fetch_bytes=True)
    client.close()
    f = store.datasets["big"].files[0]
    with pytest.raises(RuntimeError):
        client.read(f.path, 0, 1 * MB, 1.0)


def test_demand_fetch_error_propagates_without_killing_worker():
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    failing = FailingStore(store)
    ex = ThreadedExecutor()
    client = CacheClient(engine, backing=failing, executor=ex,
                         fetch_bytes=True)
    f = store.datasets["big"].files[0]
    with pytest.raises(IOError):
        client.read(f.path, 0, 1 * MB, 1.0)
    assert all(w.is_alive() for w in ex._workers)
    failing.fail = False                     # store recovers
    res = client.read(f.path, 0, 1 * MB, 2.0)
    assert len(res.data) == 1 * MB
    client.close()


# ---------------------------------------------------------------------------
# demand priority + racing complete_prefetch vs demand miss
# ---------------------------------------------------------------------------

def test_demand_fetch_preempts_queued_prefetches():
    store = mk_store()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    gated = GatedStore(store)
    ex = ThreadedExecutor(queue_depth=4096, max_fetch_bytes=4096)
    client = CacheClient(engine, backing=gated, executor=ex,
                         fetch_bytes=True)
    cands = seq_candidates(store, engine, n=16)
    ex.submit(cands, 1.0)       # queue full of background work, worker gated
    gated.gate.set()
    f = store.datasets["big"].files[0]          # untouched → demand miss
    res = client.read(f.path, 0, 1 * MB, 2.0)   # needs bytes NOW
    assert res.data is not None and len(res.data) == 1 * MB
    assert ex.stats.demand_fetches >= 1
    client.close()


def test_racing_complete_prefetch_vs_demand_miss():
    """Demand reads hammer the same blocks the background workers are
    completing; the per-shard guard serializes kernel access, so counters
    and residency must stay consistent (no lost updates, no over-capacity
    admission)."""
    store = mk_store()
    client = open_cache(store, 96 * MB, cfg=CFG, executor="threaded",
                        queue_depth=4096, max_fetch_bytes=256)
    engine = client.engine
    files = store.datasets["big"].files
    errors = []

    def reader(seed):
        rng = np.random.default_rng(seed)
        try:
            for i in range(300):
                f = files[int(rng.integers(0, len(files)))]
                b = int(rng.integers(0, f.size // CFG.block_size))
                client.read(f.path, b * CFG.block_size, 64 * 1024)
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(s,)) for s in range(3)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert client.flush(timeout=15.0)
    client.close()
    assert not errors
    st = engine.stats
    assert st.hits + st.misses == st.accesses == 900
    ex = client.executor.stats
    assert executor_identity(ex) == ex.submitted
    assert engine.cache.used_bytes() <= engine.cache.capacity


# ---------------------------------------------------------------------------
# per-shard workers
# ---------------------------------------------------------------------------

def test_threaded_executor_runs_one_worker_per_shard():
    store = RemoteStore()
    for i in range(4):
        store.add(make_dataset(f"ds{i}", "flat_files", n_files=80,
                               small_file_size=256 * 1024))
    client = open_cache(store, 128 * MB, cfg=CFG, n_shards=4,
                        executor="threaded")
    assert isinstance(client.engine, ShardedIGTCache)
    ex = client.executor
    assert len(ex._workers) == 4 and len(ex._queues) == 4
    t = 0.0
    for ds in store.datasets.values():
        for f in ds.files:
            client.read(f.path, 0, f.size, t)
            t += 0.01
    assert client.flush(timeout=15.0)
    client.close()
    st = ex.stats
    assert st.submitted > 0
    assert executor_identity(st) == st.submitted
    for shard in client.engine.shards:
        assert not shard._pending_prefetch


# ---------------------------------------------------------------------------
# byte path
# ---------------------------------------------------------------------------

def test_client_bytes_match_backing_store():
    store = mk_store()
    client = open_cache(store, 128 * MB, cfg=CFG, executor="sim",
                        fetch_bytes=True)
    f = store.datasets["big"].files[0]
    bs = CFG.block_size
    res = client.read(f.path, 3 * MB, 6 * MB, 1.0)   # spans blocks 0..2
    ref = np.concatenate([store.fetch_block(f.path + (f"#{b}",), bs)
                          for b in range(3)])
    assert np.array_equal(res.data, ref[3 * MB: 9 * MB])
    # second read: cache hits, identical bytes
    res2 = client.read(f.path, 3 * MB, 6 * MB, 2.0)
    assert all(b.hit for b in res2.blocks)
    assert np.array_equal(res2.data, res.data)
    # oversized request clamps to the file
    small = store.datasets["flat"].files[0]
    res3 = client.read(small.path, 100, small.size * 10, 3.0)
    assert len(res3.data) == small.size - 100


def test_sim_executor_moves_no_bytes_by_default():
    store = mk_store()
    counting = GatedStore(store)
    counting.gate.set()
    engine = IGTCache(store, 128 * MB, cfg=CFG)
    client = CacheClient(engine, backing=counting, executor=SimExecutor())
    for f in store.datasets["flat"].files:
        client.read(f.path, 0, f.size)
    assert client.executor.stats.completed > 0
    assert counting.fetches == 0            # virtual-clock: sizes only


# ---------------------------------------------------------------------------
# pipeline accounting (satellite: cancels visible in PipelineStats)
# ---------------------------------------------------------------------------

def _token_world():
    store = RemoteStore()
    store.add(make_token_dataset("corpus", n_shards=4, shard_bytes=2 * MB))
    ccfg = CacheConfig(min_share=2 * MB, rebalance_quantum=2 * MB,
                       rebalance_period=5.0, block_size=1 * MB,
                       window=40, reanalyze_every=20)
    return store, ccfg


def test_pipeline_stats_expose_cancelled_vs_completed():
    # one sample per small file → a sequential epoch is a file scan that
    # keeps issuing file-level readahead candidates
    store = RemoteStore()
    store.add(make_dataset("corpus", "flat_files", n_files=200,
                           small_file_size=64 * 1024))
    ccfg = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                       window=40, reanalyze_every=20)
    engine = IGTCache(store, 64 * MB, cfg=ccfg)
    gated = GatedStore(store)
    ex = ThreadedExecutor(queue_depth=1, max_fetch_bytes=512)
    client = CacheClient(engine, backing=gated, executor=ex)
    pipe = CachedTokenPipeline(store, client, "corpus", seq_len=32, batch=4,
                               vocab=1000, sample_bytes=64 * 1024,
                               access_pattern="sequential")
    for _ in pipe.batches(epochs=1):
        pass
    gated.gate.set()
    pipe.flush(timeout=10.0)
    client.close()
    pipe.close()
    s = pipe.stats
    assert s.prefetch_submitted > 0, "sequential scan issued no candidates"
    assert s.prefetch_cancelled > 0, \
        "depth-1 queue behind a gated store must overflow-cancel"
    assert s.prefetch_completed + s.prefetch_cancelled <= s.prefetch_submitted
    assert not engine._pending_prefetch    # nothing silently dropped


def test_pipeline_threaded_hit_ratio_matches_inline_within_2pct():
    """Acceptance: CachedTokenPipeline under the ThreadedExecutor matches
    the deterministic inline-completion path within 2% CHR on the seeded
    token workload (the old PrefetchWorker semantics, minus the lost
    candidates)."""

    def run(background):
        store, ccfg = _token_world()
        engine = IGTCache(store, 64 * MB, cfg=ccfg)   # corpus (8MB) fits
        pipe = CachedTokenPipeline(store, engine, "corpus", seq_len=32,
                                   batch=4, vocab=1000, seed=0,
                                   sample_bytes=4096,
                                   background_prefetch=background)
        for _ in pipe.batches(epochs=2):
            pipe.flush(timeout=10.0)   # epoch-deterministic completion
        hr = pipe.stats.hit_ratio
        pipe.close()
        return hr

    inline, threaded = run(False), run(True)
    assert inline > 0.4                     # epoch 2 ~fully cached
    assert abs(threaded - inline) <= 0.02, (threaded, inline)

"""Fast --smoke run of the overhead benchmark: keeps the perf-tracking
pipeline (BENCH_overhead.json emission) exercised in the test job."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def test_overhead_smoke_emits_json(tmp_path):
    from benchmarks import overhead

    out = tmp_path / "BENCH_overhead.json"
    rows = overhead.main(smoke=True, json_path=out)
    assert rows, "smoke run produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["smoke"] is True
    at10k = payload["results"]["10000"]
    assert at10k["us_per_access"] > 0
    assert at10k["nodes"] > 0
    assert "seed_reference" in payload
    assert "speedup_vs_pr1_start_seed" in payload
    # sharded-facade axis: both shard counts measured (interleaved) into the
    # perf trajectory
    for n in ("1", "4"):
        point = payload["sharded"][n]
        assert point["us_per_access"] > 0
        assert point["nodes"] > 0

"""Fast --smoke run of the overhead benchmark: keeps the perf-tracking
pipeline (BENCH_overhead.json emission) exercised in the test job."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def test_overhead_smoke_emits_json(tmp_path):
    from benchmarks import overhead

    out = tmp_path / "BENCH_overhead.json"
    rows = overhead.main(smoke=True, json_path=out)
    assert rows, "smoke run produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["smoke"] is True
    at10k = payload["results"]["10000"]
    assert at10k["us_per_access"] > 0
    assert at10k["nodes"] > 0
    assert "seed_reference" in payload
    assert "speedup_vs_pr1_start_seed" in payload
    # sharded-facade axis: every default shard count measured
    # (interleaved) into the perf trajectory
    for n in ("1", "4", "8", "16"):
        point = payload["sharded"][n]
        assert point["us_per_access"] > 0
        assert point["nodes"] > 0
    # multi-process driver axis (merged section, --procs 1,2,4): the
    # kernel loop and the in-process facade ride along for the
    # interleaved comparison.  Smoke asserts presence, not ordering —
    # the down-scaled run is too short for a meaningful race.
    axis = payload["proc_path"]
    assert axis["smoke"] is True
    for key in ("kernel_1", "facade_4", "proc_1", "proc_2", "proc_4"):
        assert axis[key]["us_per_access"] > 0
    assert "speedup_4p_vs_1p" in axis
    assert "speedup_4p_vs_kernel" in axis
    # rebalance_path axis (merged section; smoke runs the first shard
    # count >1 only): sketch-based demand summaries drive the planner —
    # CHR gap vs unsharded recorded for both quantum policies, and the
    # per-round summary payload stays KB-scale
    reb = payload["rebalance_path"]
    assert reb["smoke"] is True
    assert reb["unsharded_chr"] > 0
    for key in ("adaptive_4", "fixed_4"):
        point = reb[key]
        assert point["chr"] > 0
        assert point["rounds"] > 0
        assert point["summary_bytes_round_max"] > 0
        assert point["summary_bytes_round_max"] <= 4 * 4096
    # the adaptive policy is the one that converges: never (meaningfully)
    # worse than the fixed-quantum legacy path on the same trace
    assert reb["adaptive_4"]["chr"] >= reb["fixed_4"]["chr"] - 0.01


def test_sketch_micro_smoke(tmp_path):
    """--smoke sketch_path axis: the PR-7 demand-tracking pipeline
    (update + per-stream query + ship/merge) — sketch vs exact
    ghost-counter path, merged into the shared overhead JSON.  The
    strict per-access crossover is a 1M-distinct full-scale claim; smoke
    checks the pipeline runs, stays in the same cost ballpark, and the
    wire payload is O(KB) while the exact dump is O(MB)."""
    from benchmarks import allocation_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = allocation_micro.run_sketch_micro(smoke=True, json_path=out)
    assert rows, "sketch_path smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["sketch_path"]
    assert axis["smoke"] is True
    for name in ("exact", "sketch"):
        assert axis[name]["us_per_access"] > 0
        assert axis[name]["wire_bytes"] > 0
    assert axis["sketch"]["wire_bytes"] <= 24 * 1024
    assert axis["exact"]["wire_bytes"] > 100 * 1024
    assert axis["wire_reduction"] > 10


def test_store_micro_smoke(tmp_path):
    """--smoke store_path axis: ranged vs whole-block over-fetch (sim +
    real-file store), batched vs serial demand fetches, and the
    synthesis-under-transfer guard, merged into the shared overhead JSON
    without clobbering other sections."""
    from benchmarks import store_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = store_micro.main(smoke=True, json_path=out)
    assert rows, "store_path smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["store_path"]
    assert axis["smoke"] is True
    for name in ("ranged_sim", "ranged_fs"):
        assert axis[name]["ranged_us"] > 0
        assert axis[name]["overfetch_us"] > 0
        assert axis[name]["bytes_moved_ratio"] > 1
    bd = axis["batched_demand"]
    assert bd["batched_us_per_req"] > 0 and bd["serial_us_per_req"] > 0
    # the satellite guard: synthesis must stay under the simulated
    # transfer budget (store_micro asserts it; the flag records it)
    assert axis["synthesis"]["synth_under_transfer"] is True
    assert axis["synthesis"]["synth_4mb_ms"] < \
        axis["synthesis"]["transfer_4mb_ms"]


def test_fault_micro_smoke(tmp_path):
    """--smoke availability axis: kill a shard worker mid-trace, record
    recovery time, degraded-read cost and post-recovery CHR gap, merged
    into the shared overhead JSON without clobbering other sections."""
    from benchmarks import fault_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = fault_micro.main(smoke=True, json_path=out)
    assert rows, "fault_path smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["fault_path"]
    assert axis["smoke"] is True
    # the worker was killed, respawned within budget, and the client
    # actually served reads around the dead shard
    assert axis["recovery_s"] is not None and axis["recovery_s"] > 0
    assert axis["chaos"]["restarts"] >= 1
    assert axis["chaos"]["degraded_reads"] > 0
    assert axis["chaos"]["degraded_bytes"] > 0
    assert all(s == "up" for s in axis["chaos"]["shard_states"].values())
    assert axis["baseline"]["us_per_batch"] > 0
    assert axis["chaos"]["degraded_batch_us"] > 0
    # gap is recorded (the 5 % bound is asserted by the chaos e2e test
    # on a long-enough trace, not by the down-scaled smoke run)
    assert "chr_gap_pct" in axis


def test_daemon_micro_smoke(tmp_path):
    """--smoke daemon_path axis: N forked ``open_cache("cache://...")``
    client processes against one UDS daemon; aggregate metadata
    throughput per client count merged into the shared overhead JSON
    without clobbering other sections.  Scaling ordering is the full
    run's claim — smoke asserts the pipeline and the accounting."""
    from benchmarks import daemon_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = daemon_micro.main(smoke=True, json_path=out)
    assert rows, "daemon_path smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["daemon_path"]
    assert axis["smoke"] is True
    for n in (1, 2, 4):
        point = axis[f"daemon_{n}"]
        assert point["accesses_per_s"] > 0
        assert point["us_per_access"] > 0
        assert point["accesses"] == n * axis["n_accesses_per_client"]
    assert axis["scaling_4_vs_1"] > 0
    # every bench client said goodbye; nothing was lease-reaped or spilled
    assert axis["daemon_stats"]["byes"] == 7
    assert axis["daemon_stats"]["reaped"] == 0
    assert axis["daemon_stats"]["served_reads"] > 0


def test_daemon_recovery_smoke(tmp_path):
    """--smoke daemon_recovery axis: kill a journaled daemon under its
    supervisor and record the recovery arc — degraded-read latency,
    respawn + journal restore, client reconnect, and the warm-vs-cold
    ramp back to a fully-hitting pass — merged into the shared overhead
    JSON without clobbering other sections."""
    from benchmarks import daemon_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = daemon_micro.run_recovery(smoke=True, json_path=out)
    assert rows, "daemon_recovery smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["daemon_recovery"]
    assert axis["smoke"] is True
    # the daemon died, the supervisor respawned it, the journal restored
    # the manifest, and the client reconnected — each leg timed
    assert axis["respawn_s"] > 0
    assert axis["reconnect_s"] > 0
    assert axis["restore"]["mode"] == "warm"
    assert axis["restore"]["blocks"] > 0
    # reads flowed (degraded) the whole time the daemon was away
    assert axis["degraded"]["reads"] > 0
    assert axis["degraded"]["us_per_read"] > 0
    assert axis["client"]["degraded_reads"] == axis["degraded"]["reads"]
    assert axis["client"]["reconnects"] >= 1
    # the acceptance contrast: a warm restart reaches a fully-hitting
    # pass at least as fast as the cold ramp did, and both converge
    assert axis["warm_ramp"]["final_pass_chr"] == 1.0
    assert axis["cold_ramp"]["final_pass_chr"] == 1.0
    assert axis["warm_ramp"]["passes"] <= axis["cold_ramp"]["passes"]


def test_tier_micro_smoke(tmp_path):
    """--smoke tier_path axis: flat-RAM vs RAM+disk at equal total
    capacity on the down-scaled paper suite, plus the bytes-mode
    spill/promote throughput micro, merged into the shared overhead JSON
    without clobbering other sections.  The tiered-wins ordering is the
    full run's claim — smoke asserts the pipeline and the accounting."""
    from benchmarks import tier_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = tier_micro.main(smoke=True, json_path=out)
    assert rows, "tier_path smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["tier_path"]
    assert axis["smoke"] is True
    assert axis["flat"]["kernel_chr"] > 0
    assert axis["flat"]["capacity_mb"] == axis["tiered"]["capacity_mb"]
    assert axis["tiered"]["combined_chr"] >= axis["tiered"]["kernel_chr"]
    assert axis["tiered"]["tier"]["disk_hits"] > 0
    assert axis["flat"]["link_mb"] > 0 and axis["tiered"]["link_mb"] > 0
    micro = axis["spill_micro"]
    assert micro["spilled"] == micro["blocks"] - 1   # one block stays in RAM
    assert micro["disk_hits"] > 0
    assert micro["spill_MBps"] > 0 and micro["promote_MBps"] > 0


def test_prefetch_micro_client_axis_smoke(tmp_path):
    """--smoke client-path axis: kernel loop vs SimExecutor client vs
    ThreadedExecutor client, merged into the shared overhead JSON without
    clobbering existing sections."""
    from benchmarks import prefetch_micro

    out = tmp_path / "BENCH_overhead.json"
    out.write_text(json.dumps({"results": {"10000": {"us_per_access": 1}}}))
    rows = prefetch_micro.main(smoke=True, json_path=out)
    assert rows, "client-axis smoke produced no CSV rows"
    payload = json.loads(out.read_text())
    assert payload["results"]["10000"]["us_per_access"] == 1  # preserved
    axis = payload["client_path"]
    assert axis["smoke"] is True
    for proto in ("kernel_loop", "client_sim", "client_threaded"):
        assert axis[proto]["us_per_access"] > 0
    assert "client_overhead_pct" in axis

"""Pattern recognition: the three behaviours the cache must distinguish."""
import random

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.pattern import classify, detect_sequential, distinct_deficit, fit_adaptive_ttl
from repro.core.types import AccessRecord, CacheConfig, Pattern

CFG = CacheConfig()


def recs(indices, total, dt=0.1):
    return [AccessRecord(int(i), total, t * dt, str(int(i)))
            for t, i in enumerate(indices)]


def test_sequential_unit_stride():
    r = recs(range(100), 1000)
    assert classify(r, 1000, CFG).pattern is Pattern.SEQUENTIAL


def test_sequential_with_zero_runs():
    # coarse level: long runs of the same child then +1 (dir traversal)
    idx = [i // 10 for i in range(100)]
    r = recs(idx, 50)
    assert classify(r, 50, CFG).pattern is Pattern.SEQUENTIAL


def test_random_permutation():
    rng = random.Random(1)
    hits = 0
    for t in range(20):
        perm = list(range(2000))
        rng.shuffle(perm)
        r = recs(perm[:100], 2000)
        hits += classify(r, 2000, CFG).pattern is Pattern.RANDOM
    assert hits >= 18


def test_skewed_zipf_scattered():
    # hot items scattered in index space: caught by the distinct screen
    rng = np.random.default_rng(2)
    hits = 0
    for t in range(20):
        perm = rng.permutation(2000)
        idx = perm[(rng.zipf(1.3, 100) - 1) % 2000]
        r = recs(idx, 2000)
        hits += classify(r, 2000, CFG).pattern is Pattern.SKEWED
    assert hits >= 18


def test_skewed_zipf_clustered():
    rng = np.random.default_rng(3)
    idx = np.minimum((rng.zipf(1.4, 100) - 1) * 3, 1999)
    r = recs(idx, 2000)
    assert classify(r, 2000, CFG).pattern is Pattern.SKEWED


@given(st.integers(200, 5000), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_random_detection_property(c, seed):
    rng = random.Random(seed)
    perm = list(range(c))
    rng.shuffle(perm)
    r = recs(perm[:100], c)
    # permutations must never be classified sequential
    assert classify(r, c, CFG).pattern is not Pattern.SEQUENTIAL


def test_distinct_deficit_direction():
    uniform = list(np.random.default_rng(0).integers(0, 1000, 100))
    hot = [1, 2, 3, 4] * 25
    assert distinct_deficit(uniform, 1000) < 3.0
    assert distinct_deficit(hot, 1000) > 10.0


def test_adaptive_ttl():
    times = [i * 1.0 for i in range(100)]       # 1s gaps, sigma ~0
    ttl = fit_adaptive_ttl(times, CFG)
    assert ttl is not None
    assert CFG.ttl_base + 1.0 <= ttl <= CFG.ttl_base + 2.0


def test_ttl_needs_samples():
    assert fit_adaptive_ttl([1.0], CFG) is None

"""UnifiedCache / CacheManageUnit space-isolation invariants."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache import CacheManageUnit, UnifiedCache, path_key
from repro.core.types import CacheConfig, Pattern

MB = 1 << 20
CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB, block_size=MB)


def mk_cache(capacity=64 * MB):
    return UnifiedCache(capacity, CFG)


def test_quota_partition_invariant_on_create():
    c = mk_cache()
    c.create_cmu(("a",), dataset_bytes=100 * MB, now=0.0)
    c.create_cmu(("b",), dataset_bytes=10 * MB, now=1.0)
    assert sum(x.quota for x in c.cmus.values()) <= c.capacity
    assert all(x.quota >= 0 for x in c.cmus.values())


def test_cmu_used_never_exceeds_quota():
    c = mk_cache()
    cmu = c.create_cmu(("a",), dataset_bytes=100 * MB, now=0.0)
    sub = cmu.substream(("a",), Pattern.SKEWED)
    for i in range(100):
        c.insert(("a", f"f{i}", "#0"), MB, cmu, sub)
        assert cmu.used <= cmu.quota
    assert cmu.used <= cmu.quota


def test_uniform_stops_admitting():
    c = mk_cache(capacity=16 * MB)
    cmu = c.create_cmu(("a",), dataset_bytes=100 * MB, now=0.0)
    sub = cmu.substream(("a",), Pattern.RANDOM)
    admitted = sum(
        c.insert(("a", f"f{i}", "#0"), MB, cmu, sub) for i in range(50))
    assert admitted == cmu.quota // MB            # pinned then refused
    assert cmu.used == admitted * MB


def test_quota_shrink_forces_eviction():
    c = mk_cache()
    cmu = c.create_cmu(("a",), dataset_bytes=100 * MB, now=0.0)
    sub = cmu.substream(("a",), Pattern.SKEWED)
    for i in range(int(cmu.quota // MB)):
        c.insert(("a", f"f{i}", "#0"), MB, cmu, sub)
    before = cmu.used
    cmu.set_quota(cmu.quota // 2)
    assert cmu.used <= cmu.quota
    assert cmu.used < before


def test_migration_on_cmu_creation():
    c = mk_cache()
    d = c.default_cmu
    sub = d.substream(("x",), Pattern.UNKNOWN)
    key_path = ("x", "f1", "#0")
    assert c.insert(key_path, MB, d, sub)
    cmu = c.create_cmu(("x",), dataset_bytes=10 * MB, now=0.0)
    assert c.resident(path_key(key_path))
    assert cmu.resident(path_key(key_path))
    assert not d.resident(path_key(key_path))
    assert cmu.used == MB


def test_remove_cmu_adopts_blocks():
    c = mk_cache()
    cmu = c.create_cmu(("a",), dataset_bytes=10 * MB, now=0.0)
    sub = cmu.substream(("a",), Pattern.SKEWED)
    c.insert(("a", "f", "#0"), MB, cmu, sub)
    q = cmu.quota
    c.remove_cmu(("a",))
    assert ("a",) not in c.cmus
    assert c.resident("a/f/#0")                  # adopted, not dropped
    assert c.default_cmu.resident("a/f/#0")


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 40)),
                max_size=250))
@settings(max_examples=40, deadline=None)
def test_global_residency_consistency(ops):
    """Random inserts across streams: every resident block belongs to exactly
    one CMU; global used == sum of CMU used; quotas partition capacity."""
    c = mk_cache(capacity=32 * MB)
    cmus = {}
    for ds, i in ops:
        root = (f"ds{ds}",)
        if root not in cmus:
            cmus[root] = c.create_cmu(root, dataset_bytes=64 * MB,
                                      now=float(i))
        cmu = cmus[root]
        sub = cmu.substream(root, Pattern.SKEWED)
        c.insert(root + (f"f{i}", "#0"), MB, cmu, sub)
    assert sum(x.quota for x in c.cmus.values()) <= c.capacity
    total_used = sum(x.used for x in c.cmus.values())
    assert total_used == sum(sz for sz, _ in c.blocks.values())
    assert total_used <= c.capacity
    for key, (sz, cmu) in c.blocks.items():
        assert cmu.resident(key)

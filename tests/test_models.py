"""Per-architecture smoke tests (REDUCED configs, as assigned): one forward
and one train step on CPU, asserting output shapes and no NaNs; plus
prefill↔decode consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, get_config, reduced_config
from repro.models.transformer import (decode_step, forward, init_decode_state,
                                      init_params, lm_loss)

ARCHS = sorted(CONFIGS)
RNG = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg):
    kw = {}
    if cfg.family == "audio":
        kw["inputs_embeds"] = jax.random.normal(
            RNG, (B, S, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
        tokens = None
    else:
        tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        kw["img_embeds"] = jax.random.normal(
            RNG, (B, cfg.n_image_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, RNG)
    tokens, kw = _inputs(cfg)
    logits, aux = forward(params, cfg, tokens, remat="none", **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    labels = jax.random.randint(RNG, (B, S), 0, cfg.vocab)

    def loss_fn(p):
        lg, ax = forward(p, cfg, tokens, remat="full", **kw)
        return lm_loss(lg, labels, ax if cfg.family == "moe" else None)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    assert not any(bool(jnp.isnan(g.astype(jnp.float32)).any())
                   for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode must reproduce the full-sequence logits."""
    import dataclasses
    cfg = reduced_config(arch)
    if cfg.family == "moe":
        # drop-free capacity so prefill and decode route identically
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, RNG)
    tokens, kw = _inputs(cfg)
    full_logits, _ = forward(params, cfg, tokens, remat="none", **kw)

    state = init_decode_state(cfg, B, S + 4,
                              img_embeds=kw.get("img_embeds"), params=params)
    outs = []
    for t in range(S):
        if cfg.family == "audio":
            lg, state = decode_step(params, cfg, state,
                                    inputs_embeds=kw["inputs_embeds"][:, t:t+1])
        else:
            lg, state = decode_step(params, cfg, state, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    ref = full_logits.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=0.15, rtol=0.05)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expect
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "granite-moe-3b-a800m":
        assert (cfg.n_experts, cfg.top_k) == (40, 8)
    if arch in ("zamba2-1.2b",):
        assert cfg.ssm_state == 64
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128
    # long_500k only for sub-quadratic archs
    if arch in ("zamba2-1.2b", "mamba2-370m"):
        assert "long_500k" not in cfg.skip_shapes
    else:
        assert "long_500k" in cfg.skip_shapes


def test_moe_aux_loss_nonzero():
    cfg = reduced_config("qwen3-moe-30b-a3b")
    params = init_params(cfg, RNG)
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    _, aux = forward(params, cfg, tokens, remat="none")
    assert float(aux) > 0.0

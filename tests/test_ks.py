"""K-S machinery vs scipy + analytical properties."""
import math
import random

import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytest.importorskip("scipy")
from hypothesis import given, settings, strategies as st
from scipy import stats

from repro.core.ks import (ecdf_ks_statistic, ks_critical, ks_test_random,
                           normal_quantile, triangular_cdf)


def test_triangular_cdf_matches_pmf_sum():
    c = 50
    pmf = [2 * (c - k) / (c * (c - 1)) for k in range(1, c)]
    assert math.isclose(sum(pmf), 1.0, rel_tol=1e-9)
    acc = 0.0
    for k in range(1, c):
        acc += pmf[k - 1]
        assert math.isclose(triangular_cdf(k, c), acc, rel_tol=1e-9)
    assert triangular_cdf(0, c) == 0.0
    assert triangular_cdf(c + 5, c) == pytest.approx(1.0)


@given(st.lists(st.floats(0, 1000, allow_nan=False), min_size=5, max_size=200))
@settings(max_examples=50, deadline=None)
def test_ks_statistic_matches_scipy_uniform(samples):
    hi = max(samples) + 1.0
    cdf = lambda x: min(1.0, max(0.0, x / hi))
    ours = ecdf_ks_statistic(samples, cdf)
    ref = stats.ks_1samp(samples, lambda x: np.clip(np.asarray(x) / hi, 0, 1),
                         alternative="two-sided").statistic
    assert ours == pytest.approx(float(ref), abs=1e-9)


def test_ks_critical_close_to_scipy():
    for n in (20, 50, 100, 500):
        for alpha in (0.01, 0.05):
            exact = stats.ksone.isf(alpha / 2, n)  # two-sided approx
            assert ks_critical(n, alpha) == pytest.approx(exact, rel=0.05)


def test_random_permutation_gaps_accepted():
    rng = random.Random(0)
    c = 5000
    accept = 0
    trials = 50
    for t in range(trials):
        perm = list(range(c))
        rng.shuffle(perm)
        window = perm[:101]
        gaps = [abs(window[i] - window[i - 1]) for i in range(1, len(window))]
        ok, d, da = ks_test_random(gaps, c, alpha=0.01)
        accept += ok
    assert accept >= 0.9 * trials  # ~1 - alpha


def test_zipf_clustered_gaps_rejected():
    rng = np.random.default_rng(0)
    c = 5000
    reject = 0
    trials = 30
    for t in range(trials):
        idx = np.minimum((rng.zipf(1.5, 101) - 1) * 7, c - 1)  # clustered hot
        gaps = np.abs(np.diff(idx))
        ok, _, _ = ks_test_random(list(gaps), c, alpha=0.01)
        reject += not ok
    assert reject >= 0.8 * trials


def test_normal_quantile():
    for p, z in [(0.5, 0.0), (0.975, 1.959964), (0.99, 2.326348),
                 (0.01, -2.326348)]:
        assert normal_quantile(p) == pytest.approx(z, abs=1e-5)

"""Multi-process shard driver invariants (the procdriver tentpole).

Driver matrix coverage the ISSUE pins: single-worker equivalence against
the in-process kernel (outcomes + merged stats), request-order
preservation across shard splits, the full ``PrefetchExecutor`` contract
for :class:`ProcessExecutor` (``submitted == completed + cancelled +
deduped`` at close; worker-side pending tables never leak — including
under worker-side ``TransientStoreError`` retries and permanent
failures), CHR parity of ``ProcessExecutor(n_procs=1)`` vs the
``ThreadedExecutor`` on the seeded mixed trace, demand bytes crossing
through the shared-memory arena (zero spills, slots recycled), the
serialized rebalance-summary protocol conserving capacity, and clean
shutdown with prefetches in flight.

Every test runs under a hard SIGALRM guard: a deadlocked worker or a
lost reply must fail the test, not hang tier-1.
"""
import gc
import signal

import numpy as np
import pytest

from repro.core import (CacheConfig, GlobalRebalancer, IGTCache,
                        ProcessExecutor, ProcessShardedCache,
                        ShardedIGTCache, open_cache)
from repro.core.procdriver import WireOutcome
from repro.core.sharded import DemandSummary, ShardSummary
from repro.core.types import MB
from repro.storage import RemoteStore, make_dataset
from repro.storage.api import FaultyStore, store_spec

CFG = CacheConfig(min_share=4 * MB, rebalance_quantum=4 * MB,
                  window=40, reanalyze_every=20, node_cap=500)

HARD_TIMEOUT_S = 180


@pytest.fixture(autouse=True)
def hard_timeout():
    """Multiprocessing tests must never hang tier-1: a deadlocked worker
    or a lost pipe reply raises here instead of stalling the job."""

    def boom(signum, frame):  # pragma: no cover - only fires on deadlock
        raise TimeoutError(
            f"procdriver test exceeded the {HARD_TIMEOUT_S}s hard timeout "
            f"(deadlocked worker / lost reply?)")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def mk_store(n_datasets=4):
    store = RemoteStore()
    for i in range(n_datasets):
        store.add(make_dataset(f"ds{i}", "dir_tree", n_dirs=4,
                               files_per_dir=8, small_file_size=512 * 1024))
    return store


def mk_flat_store():
    """Sequential-scan-friendly layout: long single-directory streams
    clear the observation window and emit readahead candidates."""
    store = RemoteStore()
    for name in ("flat0", "flat1"):
        store.add(make_dataset(name, "flat_files", n_files=120,
                               small_file_size=256 * 1024))
    return store


def all_files(store):
    return [f for ds in store.datasets.values() for f in ds.files]


def executor_identity(st):
    return st.completed + st.cancelled + st.deduped


# ---------------------------------------------------------------------------
# equivalence + ordering
# ---------------------------------------------------------------------------

def test_single_worker_matches_inprocess_kernel():
    """n_procs=1, inline prefetch: the worker-resident kernel must
    evolve exactly like the caller-driven in-process loop — same
    per-block outcomes, same merged stats, on a mixed seeded trace."""
    store = mk_store()
    mono = IGTCache(store, 64 * MB, cfg=CFG)
    with ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=1,
                             prefetch="inline") as eng:
        files = all_files(store)
        rng = np.random.default_rng(7)
        t = 0.0
        for rep in range(3):
            picks = rng.integers(0, len(files), 40)
            reqs = []
            for j in picks:
                f = files[int(j)]
                off = int(rng.integers(0, 2)) * 256 * 1024
                reqs.append((f.path, off, f.size))
            outs = eng.read_batch(reqs, t)
            ref = mono.read_batch(reqs, t)
            for got, want in zip(outs, ref):
                assert [(b.key, b.size, b.hit, b.prefetched_hit)
                        for b in got.blocks] == \
                       [(b.key, b.size, b.hit, b.prefetched_hit)
                        for b in want.blocks]
                assert got.remote_bytes == want.remote_bytes
                assert got.cached_bytes == want.cached_bytes
            for o in ref:          # the worker completed inline already
                for p, s in o.prefetches:
                    mono.complete_prefetch(p, s, t)
            t += 0.5
        assert eng.stats.snapshot() == mono.stats.snapshot()
        assert eng.node_count() == mono.tree.node_count()


def test_read_batch_preserves_request_order_across_workers():
    store = mk_store(6)
    mono = IGTCache(store, 64 * MB, cfg=CFG)
    with ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=4,
                             prefetch="inline") as eng:
        # interleave datasets so consecutive requests hit different shards
        files = []
        dss = list(store.datasets.values())
        for i in range(8):
            for ds in dss:
                files.append(ds.files[i])
        reqs = [(f.path, 0, f.size) for f in files]
        outs = eng.read_batch(reqs, 0.0)
        ref = mono.read_batch(reqs, 0.0)
        assert len(outs) == len(reqs)
        for got, want in zip(outs, ref):
            assert [b.key for b in got.blocks] == [b.key for b in want.blocks]


def test_routing_matches_inprocess_facade():
    """Same ShardRouting mixin → a path lands on the same shard index
    under either driver (placement cannot drift between them)."""
    store = mk_store(6)
    facade = ShardedIGTCache(store, 64 * MB, cfg=CFG, n_shards=4)
    with ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=4) as eng:
        for f in all_files(store):
            assert eng.shard_id(f.path) == facade.shard_id(f.path)
        f = store.datasets["ds0"].files[0]
        eng.read(f.path, 0, f.size, 0.0)
        gathered = eng._gather_stats()
        sid = eng.shard_id(f.path)
        for i, g in enumerate(gathered):
            assert g["stats"].accesses == (1 if i == sid else 0)


def test_wire_outcome_reconstructs_keys():
    enc = (3, [4, 5], 0b01, 0b00, [])
    out = WireOutcome(enc, ("ds", "a", "f.bin"))
    assert [b.key for b in out.blocks] == ["ds/a/f.bin/#3", "ds/a/f.bin/#4"]
    assert out.blocks[0].hit and not out.blocks[1].hit
    assert out.remote_bytes == 5 and out.cached_bytes == 4


# ---------------------------------------------------------------------------
# executor contract
# ---------------------------------------------------------------------------

def _drive_client(client, store, reps=1):
    t = 0.0
    for _ in range(reps):
        for ds in store.datasets.values():
            for f in ds.files:
                client.read(f.path, 0, f.size, t)
                t += 0.01
    return t


def test_process_executor_stats_conservation():
    store = mk_flat_store()
    client = open_cache(store, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2)
    assert isinstance(client.engine, ProcessShardedCache)
    assert isinstance(client.executor, ProcessExecutor)
    _drive_client(client, store, reps=2)
    assert client.flush(timeout=30.0)
    st = client.executor.stats
    engine = client.engine
    assert st.submitted > 0, "trace generated no prefetch candidates"
    pending = engine.pending_prefetch_count()
    client.close()
    assert executor_identity(st) == st.submitted, st.snapshot()
    assert pending == 0, "worker kernels leaked pending candidates"


def test_dedup_and_overflow_cancel_on_worker_kernel():
    store = mk_flat_store()
    with ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=2) as eng:
        ex = ProcessExecutor(queue_depth=2, max_fetch_bytes=0)
        from repro.core import CacheClient
        client = CacheClient(eng, backing=store, executor=ex)
        # generate real kernel candidates (sequential whole-file scans)
        cands = []
        t = 0.0
        for f in store.datasets["flat0"].files:
            out = eng.read(f.path, 0, f.size, t)
            cands.extend(out.prefetches)
            t += 0.01
            if len(cands) >= 12:
                break
        assert len(cands) >= 8, "workload failed to generate candidates"
        ex.submit(cands, t)      # depth-2 queue: most overflow-cancel
        ex.submit(cands, t)      # re-offer: queued ones dedup
        assert client.flush(timeout=30.0)
        st = ex.stats
        assert st.deduped > 0 or st.cancelled > 0
        ex.close()
        assert executor_identity(st) == st.submitted, st.snapshot()
        assert eng.pending_prefetch_count() == 0


def test_submit_after_close_raises_and_releases():
    store = mk_store()
    client = open_cache(store, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2)
    eng = client.engine
    out = eng.read(store.datasets["ds0"].files[0].path, 0, 512 * 1024, 0.0)
    ex = client.executor
    ex.close()
    cands = [((f"ds0", "x", f"f{i}", "#0"), 1024) for i in range(3)]
    before = ex.stats.cancelled
    with pytest.raises(RuntimeError):
        ex.submit(cands, 1.0)
    assert ex.stats.cancelled >= before + len(cands)
    assert executor_identity(ex.stats) == ex.stats.submitted
    eng.close()


def test_chr_parity_process_vs_threaded_executor():
    """ProcessExecutor(n_procs=1) must land within 2% CHR of the
    ThreadedExecutor on the seeded mixed trace (same kernel decisions,
    different prefetch transport)."""

    def run(kind):
        store = mk_store()
        if kind == "threaded":
            client = open_cache(store, 48 * MB, cfg=CFG,
                                executor="threaded", max_fetch_bytes=0)
        else:
            client = open_cache(store, 48 * MB, cfg=CFG, driver="process",
                                n_procs=1, max_fetch_bytes=0)
        files = all_files(store)
        rng = np.random.default_rng(3)
        for i in range(600):
            f = files[int(rng.integers(0, len(files)))]
            client.read(f.path, 0, f.size)
            if i % 50 == 49:
                client.flush(timeout=30.0)   # epoch-ish determinism
        client.flush(timeout=30.0)
        hr = client.hit_ratio()
        st = client.executor.stats
        client.close()
        assert executor_identity(st) == st.submitted, st.snapshot()
        return hr

    threaded, proc = run("threaded"), run("process")
    assert abs(threaded - proc) <= 0.02, (threaded, proc)


# ---------------------------------------------------------------------------
# failure semantics (worker-side store errors)
# ---------------------------------------------------------------------------

def test_transient_errors_retried_worker_side_no_leak():
    store = mk_flat_store()
    flaky = FaultyStore(store, fail_rate=0.3, seed=11,
                        sleep=lambda s: None)
    client = open_cache(flaky, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, max_fetch_bytes=512)
    _drive_client(client, store)
    assert client.flush(timeout=30.0)
    st = client.executor.stats
    engine = client.engine
    assert st.submitted > 0
    assert st.retries > 0, "30% transient rate produced no retries"
    pending = engine.pending_prefetch_count()
    client.close()
    assert executor_identity(st) == st.submitted, st.snapshot()
    assert pending == 0


def test_permanent_failures_cancel_candidates_no_leak():
    store = mk_flat_store()
    broken = FaultyStore(store, permanent_rate=1.0, seed=5,
                         sleep=lambda s: None)
    client = open_cache(broken, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, max_fetch_bytes=512)
    _drive_client(client, store)
    assert client.flush(timeout=30.0)
    st = client.executor.stats
    engine = client.engine
    assert st.submitted > 0
    assert st.fetch_errors > 0
    assert st.completed == 0, "every prefetch fetch should have failed"
    pending = engine.pending_prefetch_count()
    client.close()
    assert executor_identity(st) == st.submitted, st.snapshot()
    assert pending == 0


def test_demand_fetch_permanent_error_raises_in_reader():
    store = mk_store()
    broken = FaultyStore(store, permanent_rate=1.0, seed=5,
                         sleep=lambda s: None)
    client = open_cache(broken, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, fetch_bytes=True)
    f = store.datasets["ds0"].files[0]
    from repro.storage.api import StoreError
    with pytest.raises(StoreError):
        client.read(f.path, 0, f.size, 1.0)
    # the worker and channel survive: metadata reads still serve
    out = client.read(f.path, 0, f.size, 2.0, fetch=False)
    assert out.blocks
    client.close()


# ---------------------------------------------------------------------------
# shared-memory arena byte path
# ---------------------------------------------------------------------------

def test_demand_bytes_cross_via_arena_and_match():
    store = mk_store()
    client = open_cache(store, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, fetch_bytes=True)
    f = store.datasets["ds0"].files[0]
    res = client.read(f.path, 0, f.size, 1.0)
    ref = store.fetch_range(f.path, 0, f.size)
    assert np.array_equal(res.data, ref)
    res2 = client.read(f.path, 0, f.size, 2.0)     # all hits now
    assert all(b.hit for b in res2.blocks)
    assert np.array_equal(res2.data, ref)
    assert client.engine.arena_spills() == 0, \
        "payload bytes fell back to pickling"
    client.close()


def test_arena_slots_recycle_under_pressure():
    """Reading far more bytes than the arena holds must keep working
    with zero spills once released views are collected — the refcounted
    free path feeds slots back to the worker allocators."""
    store = mk_store()
    client = open_cache(store, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, fetch_bytes=True,
                        arena_bytes=2 * MB)   # << total bytes read
    files = all_files(store)
    total = 0
    for i, f in enumerate(files[:24]):
        res = client.read(f.path, 0, f.size, float(i))
        assert len(res.data) == f.size
        total += f.size
        del res
        if i % 4 == 3:
            gc.collect()        # release views → frees piggyback
    assert total > 4 * MB
    assert client.engine.arena_spills() == 0
    client.close()


# ---------------------------------------------------------------------------
# cross-shard allocation over serialized summaries
# ---------------------------------------------------------------------------

def _skewed_pair(store, cfg):
    s0 = IGTCache(store, 32 * MB, cfg=cfg)
    s1 = IGTCache(store, 32 * MB, cfg=cfg)
    cmu = s0.cache.create_cmu(("ds0",), 128 * MB, now=0.0)
    from repro.core import Pattern
    cmu.flat_pattern = Pattern.SKEWED
    for i in range(50):
        cmu.note_access(i * 0.01)
        cmu.buffer_window.on_evict(f"k{i}")
        cmu.buffer_window.probe(f"k{i}")
    return s0, s1


def test_plan_moves_matches_live_rebalancer():
    """The serialized planner is the same greedy rule the live
    cross-shard round applies (one skewed taker, one idle donor) —
    checked under both move-sizing policies: fixed ships exactly one
    quantum, adaptive sizes the move by the measured want."""
    import dataclasses
    store = mk_store()
    fixed_cfg = dataclasses.replace(CFG, quantum_policy="fixed")
    s0, s1 = _skewed_pair(store, fixed_cfg)
    reb = GlobalRebalancer(fixed_cfg)
    rows = [r for r, _ in reb.tracker.summarize(s0, 0, 1.0, mark=False)]
    rows += [r for r, _ in reb.tracker.summarize(s1, 1, 1.0, mark=False)]
    moves = reb.plan_moves(rows)
    assert moves, "skewed demand must pull capacity cross-shard"
    donor, taker, amt = moves[0]
    assert taker.key == ("ds0",) and taker.shard == 0
    assert donor.shard == 1
    assert amt == CFG.rebalance_quantum

    s0, s1 = _skewed_pair(store, CFG)        # adaptive (default policy)
    reb = GlobalRebalancer(CFG)
    rows = [r for r, _ in reb.tracker.summarize(s0, 0, 1.0, mark=False)]
    rows += [r for r, _ in reb.tracker.summarize(s1, 1, 1.0, mark=False)]
    moves = reb.plan_moves(rows)
    assert moves
    donor, taker, amt = moves[0]
    assert taker.key == ("ds0",) and taker.shard == 0
    assert donor.shard == 1
    # want-sized: 50 distinct ghost-hit blocks x block_size, capped by
    # the donor's headroom — strictly more than one fixed quantum
    assert amt > CFG.rebalance_quantum
    assert amt <= 50 * CFG.block_size


def test_process_driver_rebalance_conserves_capacity():
    store = mk_store(6)
    cap = 64 * MB
    with ProcessShardedCache(store, cap, cfg=CFG, n_procs=4,
                             prefetch="inline") as eng:
        assert sum(eng.shard_capacities()) == cap
        t = 0.0
        hot = store.datasets["ds0"].files[:3]
        for r in range(40):
            for f in hot:
                eng.read(f.path, 0, f.size, t)
                t += 0.05
            f = store.datasets["ds1"].files[r % 32]
            eng.read(f.path, 0, f.size, t)
            t += 0.05
        moved = 0
        for k in range(1, 20):
            moved += eng.rebalance_now(t + k * CFG.rebalance_period)
            caps = eng.shard_capacities()
            assert sum(caps) == cap, caps
        # per-shard quota invariant after the rounds
        for g in eng._gather_stats():
            assert g["capacity"] >= 0
        # the bounded wire summary really crossed the pipe: exact rows
        # plus the serialized demand sketches, O(KB) total
        summary = eng._rpc(0, "rebalance_summary", t + 999.0)
        assert isinstance(summary, ShardSummary)
        assert summary.rows
        assert all(isinstance(r, DemandSummary) for r in summary.rows)
        assert summary.payload_bytes() <= 64 * 1024
        # driver-side round stats got recorded (sketch merge path)
        assert eng.global_rebalancer.last_stats is not None
        assert eng.global_rebalancer.round_log


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_clean_shutdown_with_inflight_prefetches():
    store = mk_flat_store()
    slow = FaultyStore(store, jitter_s=0.002, seed=3)
    client = open_cache(slow, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, max_fetch_bytes=512)
    _drive_client(client, store)
    st = client.executor.stats
    procs = [ch.proc for ch in client.engine._channels]
    client.close()                 # no flush: candidates still in flight
    assert executor_identity(st) == st.submitted, st.snapshot()
    for p in procs:
        assert not p.is_alive(), "worker process leaked past close()"


def test_close_is_idempotent_and_context_manager():
    store = mk_store()
    eng = ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=2)
    eng.close()
    eng.close()
    with ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=1) as eng2:
        f = store.datasets["ds0"].files[0]
        assert eng2.read(f.path, 0, f.size, 0.0).blocks
    with pytest.raises(RuntimeError):
        eng2.read(f.path, 0, f.size, 1.0)   # closed driver fails loudly


def test_worker_reports_renegotiated_capabilities():
    store = mk_store()
    with ProcessShardedCache(store, 64 * MB, cfg=CFG, n_procs=2) as eng:
        assert len(eng.worker_info) == 2
        for info in eng.worker_info:
            assert info["capabilities"]["ranges"] is True
            assert info["pid"] > 0
        pids = {info["pid"] for info in eng.worker_info}
        assert len(pids) == 2, "shards must live in distinct processes"


def test_invalidate_meta_cache_reaches_worker_snapshots(tmp_path):
    """LocalFSStore mid-run refresh workflow under driver='process':
    the facade's invalidate_meta_cache must re-walk every worker's own
    store snapshot (a client-side refresh() can't reach them)."""
    root = tmp_path / "data"
    (root / "ds").mkdir(parents=True)
    (root / "ds" / "a.bin").write_bytes(b"x" * 4096)
    cfg = CacheConfig(min_share=1 * MB, rebalance_quantum=1 * MB,
                      block_size=64 * 1024)
    client = open_cache(f"file://{root}", 8 * MB, cfg=cfg,
                        driver="process", n_procs=2, fetch_bytes=True)
    got = client.read(("ds", "a.bin"), 0, 4096, 1.0)
    assert bytes(got.data) == b"x" * 4096
    (root / "ds" / "b.bin").write_bytes(b"y" * 2048)   # tree changed
    client.engine.invalidate_meta_cache()
    got = client.read(("ds", "b.bin"), 0, 2048, 2.0)
    assert bytes(got.data) == b"y" * 2048
    client.close()


def test_spawn_start_method_pickles_store():
    """`fork` is the Linux default (populated stores travel free), but
    the driver must also run under `spawn`/`forkserver` — the escape
    hatch when the embedding process is heavily threaded (fork-safety).
    Everything then crosses by pickle, including the fault wrapper."""
    store = mk_store(2)
    flaky = FaultyStore(store, fail_rate=0.0, seed=1)
    with ProcessShardedCache(flaky, 64 * MB, cfg=CFG, n_procs=1,
                             prefetch="inline",
                             start_method="spawn") as eng:
        f = store.datasets["ds0"].files[0]
        out = eng.read(f.path, 0, f.size, 0.0)
        assert out.blocks and not out.blocks[0].hit
        out2 = eng.read(f.path, 0, f.size, 1.0)
        assert all(b.hit for b in out2.blocks)


def test_store_spec_roundtrip():
    # object spec: a populated RemoteStore must travel as itself
    store = mk_store()
    kind, payload = store_spec(store)
    assert kind == "object" and payload is store
    # URI spec: strings re-open per process
    assert store_spec("sim://default") == ("uri", "sim://default")


def test_open_cache_driver_knobs_validated():
    store = mk_store()
    with pytest.raises(ValueError):
        open_cache(store, 64 * MB, cfg=CFG, driver="warp")
    with pytest.raises(ValueError):
        open_cache(store, 64 * MB, cfg=CFG, n_procs=2)  # thread driver
    with pytest.raises(TypeError):
        # ProcessExecutor needs the process driver
        open_cache(store, 64 * MB, cfg=CFG, executor="process")


def test_bad_executor_string_does_not_leak_workers():
    """Knob validation must run before workers spawn: a typo'd executor
    on driver='process' raises without leaving igt-shard processes (or
    an arena) behind."""
    import multiprocessing
    store = mk_store()
    before = {p.pid for p in multiprocessing.active_children()}
    with pytest.raises(ValueError):
        open_cache(store, 64 * MB, cfg=CFG, driver="process", n_procs=2,
                   executor="warp-drive")
    leaked = [p for p in multiprocessing.active_children()
              if p.pid not in before and p.name.startswith("igt-shard")]
    assert not leaked, f"leaked workers: {leaked}"


# ---------------------------------------------------------------------------
# fault-supervision regressions (satellites of the robustness tentpole)
# ---------------------------------------------------------------------------

def test_worker_death_during_executor_close_keeps_identity():
    """Regression for the _fail_channel stats race: a worker dying while
    ProcessExecutor.close() is draining must not double-count or drop
    the dead channel's queued candidates — both paths drain atomically
    through the channel and account under the registration lock, so the
    identity holds no matter who wins."""
    for seed in (1, 2):
        store = mk_flat_store()
        slow = FaultyStore(store, jitter_s=0.002, seed=seed)
        client = open_cache(slow, 64 * MB, cfg=CFG, driver="process",
                            n_procs=2, max_fetch_bytes=512)
        _drive_client(client, store)
        st = client.executor.stats
        assert st.submitted > 0
        # SIGKILL one worker and close immediately: the receiver thread's
        # death accounting races the executor's close-time drain
        client.engine._channels[seed % 2].proc.kill()
        client.close()
        assert executor_identity(st) == st.submitted, st.snapshot()


def test_driver_flush_returns_promptly_after_worker_death():
    """flush() on a driver whose worker died with queued background work
    must return promptly — the dead channel's queue is drained by the
    death accounting (supervision off: nothing refills it), so the call
    must not sleep out its full timeout waiting for progress that can
    never happen."""
    import time as _time
    store = mk_flat_store()
    slow = FaultyStore(store, jitter_s=0.002, seed=4)
    client = open_cache(slow, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, max_fetch_bytes=512, supervise=False)
    _drive_client(client, store)
    for ch in client.engine._channels:
        ch.proc.kill()
    t0 = _time.monotonic()
    client.engine.flush(timeout=30.0)
    elapsed = _time.monotonic() - t0
    assert elapsed < 10.0, (
        f"flush slept {elapsed:.1f}s against a dead channel")
    client.close()


def test_shard_channel_wait_idle_reports_closed_promptly():
    """A closed channel with outstanding work can only drain through the
    death sweep — wait_idle must report False immediately, not burn the
    caller's timeout."""
    import time as _time
    from repro.core.procdriver import _ShardChannel
    ch = _ShardChannel(0, None, None)
    ch.outstanding = 3
    ch.closed = True
    t0 = _time.monotonic()
    assert ch.wait_idle(30.0) is False
    assert _time.monotonic() - t0 < 1.0


def test_client_shard_queue_wait_idle_reports_closed_promptly():
    """Same contract for the ThreadedExecutor's per-shard queue."""
    import time as _time
    from repro.core.client import _ShardQueue
    q = _ShardQueue(depth=8)
    q.outstanding = 2
    q.closed = True
    t0 = _time.monotonic()
    assert q.wait_idle(30.0) is False
    assert _time.monotonic() - t0 < 1.0


def test_backing_override_reaches_workers():
    """An explicit `backing` store must be what the *workers* fetch
    demand bytes from — a permanently failing backing proves they do
    not silently fall back to the metadata store."""
    store = mk_store()
    broken = FaultyStore(store, permanent_rate=1.0, seed=1,
                         sleep=lambda s: None)
    client = open_cache(store, 64 * MB, cfg=CFG, driver="process",
                        n_procs=2, backing=broken, fetch_bytes=True)
    from repro.storage.api import StoreError
    f = store.datasets["ds0"].files[0]
    with pytest.raises(StoreError):
        client.read(f.path, 0, f.size, 1.0)
    client.close()

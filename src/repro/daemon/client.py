"""``RemoteCacheClient``: the thin client for a ``CacheDaemon``.

Satisfies the ``CacheClient`` read surface — ``read`` / ``read_batch``
returning ``ReadResult`` objects (outcomes are ``core.wire.WireOutcome``
views decoded lazily from the shared compact codec), plus the stats
family (``stats`` / ``snapshot`` / ``hit_ratio`` / ``fault_stats``) and
the kernel passthroughs (``tick`` / ``pin`` / ``never_cache`` /
``flush``) — but holds no kernel, no store, and no executor: every call
is one framed request to the daemon.  ``open_cache("cache://...")``
constructs one.

Payload bytes: when the daemon granted shared-memory payloads (hello
reply carries the arena name — same-node, UDS), ``("shm", off, n)``
descriptors are copied out of the mapped arena and the slot is queued
for release, piggybacked on the next request (no free ever needs its
own round trip).  ``("raw", bytes)`` descriptors (TCP, arena spills)
are wrapped zero-copy.

Surviving the daemon (PR 10, docs/RELIABILITY.md "Fault of the
daemon"): the connection is a state machine — ``up`` / ``down`` /
``closed``.  Any wire failure (EOF from a crash, an RPC timeout, the
drain path's out-of-band ``going_down`` frame) marks the connection
``down``, *wakes any blocked caller* (the socket carries a
``rpc_timeout_s`` deadline, so no call ever hangs on a dead daemon),
and hands the management thread to a bounded-exponential-backoff
reconnector.  Reconnection is a fresh session: stale arena frees are
dropped (the old daemon's lease reclaim owns those slots), the shm
arena is remapped from the new hello, and the locally tracked sticky
``pin`` / ``never_cache`` prefixes are replayed — belt-and-braces over
the daemon's own journal replay, and the only path for daemons running
without one.

While ``down``, ``degraded=True`` (the default, requires a ``backing=``
store for byte reads) serves reads straight from the backing store —
all-miss outcomes from store geometry, bytes via ``fetch_many``,
counted in ``client_stats`` exactly like the PR 6 shard-level degraded
path.  ``degraded=False`` raises the typed
:class:`~repro.core.faults.DaemonUnavailableError` instead.  Operations
that *need* the daemon (stats, snapshots) always raise it while down;
``flush`` short-circuits to ``False``; ``tick`` becomes a no-op (the
kernel it would advance is gone — the restarted daemon re-learns).

Liveness: one background management thread renews the session lease at
a third of the daemon's ``lease_s`` (skipping the renewal when a caller
holds the wire — their frame renews the lease anyway) and runs the
reconnector while down.  A failed heartbeat marks the connection dead
and closes the socket so blocked callers wake with the typed error —
it never silently exits with callers still parked.  ``close()`` says
goodbye and releases the session immediately; ``kill()`` exists for
fault drills — it silences the client (and optionally drops the
socket) exactly like a crashed process would, so tests and the chaos
harness can watch the daemon's lease reclaim run.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cache import path_key
from ..core.client import ClientStats, ReadResult
from ..core.faults import DaemonUnavailableError
from ..core.igtcache import BlockResult, ReadOutcome
from ..core.types import PathT, block_key
from ..core.wire import WireOutcome
from ..storage.api import as_backing_store
from .uri import DaemonAddress, parse_cache_uri
from .wire import PROTO_VERSION, recv_msg, send_msg

__all__ = ["RemoteCacheClient"]


class _RemoteMeta:
    """``StoreMeta`` over the wire: the daemon answers from its store,
    so remote callers can size reads (``client.meta.file_size(path)``)
    without a local copy of the dataset layout.  Answers are memoized
    client-side so degraded reads keep exact file geometry while the
    daemon is away; a ``backing=`` store fills unmemoized holes."""

    __slots__ = ("_client",)

    def __init__(self, client: "RemoteCacheClient") -> None:
        self._client = client

    def file_size(self, path: PathT) -> int:
        c = self._client
        try:
            size = int(c._request("file_size", path))
        except DaemonUnavailableError:
            if not c.degraded:
                raise
            return c._file_size_fallback(path)
        c._fsize_memo[path_key(path)] = size
        return size

    def subtree_bytes(self, path: PathT) -> int:
        c = self._client
        try:
            return c._request("subtree_bytes", path)
        except DaemonUnavailableError:
            if not c.degraded:
                raise
            fn = getattr(c._backing, "subtree_bytes", None)
            if callable(fn):
                return fn(path)
            raise


class RemoteCacheClient:
    """One session against a :class:`~repro.daemon.CacheDaemon`.

    ``target`` is a ``cache://`` URI or a :class:`DaemonAddress`.
    ``fetch_bytes`` mirrors ``CacheClient``: the default for per-call
    ``fetch``.  ``now`` semantics also mirror the local client, with one
    twist: omitted timestamps are stamped *by the daemon* — every
    client of one daemon then shares a single coherent kernel timeline
    instead of mixing per-process monotonic epochs.  Virtual-clock
    callers pass ``now`` explicitly, which travels verbatim.

    Resilience knobs (URI query params or kwargs): ``reconnect``
    re-establishes a dead session with bounded exponential backoff
    (capped at ``max_backoff_s``); ``degraded`` serves reads from the
    ``backing=`` store while the daemon is down instead of raising
    :class:`DaemonUnavailableError`; ``rpc_timeout_s`` bounds every
    wire wait so a dead-but-connected daemon can never hang a caller
    (``None`` restores the old block-forever behavior).
    """

    # ClusterSim and other harnesses dispatch on this instead of
    # importing the class (daemon package stays optional at sim time)
    is_remote_cache_client = True

    def __init__(self, target, *,
                 fetch_bytes: bool = False,
                 label: Optional[str] = None,
                 heartbeat: bool = True,
                 shm: bool = True,
                 connect_timeout: float = 10.0,
                 reconnect: bool = True,
                 degraded: bool = True,
                 max_backoff_s: float = 2.0,
                 rpc_timeout_s: Optional[float] = 30.0,
                 backing=None) -> None:
        address = (target if isinstance(target, DaemonAddress)
                   else parse_cache_uri(str(target)))
        self.address = address
        self.fetch_bytes = fetch_bytes
        self.degraded = bool(degraded)
        self.reconnect = bool(reconnect)
        self.max_backoff_s = float(max_backoff_s)
        self.rpc_timeout_s = (None if rpc_timeout_s is None
                              else float(rpc_timeout_s))
        self.connect_timeout = float(connect_timeout)
        self._label = label
        self._want_shm = bool(shm)
        self._backing = as_backing_store(backing)
        self._lock = threading.RLock()
        self._pending_frees: List[Tuple[int, int]] = []
        self._closed = False
        self._killed = False
        self._zombie = None          # kill(): keeps the socket fd open
        self.state = "down"
        self.reconnects = 0
        self.disconnects = 0
        self.client_stats = ClientStats()
        self._cstats_lock = threading.Lock()
        # sticky controls, replayed into a fresh session on reconnect
        self._pins: Dict[tuple, None] = {}
        self._bans: Dict[tuple, None] = {}
        self._fsize_memo: Dict[tuple, int] = {}
        self._sock = None
        self._shm = None
        self._connect_session()          # raises if the first dial fails
        self.meta = _RemoteMeta(self)
        self._stop = threading.Event()
        self._hb_enabled = bool(heartbeat)
        self._mgmt_thread = None
        if self._hb_enabled or self.reconnect:
            self._mgmt_thread = threading.Thread(
                target=self._mgmt_loop, daemon=True,
                name=f"igt-daemon-client-{self.session_id}")
            self._mgmt_thread.start()

    # --------------------------------------------------------------- wire
    def _connect_session(self) -> None:
        """Dial + handshake one fresh session (first connect and every
        reconnect).  Caller holds ``self._lock`` on the reconnect path.
        On success the socket carries the RPC deadline, the shm arena is
        (re)mapped from the hello, and stale frees are dropped."""
        import socket as _socket
        kind, addr = self.address.connect_args()
        fam = _socket.AF_UNIX if kind == "uds" else _socket.AF_INET
        sock = _socket.socket(fam, _socket.SOCK_STREAM)
        sock.settimeout(self.connect_timeout)
        try:
            sock.connect(addr)
            sock.settimeout(self.rpc_timeout_s)
            send_msg(sock, ("hello", (), {
                "proto": PROTO_VERSION,
                "pid": os.getpid(),
                "label": self._label,
                "shm": self._want_shm,
            }))
            status, info = recv_msg(sock)
        except BaseException:
            sock.close()
            raise
        if status != "ok":
            sock.close()
            if isinstance(info, BaseException):
                raise info
            raise ConnectionError(f"daemon refused session: {info!r}")
        self._sock = sock
        self.session_id = info["session"]
        self.lease_s = info["lease_s"]
        self.block_size = info["block_size"]
        self._release_shm()
        if info.get("shm"):
            from multiprocessing import shared_memory
            self._shm = shared_memory.SharedMemory(name=info["shm"])
        # frees queued for the *old* session are stale: that daemon's
        # lease reclaim (or its death) already returned the slots
        self._pending_frees = []
        self.state = "up"

    def _mark_down(self, reason: str) -> None:
        """Declare the connection dead: close the socket (waking any
        caller blocked in ``recv``), drop stale frees, release the shm
        mapping, and hand the connection to the reconnector."""
        with self._lock:
            if self._closed or self._killed or self.state != "up":
                return
            self.state = "down"
            self.disconnects += 1
            self._pending_frees = []
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:  # pragma: no cover
                    pass
            self._release_shm()

    def _request(self, op: str, payload=None, *,
                 timeout: Optional[float] = None):
        with self._lock:
            if self._killed:
                raise ConnectionError("remote cache client is killed")
            if self._closed:
                raise DaemonUnavailableError(
                    "remote cache client is closed", state="closed")
            if self.state != "up":
                raise DaemonUnavailableError(
                    f"cache daemon at {self.address.display} is "
                    f"unavailable (op={op!r})", state=self.state)
            frees, self._pending_frees = self._pending_frees, []
            try:
                if timeout is not None:
                    self._sock.settimeout(timeout)
                send_msg(self._sock, (op, frees, payload))
                status, result = recv_msg(self._sock)
            except (ConnectionError, OSError, EOFError) as e:
                # covers socket.timeout (OSError): the deadline is the
                # no-hung-callers guarantee, treated as a dead daemon
                self._mark_down(f"{op}: {e!r}")
                raise DaemonUnavailableError(
                    f"cache daemon at {self.address.display} died "
                    f"mid-{op}: {e!r}", state="down") from e
            finally:
                if timeout is not None and self.state == "up":
                    try:
                        self._sock.settimeout(self.rpc_timeout_s)
                    except OSError:  # pragma: no cover
                        pass
            if status == "going_down":
                # drain notice (SIGTERM path): the daemon flushed and
                # snapshotted; reconnect when its successor binds
                self._mark_down("daemon draining")
                raise DaemonUnavailableError(
                    f"cache daemon at {self.address.display} is "
                    f"draining", state="down")
        if status == "err":
            raise result
        return result

    # ------------------------------------------------- management thread
    def _mgmt_loop(self) -> None:
        """One thread, two duties: lease renewal while ``up``,
        backoff-paced redial while ``down``."""
        hb_wait = max(0.05, float(self.lease_s) / 3.0)
        backoff = 0.05
        while not self._stop.is_set():
            if self._closed or self._killed:
                return
            if self.state == "up":
                backoff = 0.05
                if self._stop.wait(hb_wait if self._hb_enabled else 0.1):
                    return
                if self._hb_enabled and self.state == "up":
                    self._try_heartbeat()
            else:
                if not self.reconnect:
                    return              # stays down until close()
                if self._stop.wait(backoff):
                    return
                backoff = min(backoff * 2.0, self.max_backoff_s)
                self._try_reconnect()

    def _try_heartbeat(self) -> None:
        """Lease renewal that never queues behind a blocked caller: if
        someone holds the wire their own frame renews the lease; if the
        wire is free and the heartbeat fails, ``_request`` marks the
        connection down (closing the socket) — the old behavior of
        silently exiting left callers parked on a dead daemon."""
        if not self._lock.acquire(blocking=False):
            return
        try:
            if self.state == "up" and not self._closed and not self._killed:
                try:
                    self._request("heartbeat")
                except (DaemonUnavailableError, ConnectionError):
                    pass                # _request already marked us down
        finally:
            self._lock.release()

    def _try_reconnect(self) -> None:
        with self._lock:
            if self._closed or self._killed or self.state != "down":
                return
            try:
                self._connect_session()
            except (ConnectionError, OSError, EOFError):
                return                  # daemon still away: next backoff
            self.reconnects += 1
            # replay sticky controls into the fresh session — idempotent
            # server-side, and the only path for journal-less daemons
            for p in list(self._pins):
                try:
                    self._request("pin", p)
                except (DaemonUnavailableError, ConnectionError):
                    return              # died again mid-replay
            for p in list(self._bans):
                try:
                    self._request("never_cache", p)
                except (DaemonUnavailableError, ConnectionError):
                    return

    # --------------------------------------------------------------- reads
    def read(self, file_path: PathT, offset: int, size: int,
             now: Optional[float] = None, *,
             fetch: Optional[bool] = None) -> ReadResult:
        want = self.fetch_bytes if fetch is None else fetch
        try:
            enc, payload = self._request(
                "read", (file_path, offset, size, now, want))
        except DaemonUnavailableError:
            if not self.degraded or self._closed:
                raise
            return self._degraded_read(file_path, offset, size, want)
        return ReadResult(WireOutcome(enc, file_path),
                          self._materialize(payload))

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: Optional[float] = None, *,
                   fetch: Optional[bool] = None) -> List[ReadResult]:
        want = self.fetch_bytes if fetch is None else fetch
        requests = list(requests)
        try:
            encs, payloads = self._request("read_batch",
                                           (requests, now, want))
        except DaemonUnavailableError:
            if not self.degraded or self._closed:
                raise
            return [self._degraded_read(fp, off, sz, want)
                    for fp, off, sz in requests]
        return [ReadResult(WireOutcome(enc, fp), self._materialize(pl))
                for (fp, _o, _s), enc, pl in zip(requests, encs, payloads)]

    # ------------------------------------------------------- degraded path
    def _file_size_fallback(self, path: PathT) -> int:
        key = path_key(path)
        size = self._fsize_memo.get(key)
        if size is not None:
            return size
        fn = getattr(self._backing, "file_size", None)
        if callable(fn):
            size = int(fn(path))
            self._fsize_memo[key] = size
            return size
        raise DaemonUnavailableError(
            f"no file geometry for {path!r} while the daemon is down "
            f"(unmemoized, and the backing store serves no metadata)",
            state=self.state)

    def _degraded_read(self, file_path: PathT, offset: int, size: int,
                       want: bool) -> ReadResult:
        """Serve one request without the daemon: all-miss outcome from
        store geometry (mirroring ``CacheClient._degraded_outcome``),
        bytes straight from the ``backing=`` store.  No cache
        observation happens — the restarted daemon's kernel re-learns
        this stream from its journal, not from reads it never saw."""
        bs = self.block_size
        try:
            fsize = self._file_size_fallback(file_path)
        except Exception:
            fsize = offset + size    # unknown geometry: trust the request
        end = min(offset + size, fsize)
        blocks: List[BlockResult] = []
        reqs = []
        if end > offset:
            first = offset // bs
            for b in range(first, (end - 1) // bs + 1):
                blocks.append(BlockResult(
                    path_key(block_key(file_path, b)),
                    min(bs, fsize - b * bs), False))
                start = max(offset, b * bs) - b * bs
                stop = min(end, b * bs + blocks[-1].size) - b * bs
                if stop > start:
                    reqs.append((block_key(file_path, b), start,
                                 stop - start))
        out = ReadOutcome(blocks, [])
        with self._cstats_lock:
            self.client_stats.degraded_reads += 1
        if not want or not reqs:
            return ReadResult(out)
        if self._backing is None:
            raise DaemonUnavailableError(
                "degraded byte read needs a backing= store "
                "(daemon is down and holds the only byte path)",
                state=self.state)
        data = self._backing.fetch_many(reqs)
        with self._cstats_lock:
            self.client_stats.degraded_bytes += sum(r[2] for r in reqs)
        return ReadResult(out, np.concatenate(
            [np.asarray(d, dtype=np.uint8) for d in data])
            if data else None)

    def _materialize(self, payload) -> Optional[np.ndarray]:
        if payload is None:
            return None
        kind = payload[0]
        if kind == "raw":
            return np.frombuffer(payload[1], dtype=np.uint8)
        _, off, n = payload
        view = np.frombuffer(self._shm.buf, dtype=np.uint8, count=n,
                             offset=off)
        data = view.copy()
        del view
        with self._lock:
            self._pending_frees.append((off, n))
        return data

    # -------------------------------------------------------- passthrough
    @property
    def stats(self):
        return self._request("stats")

    def hit_ratio(self) -> float:
        return self._request("hit_ratio")

    def snapshot(self) -> dict:
        return self._request("snapshot")

    def fault_stats(self) -> dict:
        return self._request("fault_stats")

    def shard_states(self):
        return self._request("shard_states")

    def daemon_stats(self) -> dict:
        return self._request("daemon_stats")

    def tick(self, now: Optional[float] = None) -> None:
        try:
            self._request("tick", now)
        except DaemonUnavailableError:
            if not self.degraded:
                raise               # the kernel this would advance is gone

    def pin(self, path: PathT) -> None:
        self._pins[tuple(path)] = None      # replayed on reconnect
        try:
            self._request("pin", path)
        except DaemonUnavailableError:
            if not self.degraded:
                raise

    def never_cache(self, path: PathT) -> None:
        self._bans[tuple(path)] = None
        try:
            self._request("never_cache", path)
        except DaemonUnavailableError:
            if not self.degraded:
                raise

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Drain the daemon's executor.  Against a dead daemon this
        short-circuits to ``False`` promptly — there is nothing left to
        drain, and blocking a shutdown path on a corpse helps no one.
        The wire deadline stretches past ``timeout`` so a *live* flush
        is never killed by the generic RPC deadline."""
        wire_to = None
        if timeout is not None and self.rpc_timeout_s is not None:
            wire_to = max(float(timeout) + 5.0, self.rpc_timeout_s)
        try:
            return self._request("flush", timeout, timeout=wire_to)
        except DaemonUnavailableError:
            return False

    def connection_stats(self) -> dict:
        """Client-side view of the connection state machine."""
        with self._lock:
            return {
                "state": "closed" if self._closed else self.state,
                "reconnects": self.reconnects,
                "disconnects": self.disconnects,
                "degraded": self.degraded,
                "client_stats": self.client_stats.snapshot(),
                "pins_tracked": len(self._pins),
                "never_cache_tracked": len(self._bans),
            }

    def heartbeat(self) -> dict:
        """Explicit lease renewal (the background thread's op)."""
        return self._request("heartbeat")

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Graceful goodbye: the daemon releases the session (and every
        arena slot it still tracks) immediately — no lease wait.
        Against a dead daemon the goodbye is skipped (nothing to tell)
        and close returns promptly instead of dialing a corpse."""
        if self._closed or self._killed:
            return
        self._stop.set()
        if self.state == "up":
            try:
                self._request("bye", timeout=2.0)
            except (DaemonUnavailableError, ConnectionError, OSError,
                    EOFError):
                pass
        with self._lock:
            self._closed = True
            self.state = "closed"
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:  # pragma: no cover
                pass
        self._release_shm()
        if (self._mgmt_thread is not None
                and self._mgmt_thread is not threading.current_thread()):
            self._mgmt_thread.join(timeout=2.0)

    def kill(self, *, drop_connection: bool = False) -> None:
        """Die like a crashed client (fault drills / chaos harness).

        Default: go *silent* — heartbeats stop, the socket stays open
        but unused (the wedged-process case; only the daemon's lease
        can notice).  ``drop_connection=True`` closes the socket without
        a goodbye instead (the killed-process case; the daemon sees EOF
        and reclaims at once).  A killed client never reconnects —
        that is the point of the drill."""
        if self._closed:
            return
        self._stop.set()
        self._killed = True
        if drop_connection:
            try:
                self._sock.close()
            except OSError:  # pragma: no cover
                pass
        else:
            self._zombie = self._sock      # hold the fd: no EOF, no FIN
        self._release_shm()

    def _release_shm(self) -> None:
        if self._shm is not None:
            try:
                self._shm.close()
            except BufferError:  # pragma: no cover - live views
                pass
            self._shm = None

    def __enter__(self) -> "RemoteCacheClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""``RemoteCacheClient``: the thin client for a ``CacheDaemon``.

Satisfies the ``CacheClient`` read surface — ``read`` / ``read_batch``
returning ``ReadResult`` objects (outcomes are ``core.wire.WireOutcome``
views decoded lazily from the shared compact codec), plus the stats
family (``stats`` / ``snapshot`` / ``hit_ratio`` / ``fault_stats``) and
the kernel passthroughs (``tick`` / ``pin`` / ``never_cache`` /
``flush``) — but holds no kernel, no store, and no executor: every call
is one framed request to the daemon.  ``open_cache("cache://...")``
constructs one.

Payload bytes: when the daemon granted shared-memory payloads (hello
reply carries the arena name — same-node, UDS), ``("shm", off, n)``
descriptors are copied out of the mapped arena and the slot is queued
for release, piggybacked on the next request (no free ever needs its
own round trip).  ``("raw", bytes)`` descriptors (TCP, arena spills)
are wrapped zero-copy.

Liveness: a background heartbeat thread renews the session lease at a
third of the daemon's ``lease_s`` so an *idle* client isn't reaped.
``close()`` says goodbye and releases the session immediately;
``kill()`` exists for fault drills — it silences the client (and
optionally drops the socket) exactly like a crashed process would, so
tests and the chaos harness can watch the daemon's lease reclaim run.
"""
from __future__ import annotations

import os
import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.client import ReadResult
from ..core.types import PathT
from ..core.wire import WireOutcome
from .uri import DaemonAddress, parse_cache_uri
from .wire import PROTO_VERSION, recv_msg, send_msg

__all__ = ["RemoteCacheClient"]


class _RemoteMeta:
    """``StoreMeta`` over the wire: the daemon answers from its store,
    so remote callers can size reads (``client.meta.file_size(path)``)
    without a local copy of the dataset layout."""

    __slots__ = ("_client",)

    def __init__(self, client: "RemoteCacheClient") -> None:
        self._client = client

    def file_size(self, path: PathT) -> int:
        return self._client._request("file_size", path)

    def subtree_bytes(self, path: PathT) -> int:
        return self._client._request("subtree_bytes", path)


class RemoteCacheClient:
    """One session against a :class:`~repro.daemon.CacheDaemon`.

    ``target`` is a ``cache://`` URI or a :class:`DaemonAddress`.
    ``fetch_bytes`` mirrors ``CacheClient``: the default for per-call
    ``fetch``.  ``now`` semantics also mirror the local client, with one
    twist: omitted timestamps are stamped *by the daemon* — every
    client of one daemon then shares a single coherent kernel timeline
    instead of mixing per-process monotonic epochs.  Virtual-clock
    callers pass ``now`` explicitly, which travels verbatim.
    """

    def __init__(self, target, *,
                 fetch_bytes: bool = False,
                 label: Optional[str] = None,
                 heartbeat: bool = True,
                 shm: bool = True,
                 connect_timeout: float = 10.0) -> None:
        address = (target if isinstance(target, DaemonAddress)
                   else parse_cache_uri(str(target)))
        self.address = address
        self.fetch_bytes = fetch_bytes
        self._lock = threading.RLock()
        self._pending_frees: List[Tuple[int, int]] = []
        self._closed = False
        self._killed = False
        self._zombie = None          # kill(): keeps the socket fd open
        import socket as _socket
        kind, addr = address.connect_args()
        fam = _socket.AF_UNIX if kind == "uds" else _socket.AF_INET
        self._sock = _socket.socket(fam, _socket.SOCK_STREAM)
        self._sock.settimeout(connect_timeout)
        self._sock.connect(addr)
        self._sock.settimeout(None)
        send_msg(self._sock, ("hello", (), {
            "proto": PROTO_VERSION,
            "pid": os.getpid(),
            "label": label,
            "shm": bool(shm),
        }))
        status, info = recv_msg(self._sock)
        if status != "ok":
            self._sock.close()
            raise info
        self.session_id = info["session"]
        self.lease_s = info["lease_s"]
        self.block_size = info["block_size"]
        self._shm = None
        if info.get("shm"):
            from multiprocessing import shared_memory
            self._shm = shared_memory.SharedMemory(name=info["shm"])
        self.meta = _RemoteMeta(self)
        self._hb_stop = threading.Event()
        self._hb_thread = None
        if heartbeat:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, daemon=True,
                name=f"igt-daemon-hb-{self.session_id}")
            self._hb_thread.start()

    # --------------------------------------------------------------- wire
    def _request(self, op: str, payload=None):
        with self._lock:
            if self._closed or self._killed:
                raise ConnectionError("remote cache client is closed")
            frees, self._pending_frees = self._pending_frees, []
            try:
                send_msg(self._sock, (op, frees, payload))
                status, result = recv_msg(self._sock)
            except (ConnectionError, OSError):
                # slots we meant to free never reached the daemon; its
                # lease reclaim will return them
                self._closed = True
                raise
        if status == "err":
            raise result
        return result

    def _heartbeat_loop(self) -> None:
        interval = max(0.05, self.lease_s / 3.0)
        while not self._hb_stop.wait(interval):
            try:
                self._request("heartbeat")
            except BaseException:
                return

    # --------------------------------------------------------------- reads
    def read(self, file_path: PathT, offset: int, size: int,
             now: Optional[float] = None, *,
             fetch: Optional[bool] = None) -> ReadResult:
        want = self.fetch_bytes if fetch is None else fetch
        enc, payload = self._request("read",
                                     (file_path, offset, size, now, want))
        return ReadResult(WireOutcome(enc, file_path),
                          self._materialize(payload))

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: Optional[float] = None, *,
                   fetch: Optional[bool] = None) -> List[ReadResult]:
        want = self.fetch_bytes if fetch is None else fetch
        requests = list(requests)
        encs, payloads = self._request("read_batch", (requests, now, want))
        return [ReadResult(WireOutcome(enc, fp), self._materialize(pl))
                for (fp, _o, _s), enc, pl in zip(requests, encs, payloads)]

    def _materialize(self, payload) -> Optional[np.ndarray]:
        if payload is None:
            return None
        kind = payload[0]
        if kind == "raw":
            return np.frombuffer(payload[1], dtype=np.uint8)
        _, off, n = payload
        view = np.frombuffer(self._shm.buf, dtype=np.uint8, count=n,
                             offset=off)
        data = view.copy()
        del view
        with self._lock:
            self._pending_frees.append((off, n))
        return data

    # -------------------------------------------------------- passthrough
    @property
    def stats(self):
        return self._request("stats")

    def hit_ratio(self) -> float:
        return self._request("hit_ratio")

    def snapshot(self) -> dict:
        return self._request("snapshot")

    def fault_stats(self) -> dict:
        return self._request("fault_stats")

    def shard_states(self):
        return self._request("shard_states")

    def daemon_stats(self) -> dict:
        return self._request("daemon_stats")

    def tick(self, now: Optional[float] = None) -> None:
        self._request("tick", now)

    def pin(self, path: PathT) -> None:
        self._request("pin", path)

    def never_cache(self, path: PathT) -> None:
        self._request("never_cache", path)

    def flush(self, timeout: Optional[float] = None) -> bool:
        return self._request("flush", timeout)

    def heartbeat(self) -> dict:
        """Explicit lease renewal (the background thread's op)."""
        return self._request("heartbeat")

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Graceful goodbye: the daemon releases the session (and every
        arena slot it still tracks) immediately — no lease wait."""
        if self._closed or self._killed:
            return
        self._hb_stop.set()
        try:
            self._request("bye")
        except (ConnectionError, OSError, EOFError):
            pass
        with self._lock:
            self._closed = True
        try:
            self._sock.close()
        except OSError:  # pragma: no cover
            pass
        self._release_shm()

    def kill(self, *, drop_connection: bool = False) -> None:
        """Die like a crashed client (fault drills / chaos harness).

        Default: go *silent* — heartbeats stop, the socket stays open
        but unused (the wedged-process case; only the daemon's lease
        can notice).  ``drop_connection=True`` closes the socket without
        a goodbye instead (the killed-process case; the daemon sees EOF
        and reclaims at once)."""
        if self._closed:
            return
        self._hb_stop.set()
        self._killed = True
        if drop_connection:
            try:
                self._sock.close()
            except OSError:  # pragma: no cover
                pass
        else:
            self._zombie = self._sock      # hold the fd: no EOF, no FIN
        self._release_shm()

    def _release_shm(self) -> None:
        if self._shm is not None:
            try:
                self._shm.close()
            except BufferError:  # pragma: no cover - live views
                pass
            self._shm = None

    def __enter__(self) -> "RemoteCacheClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""``cache://`` addressing: the daemon's entry in the storage scheme
registry.

A cache URI names a *daemon endpoint*, not a byte store:

* ``cache:///run/igt.sock``      — Unix-domain socket (the default
  deployment: same-node clients, payload bytes over shared memory);
* ``cache://host:port``          — TCP (remote clients, payload bytes
  streamed inline over the socket);
* query params (``?fetch_bytes=true&heartbeat_s=2``) are coerced like
  every other scheme's and forwarded to the client constructor.

``storage.api.open_store("cache://...")`` therefore resolves to a
:class:`DaemonAddress` — a picklable, re-openable handle — and
``core.client.open_cache("cache://...")`` short-circuits to a
``repro.daemon.RemoteCacheClient`` connected to that endpoint.  This
module stays dependency-light (no sockets, no numpy) so the registry
can import it without dragging the server in.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional
from urllib.parse import urlsplit

__all__ = ["DaemonAddress", "format_cache_uri", "parse_cache_uri"]

SCHEME = "cache"


@dataclass
class DaemonAddress:
    """Where a :class:`~repro.daemon.CacheDaemon` listens.

    ``kind`` is ``"uds"`` (``path`` set) or ``"tcp"`` (``host``/``port``
    set).  ``params`` carries coerced query items from the URI;
    ``open_cache`` forwards the recognized ones to the remote client.
    """

    kind: str                                   # "uds" | "tcp"
    path: Optional[str] = None                  # uds socket path
    host: Optional[str] = None                  # tcp host
    port: Optional[int] = None                  # tcp port
    params: Dict[str, object] = field(default_factory=dict, compare=False)
    # provenance stamp (open_store sets it); never part of equality
    uri: Optional[str] = field(default=None, compare=False)

    # open_cache dispatches on this instead of importing the class
    is_cache_address = True

    @property
    def display(self) -> str:
        return self.path if self.kind == "uds" else f"{self.host}:{self.port}"

    def connect_args(self):
        """``(family_kind, address)`` for ``socket.connect``."""
        if self.kind == "uds":
            return "uds", self.path
        return "tcp", (self.host, self.port)


def parse_cache_uri(uri: str, **params) -> DaemonAddress:
    """``cache:///sock/path`` → uds address, ``cache://host:port`` →
    tcp address.  A bare ``cache://`` (no endpoint) is an error."""
    url = urlsplit(uri)
    if url.scheme and url.scheme != SCHEME:
        raise ValueError(f"not a cache:// URI: {uri!r}")
    return address_from_url(url, **params)


def address_from_url(url, **params) -> DaemonAddress:
    """Scheme-registry factory (``storage.api.register_scheme``): the
    ``urlsplit`` result + coerced query params → :class:`DaemonAddress`."""
    netloc, path = url.netloc, url.path
    if netloc:
        host, sep, port = netloc.rpartition(":")
        if sep and port.isdigit() and not path:
            return DaemonAddress("tcp", host=host or "127.0.0.1",
                                 port=int(port), params=params)
        # netloc without a port: a relative socket path ("cache://x.sock")
        path = netloc + path
    if not path:
        raise ValueError(
            f"cache URI {url.geturl()!r} names no endpoint; expected "
            f"cache:///path/to.sock or cache://host:port")
    return DaemonAddress("uds", path=path, params=params)


def format_cache_uri(address: DaemonAddress) -> str:
    if address.kind == "uds":
        return f"cache://{address.path}"
    return f"cache://{address.host}:{address.port}"


def _register() -> None:
    # storage.api's lazy builtin loader imports this module, and a
    # direct ``import repro.daemon`` lands here too — either way the
    # cache:// scheme resolves through the one shared registry
    from ..storage.api import register_scheme
    register_scheme(SCHEME, address_from_url)


_register()

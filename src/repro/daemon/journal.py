"""Crash-consistent daemon state: append-only journal + snapshots.

The daemon's kernel is rebuildable — a cache can always re-learn — but
rebuilding is *slow*: every stream re-converges from UNKNOWN, sticky
pins are forgotten, and the PR 9 spill tier's still-valid files sit
unindexed next to a cold RAM kernel.  :class:`CacheJournal` captures
the small, high-leverage state a restarted daemon needs to warm-start:

* **sticky controls** — ``pin`` / ``never_cache`` prefixes (journaled
  synchronously as records: a pin must survive a crash that happens one
  frame later);
* **classifier verdicts** — the per-dataset ``(pattern, pin_ram)``
  placement hints the engine pushed to the tiered store;
* **a residency manifest** — the CMU roots/quotas and the RAM-resident
  block keys at snapshot time, so the new kernel re-admits its hot set
  (metadata-only: the kernel never held payload bytes, so re-admission
  is exact) while the spill tier re-indexes its own files.

Durability model (standard write-ahead shape):

* records are CRC-32-framed pickles appended to ``journal.log``; replay
  stops at EOF, a short frame, or a CRC mismatch and **truncates the
  torn tail** (a crash mid-append loses at most the record being
  written, never the prefix);
* snapshots serialize the full state into ``state.snap`` via the
  atomic tmp → ``fsync`` → ``os.replace`` dance, then reset the log —
  a crash mid-snapshot leaves the previous snapshot + full log intact
  (``os.replace`` is the commit point);
* replay is idempotent: pins/verdicts are set-valued, manifest entries
  are keyed, so re-applying a record after an earlier partial recovery
  is harmless.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
import zlib
from typing import Any, Iterator, List, Optional, Tuple

__all__ = ["CacheJournal", "JournalStats"]

# record frame: payload length + CRC-32 of the payload, then the pickle
_FRAME = struct.Struct("!II")
# snapshot file: magic + version header, then one framed record
_SNAP_MAGIC = b"IGTJ"
_SNAP_VERSION = 1

SNAP_NAME = "state.snap"
LOG_NAME = "journal.log"


class JournalStats:
    """Counters for one journal (recovery observability)."""

    __slots__ = ("records_appended", "snapshots", "replayed_records",
                 "truncated_bytes", "snapshot_loaded")

    def __init__(self) -> None:
        self.records_appended = 0
        self.snapshots = 0
        self.replayed_records = 0
        self.truncated_bytes = 0
        self.snapshot_loaded = False

    def snapshot(self) -> dict:
        return {name: getattr(self, name) for name in self.__slots__}


def _frame(payload: bytes) -> bytes:
    return _FRAME.pack(len(payload), zlib.crc32(payload) & 0xFFFFFFFF) \
        + payload


def _read_frames(data: bytes) -> Tuple[List[Any], int]:
    """Decode framed records from ``data``; returns (records, clean
    prefix length).  Decoding stops — without raising — at the first
    torn frame: short header, short payload, CRC mismatch, or a payload
    pickle that fails to load."""
    out: List[Any] = []
    pos = 0
    n = len(data)
    while pos + _FRAME.size <= n:
        length, crc = _FRAME.unpack_from(data, pos)
        start = pos + _FRAME.size
        end = start + length
        if end > n:
            break                              # torn tail: partial payload
        payload = data[start:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break                              # torn/corrupt record
        try:
            out.append(pickle.loads(payload))
        except Exception:
            break
        pos = end
    return out, pos


class CacheJournal:
    """One daemon's durable state directory (``state.snap`` +
    ``journal.log``).

    ``append(record)`` journals one event synchronously (write +
    flush); ``write_snapshot(state)`` atomically replaces the snapshot
    and resets the log; ``load()`` returns ``(snapshot_state,
    records)`` replayed from disk, truncating any torn log tail it
    finds.  Thread-safe: one lock serializes append/snapshot/load.
    """

    def __init__(self, root: str, *, fsync: bool = False) -> None:
        self.root = str(root)
        self.fsync = bool(fsync)
        os.makedirs(self.root, exist_ok=True)
        self.snap_path = os.path.join(self.root, SNAP_NAME)
        self.log_path = os.path.join(self.root, LOG_NAME)
        self.stats = JournalStats()
        self._lock = threading.Lock()
        self._log = open(self.log_path, "ab")

    # ------------------------------------------------------------- records
    def append(self, record: Any) -> None:
        """Append one journal record (framed, flushed)."""
        payload = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
        with self._lock:
            self._log.write(_frame(payload))
            self._log.flush()
            if self.fsync:
                os.fsync(self._log.fileno())
            self.stats.records_appended += 1

    # ----------------------------------------------------------- snapshots
    def write_snapshot(self, state: Any) -> None:
        """Atomically replace the snapshot with ``state`` and reset the
        log.  Commit point is ``os.replace`` — a crash anywhere before
        it leaves the previous snapshot + the full log; a crash after
        it but before the log reset merely replays records the new
        snapshot already contains (replay is idempotent)."""
        payload = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
        blob = _SNAP_MAGIC + bytes([_SNAP_VERSION]) + _frame(payload)
        tmp = self.snap_path + f".{os.getpid()}.tmp"
        with self._lock:
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.snap_path)
            # log reset: records up to here are folded into the snapshot
            self._log.close()
            self._log = open(self.log_path, "wb")
            self._log.flush()
            self.stats.snapshots += 1

    # --------------------------------------------------------------- load
    def load(self) -> Tuple[Optional[Any], List[Any]]:
        """Replay state from disk: ``(snapshot_state_or_None,
        journal_records)``.  A torn log tail is truncated in place; an
        unreadable snapshot degrades to ``None`` (cold start) rather
        than raising."""
        with self._lock:
            snap = self._load_snapshot()
            records = self._replay_log()
        self.stats.snapshot_loaded = snap is not None
        self.stats.replayed_records = len(records)
        return snap, records

    def _load_snapshot(self) -> Optional[Any]:
        try:
            with open(self.snap_path, "rb") as f:
                blob = f.read()
        except OSError:
            return None
        head = len(_SNAP_MAGIC) + 1
        if len(blob) < head or blob[:len(_SNAP_MAGIC)] != _SNAP_MAGIC \
                or blob[len(_SNAP_MAGIC)] != _SNAP_VERSION:
            return None
        records, _ = _read_frames(blob[head:])
        return records[0] if records else None

    def _replay_log(self) -> List[Any]:
        try:
            with open(self.log_path, "rb") as f:
                data = f.read()
        except OSError:
            return []
        records, clean = _read_frames(data)
        if clean < len(data):
            # torn tail from a crash mid-append: truncate to the clean
            # prefix so the next append starts on a frame boundary
            self.stats.truncated_bytes += len(data) - clean
            self._log.close()
            with open(self.log_path, "r+b") as f:
                f.truncate(clean)
            self._log = open(self.log_path, "ab")
        return records

    def iter_records(self) -> Iterator[Any]:
        """Convenience: replayed records only (tests / tooling)."""
        _, records = self.load()
        return iter(records)

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        with self._lock:
            try:
                self._log.close()
            except OSError:  # pragma: no cover
                pass

    def __enter__(self) -> "CacheJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Cache-as-a-service: the network cache daemon and its thin clients.

``CacheDaemon`` wraps one ``CacheClient`` (in-process sharded engine or
the supervised multi-process driver) behind a framed socket protocol —
Unix-domain socket by default, TCP optionally — so many independent
processes share one unified cache (the Hoard deployment shape,
arXiv:1812.00669).  ``RemoteCacheClient`` is the thin client;
``open_cache("cache://<sock-or-host:port>")`` builds one from a URI.

Survivability (PR 10): ``CacheJournal`` makes daemon state
crash-consistent (append-only journal + periodic snapshots → warm
restart), ``DaemonSupervisor`` respawns a crashed daemon on the same
socket path inside a restart budget, and the client auto-reconnects
with degraded reads while the daemon is away.

See docs/API.md ("Cache daemon") and docs/RELIABILITY.md (the
fault-of-the-client story: session leases, heartbeats, reclamation;
and the fault-of-the-daemon story: journal, warm restart, reconnect).
"""
from .client import RemoteCacheClient
from .journal import CacheJournal
from .server import CacheDaemon
from .supervisor import DaemonSupervisor
from .uri import DaemonAddress, format_cache_uri, parse_cache_uri

__all__ = ["CacheDaemon", "CacheJournal", "DaemonAddress",
           "DaemonSupervisor", "RemoteCacheClient", "format_cache_uri",
           "parse_cache_uri"]

"""``CacheDaemon``: the cache runtime as a network service.

One daemon process owns the whole caching stack — a ``CacheClient``
over either the in-process sharded engine or the supervised
multi-process driver (``open_cache`` builds it; every knob passes
through) — and serves any number of independent client processes over
a Unix-domain socket (default) or TCP.  This is the Hoard deployment
shape (arXiv:1812.00669): a per-node cache daemon with thin clients,
so many trainer/serving processes share one unified cache and one
store-metadata view instead of each re-materializing its own.

Protocol: framed pickles (``daemon.wire``), request shapes lifted from
the PR 5 worker pipes, read replies in the shared compact codec
(``core.wire``).  Payload bytes for same-node clients cross a
daemon-owned ``ShmArena`` (descriptors on the wire, bytes in shared
memory, slots recycled via piggybacked frees); remote/TCP clients get
the bytes streamed inline, and arena exhaustion spills to inline too
(counted, like the process driver's spill path).

Sessions and leases: every connection is a session with an id and a
heartbeat lease.  *Any* frame renews the lease; a silent client (died
with the socket held open, wedged, live-migrated away) is reaped when
the lease expires.  Reclamation is the fault-of-the-client story
(docs/RELIABILITY.md): the session's live arena slots return to the
free list, its recently issued prefetch candidates are cancelled on
the kernel (bounded window, idempotent — a candidate the executor
already completed is a no-op), and the executor conservation identity
``submitted == completed + cancelled + deduped`` is untouched because
cancellation happens kernel-side, never by dropping executor work.  A
client that dies hard enough to close its socket (process exit) takes
the faster EOF path to the same reclaim.
"""
from __future__ import annotations

import os
import socket
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import numpy as np

from ..core.client import CacheClient, open_cache
from ..core.procdriver import ShmArena, _RegionAllocator
from ..core.types import MB
from ..core.wire import encode_outcome
from .journal import CacheJournal
from .uri import DaemonAddress, format_cache_uri
from .wire import (ConnectionClosed, PROTO_VERSION, ProtocolError, recv_msg,
                   send_msg)

__all__ = ["CacheDaemon", "DEFAULT_LEASE_S", "DEFAULT_SNAPSHOT_EVERY_S"]

DEFAULT_LEASE_S = 5.0
DEFAULT_DAEMON_ARENA = 16 * MB
# per-session bound on remembered prefetch candidates (reclaim window)
CANDIDATE_WINDOW = 4096
# journal snapshot cadence (journal_dir configured; reaper-thread driven)
DEFAULT_SNAPSHOT_EVERY_S = 2.0


def _pending_count(engine) -> int:
    """Kernel pending-prefetch table size across any engine flavor."""
    fn = getattr(engine, "pending_prefetch_count", None)
    if callable(fn):
        return fn()
    shards = getattr(engine, "shards", None)
    if shards is not None:
        return sum(len(s._pending_prefetch) for s in shards)
    return len(engine._pending_prefetch)


class _Session:
    """One connected client: lease deadline, live arena slots, and the
    bounded window of prefetch candidates its reads triggered."""

    __slots__ = ("sid", "conn", "label", "pid", "use_shm", "deadline",
                 "live", "candidates", "reclaimed", "graceful", "send_lock")

    def __init__(self, sid: int, conn, label: str, pid: Optional[int],
                 use_shm: bool, deadline: float) -> None:
        self.sid = sid
        self.conn = conn
        self.label = label
        self.pid = pid
        self.use_shm = use_shm
        self.deadline = deadline
        self.live: Dict[int, int] = {}            # arena offset -> length
        self.candidates: "OrderedDict" = OrderedDict()
        self.reclaimed = False
        self.graceful = False
        # serializes frames onto this connection: the serve thread's
        # replies vs the drain path's out-of-band going_down notice
        self.send_lock = threading.Lock()


class CacheDaemon:
    """Network front end over one ``CacheClient``.

    ``store``/``capacity`` plus ``**open_cache_kw`` build the inner
    client exactly like :func:`~repro.core.client.open_cache` would
    (``driver="process"`` puts the supervised shard workers behind the
    daemon); alternatively pass a pre-built client as ``store``.
    ``uds`` names the listening socket path (a private temp path is
    created when neither ``uds`` nor ``host`` is given); ``host``/
    ``port`` select TCP instead.

    ``lease_s`` is the session lease: a client that sends nothing (not
    even a heartbeat) for this long is presumed dead and reclaimed.
    ``arena_bytes`` sizes the shared-memory payload arena for same-node
    clients (0 disables it — all payloads stream inline).

    ``journal_dir`` makes the daemon crash-consistent (see
    ``daemon.journal``): sticky pins/bans are journaled synchronously,
    the engine's warm-restart manifest (CMU roots/quotas, resident
    keys, placement verdicts) is snapshotted every
    ``snapshot_every_s``, and a daemon constructed over the same
    directory **warm-starts** — pins replayed, verdicts re-pushed, hot
    blocks re-admitted (``restore_stats``), while a PR 9 tiered store
    re-indexes its spill files independently.  ``install_sigterm=True``
    registers a SIGTERM handler that runs :meth:`drain` (graceful
    stop-accept → notify → flush → final snapshot → close); default off
    so embedding processes keep their own signal disposition.
    """

    def __init__(self, store=None, capacity: Optional[int] = None, *,
                 uds: Optional[str] = None,
                 host: Optional[str] = None, port: int = 0,
                 lease_s: float = DEFAULT_LEASE_S,
                 arena_bytes: int = DEFAULT_DAEMON_ARENA,
                 candidate_window: int = CANDIDATE_WINDOW,
                 backlog: int = 16,
                 journal_dir: Optional[str] = None,
                 snapshot_every_s: float = DEFAULT_SNAPSHOT_EVERY_S,
                 journal_fsync: bool = False,
                 install_sigterm: bool = False,
                 **open_cache_kw) -> None:
        if isinstance(store, CacheClient):
            if capacity is not None or open_cache_kw:
                raise ValueError("pass either a CacheClient or "
                                 "(store, capacity, **open_cache_kw)")
            self.client = store
        else:
            if capacity is None:
                raise ValueError("CacheDaemon needs (store, capacity) "
                                 "or a pre-built CacheClient")
            self.client = open_cache(store, capacity, **open_cache_kw)
        self.lease_s = float(lease_s)
        # ---- durable state (crash consistency)
        self.journal: Optional[CacheJournal] = None
        self.restore_stats: dict = {"mode": "none"}
        self._snapshot_every = float(snapshot_every_s)
        self._last_snapshot = time.monotonic()
        self._sticky_pins: "OrderedDict" = OrderedDict()
        self._sticky_bans: "OrderedDict" = OrderedDict()
        if journal_dir is not None:
            self.journal = CacheJournal(journal_dir, fsync=journal_fsync)
            self.restore_stats = self._restore(self.journal)
        self._candidate_window = candidate_window
        self._block_size = self.client.cfg.block_size
        self._arena = ShmArena(arena_bytes, 1) if arena_bytes > 0 else None
        if self._arena is not None and self._arena.shm is not None:
            self._alloc: Optional[_RegionAllocator] = \
                _RegionAllocator(*self._arena.region(0))
            self._arena_total = self._arena.region(0)[1]
        else:
            self._arena, self._alloc, self._arena_total = None, None, 0
        self._alloc_lock = threading.Lock()
        self._lock = threading.Lock()
        self._sessions: Dict[int, _Session] = {}
        self._next_sid = 0
        self._spills = 0
        self._reaped = 0
        self._disconnects = 0
        self._byes = 0
        self._served = 0
        self._cancelled_candidates = 0
        self._closing = False
        self._draining = False
        self._crashed = False
        self._started = False
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._tmpdir: Optional[str] = None
        # ---- listening endpoint
        if host is not None:
            self._listener = socket.socket(socket.AF_INET,
                                           socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            bound_host, bound_port = self._listener.getsockname()[:2]
            self.address = DaemonAddress("tcp", host=bound_host,
                                         port=bound_port)
            self._uds_path = None
        else:
            if uds is None:
                self._tmpdir = tempfile.mkdtemp(prefix="igt-daemon-")
                uds = os.path.join(self._tmpdir, "cache.sock")
            uds = str(uds)
            if os.path.exists(uds):
                os.unlink(uds)
            self._listener = socket.socket(socket.AF_UNIX,
                                           socket.SOCK_STREAM)
            self._listener.bind(uds)
            self._uds_path = uds
            self.address = DaemonAddress("uds", path=uds)
        self._listener.listen(backlog)
        if install_sigterm:
            import signal as _signal
            try:
                _signal.signal(
                    _signal.SIGTERM,
                    lambda *_a: threading.Thread(
                        target=self.drain, name="igt-daemon-drain",
                        daemon=True).start())
            except ValueError:  # pragma: no cover - not the main thread
                pass

    # -------------------------------------------------- durable state
    def _restore(self, journal: CacheJournal) -> dict:
        """Warm-start from the journal directory: fold the snapshot and
        the replayed records into one manifest, then re-admit it into
        the (fresh) engine.  Engines without ``warm_admit`` (the
        process driver keeps kernel state worker-side) still get the
        sticky pins/bans replayed — the documented degradation."""
        t0 = time.monotonic()
        snap, records = journal.load()
        state = dict(snap or {})
        pins = {tuple(p) for p in state.get("pins", ())}
        bans = {tuple(p) for p in state.get("never_cache", ())}
        verdicts = dict(state.get("verdicts") or {})
        for rec in records:
            if not rec:
                continue
            if rec[0] == "pin":
                pins.add(tuple(rec[1]))
            elif rec[0] == "never_cache":
                bans.add(tuple(rec[1]))
            elif rec[0] == "verdict":
                verdicts[str(rec[1])] = (rec[2], bool(rec[3]))
        state["pins"] = sorted(pins)
        state["never_cache"] = sorted(bans)
        state["verdicts"] = verdicts
        for p in state["pins"]:
            self._sticky_pins[p] = None
        for p in state["never_cache"]:
            self._sticky_bans[p] = None
        out = {"snapshot": snap is not None, "records": len(records),
               "mode": "cold"}
        warm = getattr(self.client.engine, "warm_admit", None)
        if snap is None and not records:
            pass                            # nothing durable yet: cold
        elif callable(warm):
            out.update(warm(state, time.monotonic()))
            out["mode"] = "warm"
        else:
            for p in state["pins"]:
                self.client.pin(p)
            for p in state["never_cache"]:
                self.client.never_cache(p)
            out["mode"] = "sticky-only"
        out["restore_s"] = time.monotonic() - t0
        return out

    def _journal_record(self, record) -> None:
        if self.journal is None:
            return
        try:
            self.journal.append(record)
        except OSError:  # pragma: no cover - sick journal disk
            pass

    def write_snapshot(self) -> bool:
        """Snapshot the engine's warm-restart manifest (+ the sticky
        sets the daemon itself tracked) into the journal, resetting the
        log.  Returns False when no journal is configured."""
        if self.journal is None:
            return False
        ws = getattr(self.client.engine, "warm_state", None)
        state = ws() if callable(ws) else {}
        with self._lock:
            pins = {tuple(p) for p in state.get("pins", ())}
            pins.update(self._sticky_pins)
            bans = {tuple(p) for p in state.get("never_cache", ())}
            bans.update(self._sticky_bans)
        state["pins"] = sorted(pins)
        state["never_cache"] = sorted(bans)
        self.journal.write_snapshot(state)
        return True

    # ----------------------------------------------------------- lifecycle
    @property
    def uri(self) -> str:
        """``cache://`` URI clients hand to ``open_cache``."""
        return format_cache_uri(self.address)

    def start(self) -> "CacheDaemon":
        if self._started:
            return self
        self._started = True
        acc = threading.Thread(target=self._accept_loop,
                               name="igt-daemon-accept", daemon=True)
        reap = threading.Thread(target=self._reap_loop,
                                name="igt-daemon-reaper", daemon=True)
        self._threads += [acc, reap]
        acc.start()
        reap.start()
        return self

    def drain(self, timeout: float = 10.0) -> None:
        """Graceful shutdown (the SIGTERM path): stop accepting, tell
        every live session the daemon is ``going_down`` (an out-of-band
        status frame — the client marks the connection down instead of
        diagnosing a crash from EOF), flush in-flight prefetches, write
        a final snapshot, then close.  Idempotent."""
        with self._lock:
            if self._draining or self._closing:
                return
            self._draining = True
        try:
            self._listener.close()
        except OSError:  # pragma: no cover
            pass
        for sess in list(self._sessions.values()):
            try:
                if sess.send_lock.acquire(timeout=1.0):
                    try:
                        send_msg(sess.conn, ("going_down", None))
                    finally:
                        sess.send_lock.release()
            except (ConnectionError, OSError):
                pass                        # that client is already gone
        try:
            self.client.flush(timeout=timeout)
        except Exception:  # pragma: no cover - flush is best-effort here
            pass
        try:
            self.write_snapshot()
        except Exception:  # pragma: no cover - sick journal disk
            pass
        self.close()

    def crash(self) -> None:
        """Abrupt death for drills (the in-process stand-in for
        ``SIGKILL``): every socket is closed mid-conversation — no
        ``going_down``, no flush, **no final snapshot** (recovery must
        work from the journal's last periodic snapshot + log) — and the
        stale UDS socket path is deliberately left behind so the
        respawn exercises the bind-over-stale-path race.  The engine is
        still closed (it lives in *this* process; leaking its executor
        threads would poison the test process), but only after the
        sockets are dead, mirroring the ordering a real kill gives
        clients."""
        with self._lock:
            if self._closing:
                return
            self._crashed = True
            self._closing = True
        self._stop.set()
        try:
            self._listener.close()
        except OSError:  # pragma: no cover
            pass
        for sess in list(self._sessions.values()):
            try:
                sess.conn.close()
            except OSError:  # pragma: no cover
                pass
        if self.journal is not None:
            self.journal.close()
        try:
            self.client.close()
        except Exception:  # pragma: no cover - already half-dead
            pass
        if self._arena is not None:
            self._arena.close()
        # NOTE: self._uds_path is NOT unlinked — the stale socket stays.

    def close(self) -> None:
        if self._closing:
            return
        self._closing = True
        self._stop.set()
        try:
            self._listener.close()
        except OSError:  # pragma: no cover
            pass
        for sess in list(self._sessions.values()):
            self._reclaim(sess, "shutdown")
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=5.0)
        try:
            self.client.flush(timeout=10.0)
        except Exception:  # pragma: no cover - flush is best-effort here
            pass
        if self.journal is not None:
            try:
                self.write_snapshot()
            except Exception:  # pragma: no cover - sick journal disk
                pass
            self.journal.close()
        self.client.close()
        if self._arena is not None:
            self._arena.close()
        if self._uds_path is not None and os.path.exists(self._uds_path):
            try:
                os.unlink(self._uds_path)
            except OSError:  # pragma: no cover
                pass
        if self._tmpdir is not None:
            try:
                os.rmdir(self._tmpdir)
            except OSError:  # pragma: no cover - stray files
                pass

    def __enter__(self) -> "CacheDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------- accept/serve
    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return                      # listener closed: shutting down
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="igt-daemon-conn", daemon=True)
            self._threads.append(t)
            t.start()

    def _serve_conn(self, conn) -> None:
        sess: Optional[_Session] = None
        try:
            op, _, payload = recv_msg(conn)
            if op != "hello" or payload.get("proto") != PROTO_VERSION:
                send_msg(conn, ("err", ProtocolError(
                    f"handshake must be a v{PROTO_VERSION} hello")))
                return
            use_shm = (self._alloc is not None
                       and self.address.kind == "uds"
                       and bool(payload.get("shm", True)))
            with self._lock:
                if self._closing:
                    return
                sid = self._next_sid
                self._next_sid += 1
                sess = _Session(sid, conn, payload.get("label") or f"s{sid}",
                                payload.get("pid"), use_shm,
                                time.monotonic() + self.lease_s)
                self._sessions[sid] = sess
            with sess.send_lock:
                send_msg(conn, ("ok", {
                    "proto": PROTO_VERSION,
                    "session": sid,
                    "lease_s": self.lease_s,
                    "block_size": self._block_size,
                    "shm": self._arena.name if use_shm else None,
                    "server_pid": os.getpid(),
                }))
            while True:
                op, frees, payload = recv_msg(conn)
                sess.deadline = time.monotonic() + self.lease_s
                if frees:
                    self._apply_frees(sess, frees)
                if op == "bye":
                    sess.graceful = True
                    with sess.send_lock:
                        send_msg(conn, ("ok", None))
                    return
                try:
                    result = self._dispatch(sess, op, payload)
                except BaseException as e:
                    try:
                        with sess.send_lock:
                            send_msg(conn, ("err", e))
                    except (ConnectionError, OSError):
                        raise
                    except Exception:   # unpicklable: degrade to repr
                        with sess.send_lock:
                            send_msg(conn, ("err", RuntimeError(repr(e))))
                    continue
                with sess.send_lock:
                    send_msg(conn, ("ok", result))
        except (ConnectionClosed, ConnectionError, OSError, EOFError,
                ProtocolError):
            pass                            # peer died: reclaim below
        finally:
            if sess is not None:
                self._reclaim(sess, "bye" if sess.graceful
                              else "disconnect")
            else:
                try:
                    conn.close()
                except OSError:  # pragma: no cover
                    pass

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, sess: _Session, op: str, payload):
        c = self.client
        if op == "read_batch":
            reqs, now, want = payload
            return self._serve_reads(sess, reqs,
                                     c.read_batch(reqs, now,
                                                  fetch=bool(want)),
                                     want)
        if op == "read":
            fp, off, size, now, want = payload
            res = c.read(fp, off, size, now, fetch=bool(want))
            encs, payloads = self._serve_reads(sess, [(fp, off, size)],
                                               [res], want)
            return encs[0], payloads[0]
        if op == "heartbeat":
            return {"t": time.monotonic(), "session": sess.sid}
        if op == "stats":
            return c.stats
        if op == "snapshot":
            return c.snapshot()
        if op == "hit_ratio":
            return c.hit_ratio()
        if op == "fault_stats":
            return c.fault_stats()
        if op == "shard_states":
            return c.shard_states()
        if op == "tick":
            c.tick(payload)
            return None
        if op == "pin":
            c.pin(payload)
            key = tuple(payload)
            with self._lock:
                self._sticky_pins[key] = None
            self._journal_record(("pin", key))
            return None
        if op == "never_cache":
            c.never_cache(payload)
            key = tuple(payload)
            with self._lock:
                self._sticky_bans[key] = None
            self._journal_record(("never_cache", key))
            return None
        if op == "flush":
            return c.flush(payload)
        if op == "daemon_stats":
            return self.daemon_stats()
        if op == "file_size":
            return c.meta.file_size(payload)
        if op == "subtree_bytes":
            return c.meta.subtree_bytes(payload)
        raise ValueError(f"unknown daemon op {op!r}")

    def _serve_reads(self, sess: _Session, reqs, results, want):
        bs = self._block_size
        encs, payloads = [], []
        for (fp, off, _sz), res in zip(reqs, results):
            self._note_candidates(sess, res.outcome.prefetches)
            encs.append(encode_outcome(res.outcome, off // bs))
            payloads.append(self._stage(sess, res.data) if want else None)
        with self._lock:
            self._served += len(reqs)
        return encs, payloads

    def _note_candidates(self, sess: _Session, prefetches) -> None:
        if not prefetches:
            return
        cands = sess.candidates
        for p, _s in prefetches:
            cands[p] = None
            cands.move_to_end(p)
        while len(cands) > self._candidate_window:
            cands.popitem(last=False)

    def _stage(self, sess: _Session, data):
        """Payload placement: arena slot descriptor for same-node
        sessions, inline bytes otherwise (and on arena exhaustion —
        counted as a spill, like the process driver)."""
        if data is None:
            return None
        arr = np.asarray(data, dtype=np.uint8)
        n = int(arr.size)
        if n == 0:
            return ("raw", b"")
        if sess.use_shm:
            with self._alloc_lock:
                off = self._alloc.alloc(n)
                if off >= 0:
                    sess.live[off] = n
            if off >= 0:
                dst = np.frombuffer(self._arena.shm.buf, dtype=np.uint8,
                                    count=n, offset=off)
                dst[:] = arr
                return ("shm", off, n)
            with self._lock:
                self._spills += 1
        return ("raw", arr.tobytes())

    # ----------------------------------------------------------- reclaim
    def _apply_frees(self, sess: _Session, frees) -> None:
        with self._alloc_lock:
            for off, n in frees:
                if sess.live.pop(off, None) == n:
                    self._alloc.free(off, n)

    def _reclaim(self, sess: _Session, reason: str) -> None:
        """Session teardown — idempotent, reached from the serve thread
        (EOF / bye), the reaper (lease expiry), and ``close``.  Frees
        every arena slot the client still held and cancels its window of
        prefetch candidates on the kernel (clearing pending-table
        entries so re-issue is never suppressed; an already-completed
        candidate is a no-op)."""
        with self._lock:
            if sess.reclaimed:
                return
            sess.reclaimed = True
            self._sessions.pop(sess.sid, None)
            if reason == "lease":
                self._reaped += 1
            elif reason == "disconnect":
                self._disconnects += 1
            elif reason == "bye":
                self._byes += 1
        try:
            sess.conn.close()
        except OSError:  # pragma: no cover
            pass
        with self._alloc_lock:
            for off, n in sess.live.items():
                self._alloc.free(off, n)
            sess.live.clear()
        cancelled = 0
        for path in list(sess.candidates):
            try:
                self.client.cancel_prefetch(path)
                cancelled += 1
            except Exception:  # pragma: no cover - engine shutting down
                break
        sess.candidates.clear()
        with self._lock:
            self._cancelled_candidates += cancelled

    def _reap_loop(self) -> None:
        tick = max(0.05, min(0.25, self.lease_s / 4.0))
        while not self._stop.wait(tick):
            now = time.monotonic()
            for sess in list(self._sessions.values()):
                if now > sess.deadline:
                    self._reclaim(sess, "lease")
            if (self.journal is not None and not self._draining
                    and now - self._last_snapshot >= self._snapshot_every):
                self._last_snapshot = now
                try:
                    self.write_snapshot()
                except Exception:  # pragma: no cover - sick journal disk
                    pass

    # ------------------------------------------------------------- stats
    def daemon_stats(self) -> dict:
        with self._lock:
            sessions = list(self._sessions.values())
            out = {
                "sessions": len(sessions),
                "served_reads": self._served,
                "spills": self._spills,
                "reaped": self._reaped,
                "disconnects": self._disconnects,
                "byes": self._byes,
                "cancelled_candidates": self._cancelled_candidates,
                "draining": self._draining,
                "crashed": self._crashed,
                "restore": dict(self.restore_stats),
                "journal": (self.journal.stats.snapshot()
                            if self.journal is not None else None),
            }
        with self._alloc_lock:
            out["arena_total"] = self._arena_total
            out["arena_free"] = (self._alloc.free_bytes()
                                 if self._alloc is not None else 0)
            out["live_slots"] = sum(len(s.live) for s in sessions)
        out["pending_prefetch"] = _pending_count(self.client.engine)
        return out

"""``DaemonSupervisor``: keep one cache daemon alive on a fixed socket.

The PR 6 shard supervisor answered *fault of the worker* — a shard
process dies, the driver respawns it inside a :class:`RestartBudget`.
This module lifts the same shape one level: the unit of failure is the
whole daemon.  A supervisor owns a ``factory()`` that builds-and-starts
a :class:`~repro.daemon.server.CacheDaemon` **on the same socket path**
every time (clients reconnect to the address they already know — no
re-discovery protocol), a monitor thread that notices a crashed daemon,
and the same sliding-window budget semantics: a daemon that keeps
dying (poisoned journal, bad disk) stops being respawned and the
supervisor converges to a stable ``down`` state — clients with
``degraded=True`` keep serving reads from the backing store.

State machine mirrors the shard vocabulary (``up`` / ``restarting`` /
``down``); transitions land in ``events`` with wall-clock timestamps
and, for each respawn, the measured ``recovery_s`` (factory return to
listening socket — the number the recovery benchmark reports).

In-process by design: the daemon here is an object, not a child
process, so "crash" means :meth:`CacheDaemon.crash` (sockets die
abruptly, journal unsynced, stale UDS path left behind) and drills can
run inside one pytest process with no fork/exec variance.  The factory
indirection is exactly what a process-level supervisor would keep —
swapping in ``subprocess.Popen`` changes the factory, not the loop.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

from ..core.faults import (RestartBudget, SHARD_DOWN, SHARD_RESTARTING,
                           SHARD_UP)
from .server import CacheDaemon

__all__ = ["DaemonSupervisor"]


class DaemonSupervisor:
    """Respawn a crashed :class:`CacheDaemon` on its fixed socket path.

    ``factory`` builds **and starts** a daemon each time it is called;
    it must bind the same address every call (pass an explicit ``uds``
    path and the same ``journal_dir`` so respawns warm-start).
    ``restart_budget`` / ``restart_window_s`` bound the respawn rate —
    exhaustion marks the service permanently ``down``.  ``poll_s`` is
    the monitor cadence for noticing an abrupt crash.
    """

    def __init__(self, factory: Callable[[], CacheDaemon], *,
                 restart_budget: int = 3, restart_window_s: float = 60.0,
                 poll_s: float = 0.05) -> None:
        self._factory = factory
        self._budget = RestartBudget(max_restarts=restart_budget,
                                     window_s=restart_window_s)
        self._poll_s = float(poll_s)
        self._lock = threading.RLock()
        self._closing = False
        self.state = SHARD_UP
        self.restarts = 0
        self.events: List[dict] = []
        self.daemon: CacheDaemon = factory()
        self._log("spawn", recovery_s=None)
        self._stop = threading.Event()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         name="igt-daemon-supervisor",
                                         daemon=True)
        self._monitor.start()

    # ------------------------------------------------------------- events
    def _log(self, kind: str, **extra) -> None:
        ev = {"t": time.monotonic(), "kind": kind, "state": self.state}
        ev.update(extra)
        self.events.append(ev)

    @property
    def uri(self) -> str:
        """The (stable) ``cache://`` URI clients connect to."""
        return self.daemon.uri

    # ------------------------------------------------------------ respawn
    def _respawn(self, reason: str) -> bool:
        """Budget-checked respawn; returns True when the daemon is back
        up.  Caller holds ``self._lock``."""
        if self._closing:
            return False
        if not self._budget.allow(time.monotonic()):
            self.state = SHARD_DOWN
            self._log("budget_exhausted", reason=reason)
            return False
        self.state = SHARD_RESTARTING
        self._log("respawn_start", reason=reason)
        t0 = time.monotonic()
        self.daemon = self._factory()
        self.restarts += 1
        self.state = SHARD_UP
        self._log("respawn_done", reason=reason,
                  recovery_s=time.monotonic() - t0,
                  restore=dict(self.daemon.restore_stats))
        return True

    def _monitor_loop(self) -> None:
        while not self._stop.wait(self._poll_s):
            with self._lock:
                if self._closing or self.state == SHARD_DOWN:
                    return
                if self.daemon._crashed and self.state == SHARD_UP:
                    self._respawn("crash")

    # ------------------------------------------------------------- drills
    def kill_daemon(self) -> None:
        """Abrupt kill (the ``daemon_kill`` strike): sockets die
        mid-conversation, no final snapshot.  The monitor thread
        notices and respawns within the budget."""
        with self._lock:
            self.daemon.crash()
            self._log("kill", recovery_s=None)

    def drain_restart(self) -> bool:
        """Graceful roll (the ``daemon_restart`` strike / SIGTERM
        path): drain — clients get ``going_down``, a final snapshot is
        written — then respawn immediately.  Returns True when the new
        daemon is up."""
        with self._lock:
            self.daemon.drain()
            self._log("drain", recovery_s=None)
            return self._respawn("drain")

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        with self._lock:
            if self._closing:
                return
            self._closing = True
        self._stop.set()
        self._monitor.join(timeout=5.0)
        self.daemon.close()

    def __enter__(self) -> "DaemonSupervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- stats
    def supervisor_stats(self) -> dict:
        with self._lock:
            return {
                "state": self.state,
                "restarts": self.restarts,
                "budget_used": self._budget.used,
                "budget_max": self._budget.max_restarts,
                "events": [dict(e) for e in self.events],
            }

"""Framed binary wire protocol for the cache daemon.

Frames are length-prefixed pickles over a stream socket — the network
promotion of the PR 5 worker-pipe protocol, message shapes included:

* request:  ``(op, frees, payload)`` — ``frees`` is the piggybacked
  list of ``(offset, length)`` arena slots the client has finished
  reading (same slot-recycling trick as the process driver: a free
  never needs its own round trip);
* reply:    ``("ok", result)`` or ``("err", exc)``.

Read replies carry outcomes in the shared compact codec
(``core.wire.encode_outcome`` / ``WireOutcome``) plus one payload
descriptor per request: ``("shm", offset, length)`` when the bytes sit
in the daemon's shared-memory arena (same-node clients), or
``("raw", bytes)`` streamed inline (remote clients / arena spills).

The framing itself is deliberately dumb: a 4-byte big-endian length
then the pickle.  Protocol agreement is checked once at ``hello`` time
(``PROTO_VERSION``), and a frame larger than ``MAX_FRAME`` is treated
as a protocol violation rather than an allocation request.
"""
from __future__ import annotations

import pickle
import struct

__all__ = ["ConnectionClosed", "MAX_FRAME", "PROTO_VERSION",
           "ProtocolError", "recv_msg", "send_msg"]

PROTO_VERSION = 1
_HEADER = struct.Struct("!I")
MAX_FRAME = 512 * 1024 * 1024


class ConnectionClosed(ConnectionError):
    """Peer went away (EOF mid-frame or before one started)."""


class ProtocolError(RuntimeError):
    """Frame that cannot be ours (oversized length prefix)."""


def send_msg(sock, obj) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    # one sendall: header+payload coalesced so small commands are one
    # segment on the wire
    sock.sendall(_HEADER.pack(len(payload)) + payload)


def _recv_exact(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionClosed(
                f"peer closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def recv_msg(sock):
    (n,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if n > MAX_FRAME:
        raise ProtocolError(f"frame of {n} bytes exceeds MAX_FRAME")
    return pickle.loads(_recv_exact(sock, n))

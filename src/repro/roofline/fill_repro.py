"""Patch EXPERIMENTS.md §Repro FILL_ placeholders from bench_output.txt."""
from __future__ import annotations

import re
import sys
from pathlib import Path


def parse(path: Path) -> dict:
    vals = {}
    for line in path.read_text().splitlines():
        if line.startswith("#") or "," not in line:
            continue
        parts = line.split(",", 2)
        if len(parts) >= 2:
            vals[parts[0]] = (parts[1], parts[2] if len(parts) > 2 else "")
    return vals


def main() -> None:
    bench = parse(Path("bench_output.txt"))
    exp_path = Path("EXPERIMENTS.md")
    exp = exp_path.read_text()

    def v(key, default="n/a"):
        return bench.get(key, (default, ""))[0]

    def d(key):
        return bench.get(key, ("", ""))[1]

    fills = {
        "FILL_FIG8_JFS_NC": f"{v('fig8.juicefs_vs_nocache_jct_reduction_pct')} %",
        "FILL_FIG8_JCT": f"{v('fig8.jct_reduction_vs_juicefs_pct')} %",
        "FILL_FIG8_CHR": f"{v('fig8.chr_gain_vs_juicefs_pct')} %",
        "FILL_FIG9_JCT": f"−{v('fig9.jct_reduction_vs_second_best_pct')} % "
                         f"(CHR +{v('fig9.chr_gain_vs_second_best_pct')} %)",
        "FILL_FIG9_HIER": f"−{v('fig9.hierarchical.jct_reduction_pct')} %",
        "FILL_FIG10": f"−{v('fig10.jct_reduction_vs_second_best_pct')} % "
                      f"(CHR +{v('fig10.chr_gain_vs_second_best_pct')} %)",
        "FILL_FIG11": f"{v('fig11.adaptive.evict_start_s')} s "
                      f"(vs {v('fig11.fixed600.evict_start_s')} s fixed)",
        "FILL_FIG12": f"−{v('fig12.jct_reduction_vs_second_best_pct')} % "
                      f"(CHR +{v('fig12.chr_gain_vs_second_best_pct')} %)",
        "FILL_FIG14": f"α=0.01: {v('fig14.alpha_0.01.random_acc')} rand / "
                      f"{d('fig14.alpha_0.01.random_acc').split('=')[-1]} skew",
        "FILL_FIG15": f"w=10: skew {d('fig15.window_10.random_acc').split('=')[-1]}; "
                      f"w=100: {d('fig15.window_100.random_acc').split('=')[-1]}",
        "FILL_FIG16": f"35 %: {v('fig16.cache_35pct.igtcache_chr')} vs "
                      f"{d('fig16.cache_35pct.igtcache_chr').split('=')[-1]}",
        "FILL_FIG17": f"{v('fig17.nodecap_10000.us_per_access')} µs @10k "
                      f"({d('fig17.nodecap_10000.us_per_access').split(' ')[0]})",
    }
    for k, val in fills.items():
        exp = exp.replace(k, val)
    exp_path.write_text(exp)
    print("patched", len(fills), "placeholders")


if __name__ == "__main__":
    main()

"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the optimized HLO text: the summed operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (per-shard shapes → bytes moved per chip, ×(n-1)/n wire
factor folded into the ring estimate).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# shape like  bf16[16,4096,128]{2,1,0:T(8,128)(2,1)}  or  f32[] or tuples
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[\w\[\],{}:()#*\s]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum *output shard* sizes of collective ops in optimized HLO.

    The lhs shape of each collective instruction is the per-shard result —
    a good proxy for bytes a chip moves per invocation (all-reduce moves ~2×
    in a ring; we fold that into a ×2 factor for all-reduce).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r".*=\s*(\S+)\s+(all-gather|all-reduce|reduce-scatter|"
            r"all-to-all|collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        if kind == "all-reduce":
            nbytes *= 2          # reduce-scatter + all-gather ring phases
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float            # global FLOPs (cost_analysis is per-device
                                 # under SPMD — recorded as reported)
    hlo_gbytes: float
    collective_gbytes: float     # per-chip bytes over ICI
    compute_s: float
    memory_s: float
    collective_s: float
    model_gflops: float          # 6·N·D (or 6·N_active·D)
    collectives: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    bytes_per_device: Optional[float] = None
    fits_hbm: Optional[bool] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        if self.hlo_gflops <= 0:
            return 0.0
        return self.model_gflops / self.hlo_gflops

    @property
    def step_time_s(self) -> float:
        """Roofline step estimate: overlap-free upper bound is the max term;
        we report the max (ideal overlap) — the bottleneck term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max-term: 1.0 = perfectly compute-bound."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_gflops": round(self.hlo_gflops, 1),
            "hlo_gbytes": round(self.hlo_gbytes, 2),
            "coll_gbytes": round(self.collective_gbytes, 3),
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "dominant": self.dominant,
            "model_gflops": round(self.model_gflops, 1),
            "useful_ratio": round(self.useful_flops_ratio, 3),
            "roofline_fraction": round(self.roofline_fraction, 3),
            "bytes_per_device_gb": (round(self.bytes_per_device / 2**30, 2)
                                    if self.bytes_per_device else None),
            "fits_hbm_16g": self.fits_hbm,
            "collectives": self.collectives,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for a train step, 2·N·D for inference (per the
    standard decoder accounting), using active params for MoE.  D = tokens
    processed by the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(arch: str, shape, mesh_name: str, chips: int, cost: dict,
            hlo_text: str, cfg, memory_stats: Optional[dict] = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    # bytes accessed: sum the "bytes accessed" keys
    nbytes = float(cost.get("bytes accessed", 0.0))
    if nbytes == 0.0:
        nbytes = sum(float(v) for k, v in cost.items()
                     if k.startswith("bytes accessed"))
    coll = parse_collectives(hlo_text)
    mf = model_flops(cfg, shape)

    # cost_analysis under SPMD reports per-device numbers; normalize terms
    # per chip directly.
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll.total_bytes / ICI_BW

    bytes_per_device = None
    fits = None
    if memory_stats:
        bytes_per_device = memory_stats.get("bytes_per_device")
        if bytes_per_device:
            fits = bytes_per_device <= 16 * 2**30   # v5e HBM

    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=nbytes / 1e9,
        collective_gbytes=coll.total_bytes / 1e9,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_gflops=mf / 1e9 / chips,
        collectives=coll.counts,
        collective_bytes_by_kind={k: v / 1e9 for k, v in
                                  coll.bytes_by_kind.items()},
        bytes_per_device=bytes_per_device, fits_hbm=fits)

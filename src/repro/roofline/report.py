"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from
dryrun_results.json (no recompile — analytic terms computed from configs).

Usage: PYTHONPATH=src python -m repro.roofline.report [results.json]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from ..configs import SHAPES, get_config
from .analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from .analytic import analytic_hbm_bytes


def enrich(row: dict) -> dict:
    if row.get("status") != "ok":
        return row
    cfg = get_config(row["arch"])
    shape = SHAPES[row["shape"]]
    ana = analytic_hbm_bytes(cfg, shape, row["mesh"],
                             row.get("remat", "full"))
    row["analytic_gbytes"] = round(ana / 1e9, 2)
    row["memory_ms_analytic"] = round(ana / HBM_BW * 1e3, 3)
    terms = {"compute": row["compute_ms"],
             "memory": row["memory_ms_analytic"],
             "collective": row["collective_ms"]}
    row["dominant_adj"] = max(terms, key=terms.get)
    peak = max(terms.values())
    row["roofline_fraction_adj"] = (round(row["compute_ms"] / peak, 3)
                                    if peak > 0 else 0.0)
    # achieved fraction: the unavoidable bound (compute or HBM streaming,
    # whichever is larger — the hardware roofline for this cell) over the
    # achieved step bound.  1.0 = the sharding adds no collective overhead
    # beyond the roofline; this is the §Perf score.
    bound = max(row["compute_ms"], row["memory_ms_analytic"])
    row["achieved_fraction"] = round(bound / peak, 3) if peak > 0 else 0.0
    row["step_ms_adj"] = round(peak, 3)
    return row


def table(rows, mesh: str) -> str:
    hdr = ("| arch | shape | chips | compute ms | memory ms (HLO / analytic) "
           "| collective ms | dominant | useful | roofline-frac | achieved | "
           "bytes/dev GB | fits 16G |")
    sep = "|" + "---|" * 12
    out = [hdr, sep]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r.get("mesh", mesh) != mesh and r.get("status") == "ok":
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped (full attention, DESIGN.md) | — | — | — | — "
                       f"| — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | ERROR: "
                       f"{r.get('error','')[:60]} | | | | | | | | |")
            continue
        name = r["arch"]
        if r.get("variant", "baseline") != "baseline":
            name += f" **[{r['variant']}]**"
        out.append(
            f"| {name} | {r['shape']} | {r['chips']} "
            f"| {r['compute_ms']} "
            f"| {r['memory_ms']} / {r.get('memory_ms_analytic','-')} "
            f"| {r['collective_ms']} "
            f"| {r.get('dominant_adj', r['dominant'])} "
            f"| {r['useful_ratio']} "
            f"| {r.get('roofline_fraction_adj', r['roofline_fraction'])} "
            f"| {r.get('achieved_fraction','-')} "
            f"| {r.get('bytes_per_device_gb','-')} "
            f"| {r.get('fits_hbm_16g','-')} |")
    return "\n".join(out)


def main() -> None:
    path = Path(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
    rows = [enrich(dict(r)) for r in json.loads(path.read_text())]
    for mesh in ("single", "multi"):
        sub = [r for r in rows if r.get("mesh", "single") == mesh]
        if not sub:
            continue
        print(f"\n### Roofline — {mesh} mesh "
              f"({'2×16×16' if mesh == 'multi' else '16×16'})\n")
        print(table(sub, mesh))
    path.with_suffix(".enriched.json").write_text(
        json.dumps(rows, indent=1, default=str))


if __name__ == "__main__":
    main()

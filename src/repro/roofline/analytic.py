"""First-order analytic HBM-traffic model (per chip, per step).

XLA's ``cost_analysis()['bytes accessed']`` counts every instruction's
operands at HBM prices — on the real TPU most of those ops fuse, so the
reported number overestimates true HBM traffic by ~5–15×.  The roofline
table therefore carries BOTH: the raw HLO bytes (as specified) and this
documented first-order model, which drives the dominant-term call:

train (per chip):
    params:   all-gathered per layer over the FSDP axis → each chip reads the
              TP-shard twice (fwd+bwd) and writes the gathered copy once
              ≈ 6 B/param / TP
    grads:    reduce-scattered: 4 B/param / TP write + 4 B/param / n read
    optimizer: read+write p(2B), m(4B), v(4B) on the 1/n shard → 20 B/param/n
    activations: ~C_ACT tensors of (tokens_loc × d_model) bf16 per layer,
              ×2 for full remat recompute (C_ACT≈14 write+read pairs)
    logits:   fwd bf16 write+read + f32 softmax/grad round trips
              ≈ 12 B × tokens_loc × vocab/TP
decode (per chip):
    params 2 B/TP, KV cache streamed once (2 B × 2 × L × B × S × KV × hd / n),
    SSD states for ssm/hybrid.
prefill: fwd-only params + activations + logits.
"""
from __future__ import annotations

C_ACT = 14  # activation tensors per layer (write+read), empirical first-order


def _dims(mesh_name: str):
    if mesh_name == "multi":
        return 512, 16  # chips, TP(model axis)
    return 256, 16


def analytic_hbm_bytes(cfg, shape, mesh_name: str, remat: str = "full") -> float:
    chips, tp = _dims(mesh_name)
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    d = cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    tok_loc = max(1, B * S // chips)

    if shape.kind == "train":
        params = 6.0 * P / tp
        grads = 4.0 * P / tp + 4.0 * P / chips
        opt = 20.0 * P / chips
        remat_mult = 2.0 if remat == "full" else 1.5
        acts = C_ACT * remat_mult * L * tok_loc * d * 2.0
        logits = 12.0 * tok_loc * cfg.vocab / tp
        return params + grads + opt + acts + logits
    if shape.kind == "prefill":
        params = 2.0 * Pa / tp
        acts = (C_ACT / 2) * L * tok_loc * d * 2.0
        logits = 4.0 * tok_loc * cfg.vocab / tp
        return params + acts + logits
    # decode: one token/seq — weight- and cache-streaming bound
    tok_loc = max(1, B // min(B, chips // tp) // 1)  # per-chip rows
    params = 2.0 * Pa / tp
    cache = 0.0
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        n_attn = L if cfg.family != "hybrid" else max(
            1, L // max(1, cfg.shared_attn_every))
        cache += 2.0 * 2.0 * n_attn * B * S * cfg.n_kv_heads * cfg.hd / chips
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        cache += (4.0 + 4.0) * L * B * nh * cfg.ssm_head_dim * \
            cfg.ssm_state / chips
    return params + cache

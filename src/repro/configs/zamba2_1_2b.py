"""zamba2-1.2b [arXiv:2411.15242; hf]: Mamba2 backbone + shared attention.

38 Mamba2 layers; a single weight-shared (attention + MLP) block is applied
every 6th layer (the Zamba2 shared-block design). Sub-quadratic: runs
long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6, rope_theta=10000.0,
)

"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from dataclasses import replace

from ..models.config import ModelConfig, SHAPES, ShapeSpec
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .llama_3_2_vision_90b import CONFIG as llama_3_2_vision_90b
from .qwen2_5_14b import CONFIG as qwen2_5_14b
from .llama3_405b import CONFIG as llama3_405b
from .mistral_large_123b import CONFIG as mistral_large_123b
from .qwen3_1_7b import CONFIG as qwen3_1_7b
from .zamba2_1_2b import CONFIG as zamba2_1_2b
from .musicgen_large import CONFIG as musicgen_large
from .mamba2_370m import CONFIG as mamba2_370m

CONFIGS = {
    c.name: c for c in [
        qwen3_moe_30b_a3b, granite_moe_3b_a800m, llama_3_2_vision_90b,
        qwen2_5_14b, llama3_405b, mistral_large_123b, qwen3_1_7b,
        zamba2_1_2b, musicgen_large, mamba2_370m,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(CONFIGS)}")
    return CONFIGS[name]


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    c = get_config(name)
    kw = dict(
        n_layers=2, d_model=64, vocab=256,
        n_heads=4 if c.n_heads else 0,
        n_kv_heads=min(c.n_kv_heads, 2) if c.n_heads else 0,
        head_dim=16 if c.n_heads else 0,
        d_ff=128 if c.d_ff else 0,
        rope_theta=10000.0,
    )
    if c.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff=64)
    if c.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if c.family == "hybrid":
        kw.update(shared_attn_every=2)
    if c.family == "vlm":
        kw.update(cross_attn_every=2, n_image_tokens=16)
    if c.family == "audio":
        kw.update(n_codebooks=c.n_codebooks)
    return replace(c, **kw)


__all__ = ["CONFIGS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config",
           "reduced_config"]

"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]: 48L MoE 128e top-8."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, n_experts=128, top_k=8, qk_norm=True,
    rope_theta=1000000.0,
    skip_shapes=("long_500k",),   # full attention: 500k decode skipped
)

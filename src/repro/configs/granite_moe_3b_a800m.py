"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, n_experts=40, top_k=8,
    rope_theta=10000.0, tie_embeddings=True,
    skip_shapes=("long_500k",),
)

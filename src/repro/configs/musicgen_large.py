"""musicgen-large [arXiv:2306.05284; hf]: decoder-only over EnCodec tokens.

The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (4 codebooks summed), per the assignment note.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, n_codebooks=4, rope_theta=10000.0,
    skip_shapes=("long_500k",),
)

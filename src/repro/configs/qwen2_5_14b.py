"""qwen2.5-14b [hf:Qwen/Qwen2.5-0.5B family; hf]: GQA + QKV bias."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1000000.0,
    skip_shapes=("long_500k",),
)

"""Shared remote link: a single bandwidth pipe with request latency.

Demand (read-miss) transfers strictly precede background prefetch transfers;
within a class, FIFO.  A transfer occupies the pipe for bytes/bandwidth and
completes ``latency`` later (pipelined requests — latency adds delay but does
not hold the pipe).  This is the contention model that makes the
hierarchical-prefetch experiment meaningful: indiscriminate directory
prefetch saturates the pipe and inflates demand latency (Fig. 7, 15.7× JCT).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Optional, Tuple


@dataclass(order=True)
class _Transfer:
    seq: int
    nbytes: int = field(compare=False)
    key: str = field(compare=False)
    demand: bool = field(compare=False)
    callback: Callable[[float], None] = field(compare=False)


class SharedLink:
    def __init__(self, bandwidth_Bps: float, latency_s: float) -> None:
        self.bw = bandwidth_Bps
        self.latency = latency_s
        self.free_at = 0.0
        self._demand: Deque[_Transfer] = deque()
        self._background: Deque[_Transfer] = deque()
        self._seq = itertools.count()
        # key -> (finish_time, transfer) for in-flight/queued background work
        self.inflight: Dict[str, _Transfer] = {}
        self.bytes_moved = 0
        self.busy_time = 0.0

    def enqueue(self, nbytes: int, key: str, demand: bool,
                callback: Callable[[float], None]) -> None:
        t = _Transfer(next(self._seq), nbytes, key, demand, callback)
        (self._demand if demand else self._background).append(t)
        self.inflight[key] = t

    def promote(self, key: str) -> bool:
        """A queued background transfer became demand-critical."""
        t = self.inflight.get(key)
        if t is None or t.demand:
            return False
        try:
            self._background.remove(t)
        except ValueError:
            return False  # already started
        t.demand = True
        self._demand.append(t)
        return True

    def pending(self, key: str) -> bool:
        return key in self.inflight

    def idle(self) -> bool:
        return not self._demand and not self._background

    def pump(self, now: float):
        """Start the next transfer if the pipe is free.

        Returns (finish_time, transfer) or None.  The caller (event loop)
        schedules the completion event and re-pumps afterwards.
        """
        if now < self.free_at or self.idle():
            return None
        t = self._demand.popleft() if self._demand else self._background.popleft()
        start = max(now, self.free_at)
        busy = t.nbytes / self.bw
        self.free_at = start + busy
        self.bytes_moved += t.nbytes
        self.busy_time += busy
        finish = start + busy + self.latency
        self.inflight.pop(t.key, None)
        return finish, t

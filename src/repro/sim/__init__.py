"""Discrete-event cluster simulation for the IGTCache evaluation (§5).

Replaces the paper's physical testbed with a virtual-clock model calibrated
to its measured constants (150 ms S3 latency, 1 Gbps remote link, 4 MB
blocks).  Jobs, datasets and arrival process follow Table 3 (scaled ~10×
down, as the paper itself does for the allocation study)."""
from .chaos import ChaosMonkey, ChaosSchedule, ChaosStrike, plan_strikes
from .cluster import ClusterSim, LinkExecutor, SimResult
from .link import SharedLink
from .workloads import (Job, WorkloadSuite, make_paper_suite, make_datasets)

__all__ = ["ChaosMonkey", "ChaosSchedule", "ChaosStrike", "ClusterSim",
           "Job", "LinkExecutor", "SharedLink", "SimResult",
           "WorkloadSuite", "make_datasets", "make_paper_suite",
           "plan_strikes"]

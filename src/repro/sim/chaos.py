"""Chaos harness: seeded worker-failure injection for the fault runtime.

The supervision/recovery machinery in ``core.procdriver`` is only as
trustworthy as the failures it has been marched through.  This module is
the controlled failure source: a :class:`ChaosMonkey` that kills
(SIGKILL) or wedges (SIGSTOP/SIGCONT) the shard workers of a
``ProcessShardedCache`` on demand, a deterministic strike planner
(``plan_strikes`` — same seed, same schedule), and a
:class:`ChaosSchedule` that fires the planned strikes as a trace driver
advances through its steps.  The cluster simulator accepts the same
strikes as virtual-time events (``ClusterSim(chaos_events=...)``), so a
mixed-workload trace can lose a shard mid-run and the whole
read → degrade → respawn → re-warm arc plays out inside one test.

Strikes are *count-driven* (fire at step N), not wall-clock-driven:
schedules replay bit-identically regardless of machine speed, which is
what lets the fault matrix in tests/test_chaos.py assert exact
bookkeeping (conservation identities, zero lost reads) instead of
sampling a race.

PR 8 adds the *client* failure domain: ``client_kill`` strikes a
registered daemon client (anything with a ``kill()`` — a
``repro.daemon.RemoteCacheClient`` dies silently, socket held open, so
only the daemon's session lease can notice), drilling the
fault-of-the-client arc the same way ``kill``/``suspend`` drill the
fault-of-the-worker one.

PR 10 adds the *daemon* failure domain: ``daemon_kill`` crashes a
supervised cache daemon abruptly (sockets die mid-conversation, no
final snapshot — the SIGKILL stand-in; the
``repro.daemon.DaemonSupervisor`` notices and respawns on the same
socket path, warm-starting from the journal), and ``daemon_restart``
rolls it gracefully (SIGTERM shape: drain → ``going_down`` to
sessions → final snapshot → respawn).  Register the supervisor via
``ChaosMonkey(daemon=...)``; the drill asserts the full kill →
degraded reads → respawn → reconnect → CHR re-convergence arc.

Only the process driver has failure domains to strike; handing an
in-process engine to the monkey is a ``TypeError``, not a silent no-op.
"""
from __future__ import annotations

import os
import random
import signal
import time
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set

__all__ = ["ChaosMonkey", "ChaosSchedule", "ChaosStrike", "plan_strikes"]

KINDS = ("kill", "suspend", "resume", "client_kill", "daemon_kill",
         "daemon_restart")


@dataclass(frozen=True)
class ChaosStrike:
    """One planned failure: at trace step ``step``, do ``kind`` to shard
    (or, for ``client_kill``, registered client) ``sid``.  The daemon
    strikes ignore ``sid`` — there is one supervised daemon."""

    step: int
    kind: str          # "kill" | "suspend" | "resume" | "client_kill"
                       # | "daemon_kill" | "daemon_restart"
    sid: int


class ChaosMonkey:
    """Failure injector over a multi-process shard driver.

    ``target`` is a ``ProcessShardedCache`` or a ``CacheClient`` wrapping
    one.  ``kill`` routes through the driver's own kill path (so the
    fault shows up in ``fault_stats()`` exactly like an RPC-timeout
    kill); ``suspend``/``resume`` SIGSTOP/SIGCONT the worker process
    directly — a stopped worker is the hung-worker case: the pipe stays
    open, no EOF fires, and only heartbeat/RPC deadlines can notice.

    ``clients`` registers daemon-client victims for the ``client_kill``
    strike (index = sid): each must expose ``kill()`` — the
    ``RemoteCacheClient`` drill that goes silent without closing the
    socket, so the daemon's *lease*, not EOF, must reclaim the session.
    ``target`` may be ``None`` when only client strikes are planned.

    Every strike lands in ``self.strikes`` (kind, sid, pid, generation,
    wall time) for post-run audit.
    """

    def __init__(self, target, clients: Sequence = (),
                 daemon=None) -> None:
        driver = getattr(target, "engine", target) \
            if target is not None else None
        if driver is not None and (
                not hasattr(driver, "_channels")
                or not hasattr(driver, "_kill_worker")):
            raise TypeError(
                "ChaosMonkey needs a ProcessShardedCache (or a CacheClient "
                f"over one); got {type(driver).__name__} — in-process "
                "engines have no worker processes to strike")
        if daemon is not None and (
                not hasattr(daemon, "kill_daemon")
                or not hasattr(daemon, "drain_restart")):
            raise TypeError(
                "daemon= needs a DaemonSupervisor (kill_daemon/"
                f"drain_restart); got {type(daemon).__name__} — an "
                "unsupervised daemon would stay dead after the strike")
        if driver is None and not clients and daemon is None:
            raise TypeError("ChaosMonkey with no process driver needs "
                            "at least one registered client victim or a "
                            "supervised daemon")
        self.driver = driver
        self.clients = list(clients)
        self.daemon = daemon
        self.strikes: List[dict] = []
        self._suspended: Set[int] = set()

    # ------------------------------------------------------------- strikes
    def _log(self, kind: str, sid: int, pid: Optional[int]) -> None:
        gen = (self.driver._channels[sid].generation
               if kind in ("kill", "suspend", "resume") else None)
        self.strikes.append({"kind": kind, "sid": sid, "pid": pid,
                             "generation": gen,
                             "at": time.monotonic()})

    def _require_driver(self, kind: str) -> None:
        if self.driver is None:
            raise RuntimeError(f"strike {kind!r} needs a process driver; "
                               "this monkey only has client victims")

    def kill(self, sid: int, reason: str = "chaos") -> None:
        """SIGKILL the shard's current worker via the driver's kill path
        (fault event recorded, supervisor respawns if budget allows)."""
        self._require_driver("kill")
        ch = self.driver._channels[sid]
        pid = ch.proc.pid
        self.driver._kill_worker(sid, reason)
        self._suspended.discard(sid)
        self._log("kill", sid, pid)

    def suspend(self, sid: int) -> None:
        """SIGSTOP the worker: alive to the OS, dead to its callers.
        Undetectable by pipe EOF — this is the case heartbeats and RPC
        deadlines exist for."""
        self._require_driver("suspend")
        pid = self.driver._channels[sid].proc.pid
        try:
            os.kill(pid, signal.SIGSTOP)
            self._suspended.add(sid)
        except ProcessLookupError:      # already gone: nothing to wedge
            pid = None
        self._log("suspend", sid, pid)

    def resume(self, sid: int) -> None:
        """SIGCONT a suspended worker (no-op if it was never suspended or
        the supervisor already killed and replaced it)."""
        if sid not in self._suspended:
            return
        self._require_driver("resume")
        self._suspended.discard(sid)
        pid = self.driver._channels[sid].proc.pid
        try:
            os.kill(pid, signal.SIGCONT)
        except ProcessLookupError:
            pid = None
        self._log("resume", sid, pid)

    def resume_all(self) -> None:
        """Un-wedge everything — call from test teardown so a failing
        assertion never leaves stopped processes behind."""
        for sid in list(self._suspended):
            self.resume(sid)

    def client_kill(self, sid: int) -> None:
        """Kill registered client ``sid`` the crashed-process way: it
        goes silent (heartbeats stop, socket stays open), so the
        daemon's session lease — not EOF — must notice and reclaim."""
        victim = self.clients[sid]
        victim.kill()
        self._log("client_kill", sid, getattr(victim, "pid", None))

    def _require_daemon(self, kind: str) -> None:
        if self.daemon is None:
            raise RuntimeError(f"strike {kind!r} needs a supervised "
                               "daemon (ChaosMonkey(daemon=...))")

    def daemon_kill(self, sid: int = 0) -> None:
        """Crash the supervised daemon abruptly (SIGKILL stand-in):
        every session socket dies mid-conversation, no final snapshot.
        The supervisor respawns within its restart budget; clients see
        EOF, serve degraded reads, and reconnect to the same path."""
        self._require_daemon("daemon_kill")
        self.daemon.kill_daemon()
        self._log("daemon_kill", sid, None)

    def daemon_restart(self, sid: int = 0) -> None:
        """Roll the daemon gracefully (SIGTERM shape): drain — sessions
        get ``going_down``, executor flushed, final snapshot written —
        then respawn immediately on the same socket path."""
        self._require_daemon("daemon_restart")
        self.daemon.drain_restart()
        self._log("daemon_restart", sid, None)

    def strike(self, kind: str, sid: int) -> None:
        if kind not in KINDS:
            raise ValueError(f"unknown strike kind {kind!r}; "
                             f"expected one of {KINDS}")
        getattr(self, kind)(sid)


def plan_strikes(n_steps: int, *, n_shards: int, seed: int = 0,
                 n_strikes: int = 1, kinds: Sequence[str] = ("kill",),
                 min_step: int = 1, resume_after: int = 3,
                 n_clients: int = 0) -> List[ChaosStrike]:
    """Deterministic strike schedule: ``n_strikes`` failures at distinct
    pseudo-random steps in ``[min_step, n_steps)``, kinds and target
    shards drawn from the same seeded stream.  Every planned ``suspend``
    is paired with a ``resume`` ``resume_after`` steps later (clamped to
    the trace) so a schedule can never leave a worker wedged past the
    run.  ``client_kill`` strikes draw their victim from
    ``range(n_clients)`` instead of the shard space.  Same (seed,
    shape) → same schedule, always."""
    for k in kinds:
        if k not in ("kill", "suspend", "client_kill", "daemon_kill",
                     "daemon_restart"):
            raise ValueError("plannable kinds are kill/suspend/client_kill/"
                             f"daemon_kill/daemon_restart, got {k!r}")
    if "client_kill" in kinds and n_clients <= 0:
        raise ValueError("client_kill strikes need n_clients > 0")
    if n_steps <= min_step:
        raise ValueError("trace too short for the requested strike window")
    rng = random.Random(seed)
    span = range(min_step, n_steps)
    steps = sorted(rng.sample(span, min(n_strikes, len(span))))
    out: List[ChaosStrike] = []
    for step in steps:
        kind = kinds[rng.randrange(len(kinds))]
        if kind in ("daemon_kill", "daemon_restart"):
            sid = 0                       # one supervised daemon
        else:
            sid = rng.randrange(n_clients if kind == "client_kill"
                                else n_shards)
        out.append(ChaosStrike(step, kind, sid))
        if kind == "suspend":
            out.append(ChaosStrike(min(n_steps - 1, step + resume_after),
                                   "resume", sid))
    return sorted(out, key=lambda s: (s.step, s.kind != "resume"))


class ChaosSchedule:
    """Binds a strike plan to a monkey: the trace driver calls
    ``on_step(i)`` once per step and every strike planned at step ``i``
    fires.  ``fired`` is the executed subset (a strike against a shard
    can fire at most once per plan entry)."""

    def __init__(self, monkey: ChaosMonkey,
                 strikes: Sequence[ChaosStrike]) -> None:
        self.monkey = monkey
        self._by_step: Dict[int, List[ChaosStrike]] = defaultdict(list)
        for s in strikes:
            self._by_step[s.step].append(s)
        self.fired: List[ChaosStrike] = []

    def on_step(self, step: int) -> List[ChaosStrike]:
        due = self._by_step.pop(step, [])
        for s in due:
            self.monkey.strike(s.kind, s.sid)
            self.fired.append(s)
        return due

    def close(self) -> None:
        self.monkey.resume_all()

"""The Table-3 workload suite (18 jobs, 10 datasets), scaled ~10×.

Each job is a materialized sequence of *steps*; a step is
``(compute_seconds, [(file_path, offset, size), ...])`` — read the batch,
then compute.  Patterns per Table 3: sequential (test/analytics/
preprocessing/checkpoint-load), random (training epochs), skewed (LakeBench
table queries, Wiki RAG), and the mixed LLaVa finetune.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.types import MB, PathT
from ..storage.datasets import DatasetSpec, make_dataset

Request = Tuple[PathT, int, int]          # (file_path, offset, size)
Step = Tuple[float, List[Request]]        # (compute_s, batch of reads)

BLOCK = 4 * MB


@dataclass
class Job:
    job_id: int
    name: str
    dataset: str
    pattern: str                      # sequential | random | skewed | mixed
    steps: List[Step]
    device: str = "V"                 # A/V/C — informational (Table 3)
    submit_time: float = 0.0

    @property
    def n_accesses(self) -> int:
        return sum(len(reqs) for _, reqs in self.steps)


# --------------------------------------------------------------------------
# datasets (Table 1 layouts, scaled ~10×; sizes in bytes)
# --------------------------------------------------------------------------

def make_datasets(scale: float = 1.0) -> Dict[str, DatasetSpec]:
    s = scale

    def n(x: float) -> int:
        return max(2, int(x * s))

    # Sizes keep the paper's *proportions* (total ≈ 430 GB, cache 150 GB,
    # ImageNet+Places ≈ 60 % of the data, hot/query sets a small fraction of
    # the cache) scaled to ≈10 GB total so a full 18-job day runs in seconds.
    return {
        "audiomnist": make_dataset("audiomnist", "flat_files",
                                   n_files=n(2000), small_file_size=64 * 1024),
        "fashionproduct": make_dataset("fashionproduct", "flat_files",
                                       n_files=n(3000), small_file_size=64 * 1024),
        "airquality": make_dataset("airquality", "big_files",
                                   n_files=4, file_size=int(48 * MB * s)),
        "icoads": make_dataset("icoads", "dir_tree", n_dirs=n(120),
                               files_per_dir=10, small_file_size=512 * 1024),
        "bookcorpus": make_dataset("bookcorpus", "big_files",
                                   n_files=8, file_size=int(96 * MB * s)),
        "imagenet": make_dataset("imagenet", "dir_tree", n_dirs=n(310),
                                 files_per_dir=26, small_file_size=512 * 1024),
        "mitplaces": make_dataset("mitplaces", "dir_tree", n_dirs=n(190),
                                  files_per_dir=30, small_file_size=512 * 1024),
        "lakebench": make_dataset("lakebench", "flat_files",
                                  n_files=n(800), small_file_size=512 * 1024),
        "wiki": make_dataset("wiki", "big_files",
                             n_files=8, file_size=int(64 * MB * s)),
        "llava_text": make_dataset("llava_text", "big_files",
                                   n_files=2, file_size=int(64 * MB * s)),
        "llava_images": make_dataset("llava_images", "flat_files",
                                     n_files=n(1200), small_file_size=256 * 1024),
    }


# --------------------------------------------------------------------------
# access-sequence generators
# --------------------------------------------------------------------------

def seq_files(ds: DatasetSpec, passes: int, batch: int, compute: float) -> List[Step]:
    steps: List[Step] = []
    for _ in range(passes):
        reqs = [(f.path, 0, f.size) for f in ds.files]
        for i in range(0, len(reqs), batch):
            steps.append((compute, reqs[i:i + batch]))
    return steps


def coalesce_extents(reqs: Sequence[Request]) -> List[Request]:
    """Merge adjacent same-file contiguous block requests into one extent.

    A run ``(f, 0, B), (f, B, B), (f, 2B, B)`` becomes ``(f, 0, 3B)`` — the
    multi-block extent form the engine's batched ``read()`` was built for
    (one resolve/route/chain replay serves all blocks).  The engine
    decomposes the extent back into the identical block sequence, so cache
    decisions and per-block outcomes are unchanged; only the number of
    engine calls drops.
    """
    out: List[Request] = []
    for path, off, size in reqs:
        if out:
            lpath, loff, lsize = out[-1]
            if lpath == path and loff + lsize == off:
                out[-1] = (lpath, loff, lsize + size)
                continue
        out.append((path, off, size))
    return out


def seq_blocks(ds: DatasetSpec, passes: int, batch: int, compute: float,
               file_limit: Optional[int] = None) -> List[Step]:
    """Sequential block scan; each step's contiguous per-block runs are
    coalesced into multi-block extent reads (``batch`` counts blocks, so
    the bytes-per-step and the block stream are unchanged)."""
    steps: List[Step] = []
    files = ds.files[:file_limit] if file_limit else ds.files
    for _ in range(passes):
        reqs: List[Request] = []
        for f in files:
            nb = max(1, -(-f.size // BLOCK))
            for b in range(nb):
                reqs.append((f.path, b * BLOCK, min(BLOCK, f.size - b * BLOCK)))
        for i in range(0, len(reqs), batch):
            steps.append((compute, coalesce_extents(reqs[i:i + batch])))
    return steps


def random_files(ds: DatasetSpec, epochs: int, batch: int, compute: float,
                 seed: int) -> List[Step]:
    rng = random.Random(seed)
    steps: List[Step] = []
    idx = list(range(len(ds.files)))
    for _ in range(epochs):
        rng.shuffle(idx)
        for i in range(0, len(idx), batch):
            reqs = [(ds.files[j].path, 0, ds.files[j].size)
                    for j in idx[i:i + batch]]
            steps.append((compute, reqs))
    return steps


def random_records(ds: DatasetSpec, n_steps: int, records_per_step: int,
                   record_size: int, compute: float, seed: int) -> List[Step]:
    """Random record reads inside big files (fine-tuning over a corpus)."""
    rng = random.Random(seed)
    steps: List[Step] = []
    for _ in range(n_steps):
        reqs: List[Request] = []
        for _ in range(records_per_step):
            f = ds.files[rng.randrange(len(ds.files))]
            off = rng.randrange(max(1, f.size - record_size))
            reqs.append((f.path, off, record_size))
        steps.append((compute, reqs))
    return steps


def zipf_files(ds: DatasetSpec, n_queries: int, a: float, batch: int,
               compute: float, seed: int,
               drift_every: int = 1200) -> List[Step]:
    """Zipf-hot file queries; the hot set DRIFTS (rotating rank→item map
    every ``drift_every`` queries) — real query popularity is
    non-stationary, which is what separates recency-aware eviction from
    static pinning."""
    rng = np.random.default_rng(seed)
    n = len(ds.files)
    perm = rng.permutation(n)
    steps: List[Step] = []
    reqs: List[Request] = []
    for q in range(n_queries):
        if drift_every and q and q % drift_every == 0:
            perm = rng.permutation(n)
        r = (rng.zipf(a) - 1) % n
        f = ds.files[int(perm[r])]
        reqs.append((f.path, 0, f.size))
        if len(reqs) == batch:
            steps.append((compute, reqs))
            reqs = []
    if reqs:
        steps.append((compute, reqs))
    return steps


def zipf_blocks(ds: DatasetSpec, n_queries: int, a: float, batch: int,
                compute: float, seed: int,
                drift_every: int = 1500) -> List[Step]:
    rng = np.random.default_rng(seed)
    blocks: List[Request] = []
    for f in ds.files:
        nb = max(1, -(-f.size // BLOCK))
        for b in range(nb):
            blocks.append((f.path, b * BLOCK, min(BLOCK, f.size - b * BLOCK)))
    n = len(blocks)
    perm = rng.permutation(n)
    steps: List[Step] = []
    reqs = []
    for q in range(n_queries):
        if drift_every and q and q % drift_every == 0:
            perm = rng.permutation(n)
        r = (rng.zipf(a) - 1) % n
        reqs.append(blocks[int(perm[r])])
        if len(reqs) == batch:
            steps.append((compute, reqs))
            reqs = []
    if reqs:
        steps.append((compute, reqs))
    return steps


def location_scan(ds: DatasetSpec, file_indices: Sequence[int],
                  compute: float) -> List[Step]:
    """ICOADS marine analysis (Fig. 7): one location file per date dir,
    traversing dirs in order — the hierarchical-prefetch showcase."""
    steps: List[Step] = []
    root = ds.root()
    for loc in file_indices:
        for d in ds.dirs[root]:
            fname = ds.dirs[root + (d,)][loc]
            fpath = root + (d, fname)
            size = next(f.size for f in ds.files if f.path == fpath)
            steps.append((compute, [(fpath, 0, size)]))
    return steps


def mixed_llava(text: DatasetSpec, images: DatasetSpec, epochs: int,
                batch: int, compute: float, seed: int) -> List[Step]:
    """LLaVa finetune: sequential text shards + random image batches."""
    rng = random.Random(seed)
    steps: List[Step] = []
    text_blocks: List[Request] = []
    for f in text.files:
        nb = max(1, -(-f.size // BLOCK))
        for b in range(nb):
            text_blocks.append((f.path, b * BLOCK, min(BLOCK, f.size - b * BLOCK)))
    ti = 0
    idx = list(range(len(images.files)))
    for _ in range(epochs):
        rng.shuffle(idx)
        for i in range(0, len(idx), batch):
            reqs = [(images.files[j].path, 0, images.files[j].size)
                    for j in idx[i:i + batch]]
            reqs.append(text_blocks[ti % len(text_blocks)])
            ti += 1
            steps.append((compute, reqs))
    return steps


# --------------------------------------------------------------------------
# the 18-job suite (Table 3)
# --------------------------------------------------------------------------

@dataclass
class WorkloadSuite:
    datasets: Dict[str, DatasetSpec]
    jobs: List[Job]

    def total_bytes(self) -> int:
        return sum(d.total_bytes for d in self.datasets.values())


def make_paper_suite(scale: float = 1.0, seed: int = 0,
                     poisson_beta: float = 60.0,
                     job_filter: Optional[Sequence[int]] = None) -> WorkloadSuite:
    ds = make_datasets(scale)
    J = []

    def add(jid, name, dsname, pattern, steps, device):
        J.append(Job(jid, name, dsname, pattern, steps, device))

    add(1, "vgg16_train_audiomnist", "audiomnist", "sequential",
        seq_files(ds["audiomnist"], 2, 32, 0.25), "V")
    add(2, "vgg16_test_fashion", "fashionproduct", "sequential",
        seq_files(ds["fashionproduct"], 3, 32, 0.12), "V")
    add(3, "airquality_analysis", "airquality", "sequential",
        seq_blocks(ds["airquality"], 1, 4, 0.05), "C")
    add(4, "marine_analysis_icoads", "icoads", "sequential",
        location_scan(ds["icoads"], [3, 7], 0.08), "C")
    add(5, "preprocess_icoads", "icoads", "sequential",
        seq_files(ds["icoads"], 2, 8, 0.06), "C")
    add(6, "opt125m_ckpt_load", "bookcorpus", "sequential",
        seq_blocks(ds["bookcorpus"], 1, 8, 0.01, file_limit=4), "A")
    add(7, "opt125m_finetune", "bookcorpus", "random",
        random_records(ds["bookcorpus"], 2500, 8, 64 * 1024, 0.18, seed + 7), "A")
    add(8, "resnet50_test_imagenet", "imagenet", "sequential",
        seq_files(ds["imagenet"], 2, 32, 0.10), "V")
    add(9, "resnet50_train_imagenet", "imagenet", "random",
        random_files(ds["imagenet"], 5, 32, 0.22, seed + 9), "V")
    add(10, "alexnet_train_imagenet", "imagenet", "random",
        random_files(ds["imagenet"], 5, 32, 0.15, seed + 10), "V")
    add(11, "alexnet_test_mitplaces", "mitplaces", "sequential",
        seq_files(ds["mitplaces"], 2, 32, 0.10), "V")
    add(12, "resnet50_train_mitplaces", "mitplaces", "random",
        random_files(ds["mitplaces"], 5, 32, 0.22, seed + 12), "V")
    add(13, "alexnet_train_mitplaces", "mitplaces", "random",
        random_files(ds["mitplaces"], 5, 32, 0.15, seed + 13), "V")
    add(14, "lakebench_join", "lakebench", "skewed",
        zipf_files(ds["lakebench"], 3000, 1.2, 4, 0.06, seed + 14), "C")
    add(15, "lakebench_union", "lakebench", "skewed",
        zipf_files(ds["lakebench"], 2500, 1.1, 4, 0.06, seed + 15), "C")
    add(16, "rag_large_wiki", "wiki", "skewed",
        zipf_blocks(ds["wiki"], 6000, 1.2, 2, 0.08, seed + 16), "V")
    add(17, "rag_small_wiki", "wiki", "skewed",
        zipf_blocks(ds["wiki"], 2500, 1.4, 2, 0.08, seed + 17), "V")
    add(18, "llava_finetune", "llava_images", "mixed",
        mixed_llava(ds["llava_text"], ds["llava_images"], 3, 32, 0.25,
                    seed + 18), "A")

    if job_filter is not None:
        keep = set(job_filter)
        J = [j for j in J if j.job_id in keep]

    # Poisson arrivals (§5.1): expected inter-arrival beta seconds.
    rng = random.Random(seed + 100)
    t = 0.0
    for j in J:
        j.submit_time = t
        t += rng.expovariate(1.0 / poisson_beta)
    return WorkloadSuite(datasets=ds, jobs=J)

"""Discrete-event cluster simulator: jobs × IGTCache × shared remote link.

Semantics:
  * every job owns its compute device (Table 3 assigns distinct GPUs), so
    jobs contend only for the remote link and the shared cache;
  * a step = read batch → compute; the step's compute starts when all its
    demand bytes have landed (hits cost the local service time);
  * engine-issued prefetch candidates ride the link at background priority
    and are admitted on completion (``complete_prefetch``);
  * a demand read that finds its block already in flight (as someone else's
    miss or a background prefetch) waits for that transfer instead of
    re-fetching (single-flight).

The simulator is a ``CacheClient`` consumer: it drives the kernel through
the client layer with a :class:`LinkExecutor` — the executor that models
prefetch transport as background-priority transfers on the shared link
(the sim owns time and bandwidth, so candidates cannot complete inline;
they complete when the event loop lands their transfer and calls
``client.complete_prefetch``).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

# The simulator drives the kernel only through the client layer, which
# itself only uses the public kernel surface (read_batch /
# complete_prefetch / tick / ...), so the sharded facade slots in
# unchanged.
from ..core import path_key
from ..core.allocation import marginal_benefit
from ..core.client import CacheClient, PrefetchExecutor
from ..core.sharded import Engine
from ..core.types import PathT
from .link import SharedLink
from .workloads import Job, WorkloadSuite


class LinkExecutor(PrefetchExecutor):
    """PrefetchExecutor over the simulated shared link.

    ``submit`` enqueues each candidate as a background-priority transfer
    (skipping blocks already in flight — single-flight); the sim's event
    loop completes or promotes them.  Completion/cancellation accounting
    therefore lives in the event loop, not here: the executor only hands
    candidates to the bandwidth model.
    """

    def __init__(self, link: SharedLink) -> None:
        super().__init__()
        self.link = link
        # tier-aware transport: when the client's backing store is a
        # TieredStore (mode="index"), disk-resident prefetch candidates
        # complete instantly from local disk instead of riding the link
        self.tier = None

    def submit(self, candidates, now: float) -> None:
        self.stats.submitted += len(candidates)
        for ppath, psize in candidates:
            pkey = path_key(ppath)
            t = self.link.inflight.get(pkey)
            if t is None:
                if self.tier is not None and \
                        self.tier.sim_read(pkey, psize, prefetch=True):
                    self.engine.complete_prefetch(ppath, psize, now)
                    self.stats.completed += 1
                    continue
                self.link.enqueue(psize, pkey, demand=False,
                                  callback=(ppath, psize))
            elif t.callback is None:
                # the in-flight transfer is pure demand: it will land
                # without calling complete_prefetch, so this candidate
                # must be cancelled, not skipped — otherwise its kernel
                # pending-table entry leaks and suppresses re-issue
                self.engine.cancel_prefetch(ppath)
                self.stats.cancelled += 1
            # else: an in-flight prefetch transfer for the same block —
            # its completion clears the (shared) pending entry; skip


@dataclass
class SimResult:
    jct: Dict[int, float]                      # job_id -> completion seconds
    hit_ratio: float
    stats: dict
    makespan: float
    link_utilization: float
    step_trace: Dict[int, List[float]]         # job_id -> step finish times
    alloc_trace: List[dict] = field(default_factory=list)
    chaos_log: List[dict] = field(default_factory=list)
    # per-round cross-shard rebalance stats (moves applied, bytes moved,
    # summary payload bytes, ghost mass) — empty for unsharded engines
    rebalance_trace: List[dict] = field(default_factory=list)
    # tiered-backing accounting (storage.tiers tier_stats snapshot):
    # disk hits / remote bytes for the bytes-moved comparison — empty
    # when the backing store has no tiers
    tier_stats: dict = field(default_factory=dict)
    # total bytes that crossed the remote link (demand + prefetch): the
    # bytes-moved axis of the tiered-vs-flat comparison
    link_bytes: int = 0

    @property
    def avg_jct(self) -> float:
        return sum(self.jct.values()) / max(1, len(self.jct))


class ClusterSim:
    def __init__(self, suite: WorkloadSuite, engine: Union[Engine, CacheClient],
                 bandwidth_Bps: float = 125e6, latency_s: float = 0.150,
                 local_latency_s: float = 0.0005,
                 local_bandwidth_Bps: float = 6e9,
                 disk_latency_s: float = 0.002,
                 disk_bandwidth_Bps: float = 2e9,
                 trace_alloc: bool = False,
                 stop_job_at: Optional[Tuple[int, float]] = None,
                 chaos_events: Optional[List[Tuple[float, str, int]]]
                 = None,
                 chaos_clients: Optional[List] = None,
                 chaos_daemon=None) -> None:
        self.suite = suite
        self.link = SharedLink(bandwidth_Bps, latency_s)
        # Accept any of three layers: a CacheClient (open_cache path), a
        # bare kernel, or a RemoteCacheClient session against a running
        # CacheDaemon.  For the local layers the sim re-routes prefetch
        # transport onto its own link — inside the simulation, background
        # bytes must contend for the modeled bandwidth, so an
        # inline/threaded executor would be wrong here.  A passed client
        # is reused (its previous executor is closed, with queued
        # candidates cancelled on the kernel).  A *remote* client has no
        # local executor to re-route (the daemon owns prefetch transport)
        # — the sim charges its demand misses to the link as pure-demand
        # transfers and drives the shared kernel timeline via explicit
        # ``now`` stamps; this is the harness the daemon-kill chaos
        # drills run in (wall-clock daemon recovery under a virtual-time
        # trace, reconciled via ``at()`` probes).
        self._remote = bool(getattr(engine, "is_remote_cache_client",
                                    False))
        if self._remote:
            self.client = engine
            self.engine = None
        elif isinstance(engine, CacheClient):
            self.client = engine
            self.client.set_executor(LinkExecutor(self.link))
            self.engine = self.client.engine
        else:
            self.client = CacheClient(engine,
                                      executor=LinkExecutor(self.link),
                                      clock=lambda: self.now)
            self.engine = self.client.engine
        self.local_latency = local_latency_s
        self.local_bw = local_bandwidth_Bps
        self.disk_latency = disk_latency_s
        self.disk_bw = disk_bandwidth_Bps
        # a tiered backing store (storage.tiers) exposes sim_read: missed
        # blocks resident in the spill tier cost a local disk read, not a
        # remote-link transfer — the tier-aware bytes-moved model
        backing = getattr(self.client, "backing", None)
        self._tier = backing if callable(getattr(backing, "sim_read",
                                                 None)) else None
        if not self._remote:
            self.client.executor.tier = self._tier
        self.trace_alloc = trace_alloc
        self.stop_job_at = stop_job_at       # (job_id, time): forced stop (Fig 11)
        # (virtual time, kind, sid) strikes against a process-backed
        # engine: the chaos arc (kill → degraded reads → respawn →
        # re-warm) plays out inside the simulated trace.  Worker strikes
        # (kill/suspend/resume) need a multi-process driver (sim.chaos);
        # "client_kill" strikes target ``chaos_clients[sid]`` instead —
        # daemon clients registered as victims, so a trace can lose a
        # remote cache client mid-run and the daemon's lease reclaim
        # plays out alongside the simulated workload.
        self.chaos_events = list(chaos_events or [])
        self.chaos_clients = list(chaos_clients or [])
        # a DaemonSupervisor: the victim of daemon_kill/daemon_restart
        # strikes (sim.chaos) — the daemon failure domain
        self.chaos_daemon = chaos_daemon
        self._chaos = None
        self._chaos_log: List[dict] = []
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._waiters: Dict[str, List[int]] = {}
        self._outstanding: Dict[int, int] = {}
        self._step_idx: Dict[int, int] = {}
        self._jobs: Dict[int, Job] = {j.job_id: j for j in suite.jobs}
        self._done: Dict[int, float] = {}
        self._step_trace: Dict[int, List[float]] = {j.job_id: [] for j in suite.jobs}
        self._alloc_trace: List[dict] = []
        self._stopped: set = set()
        self.now = 0.0

    # ---------------------------------------------------------------- events
    def _push(self, t: float, kind: str, payload: object = None) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def at(self, t: float, fn: Callable[["ClusterSim"], None]) -> None:
        """Schedule ``fn(sim)`` at virtual time ``t`` (before ``run``):
        a measurement probe inside the event loop — the chaos tests use
        it to snapshot stats at fixed virtual times so windowed CHR is
        comparable across baseline and fault runs."""
        self._push(t, "probe", fn)

    def run(self, max_time: float = 1e7) -> SimResult:
        for j in self.suite.jobs:
            self._push(j.submit_time, "job_start", j.job_id)
        self._push(5.0, "tick", None)
        if self.stop_job_at is not None:
            self._push(self.stop_job_at[1], "stop_job", self.stop_job_at[0])
        for t, kind, sid in self.chaos_events:
            self._push(t, "chaos", (kind, sid))
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if t > max_time:
                break
            self.now = t
            if kind == "job_start":
                self._step_idx[payload] = 0
                self._start_step(payload)
            elif kind == "compute_done":
                jid = payload
                if jid in self._stopped:
                    continue
                self._step_trace[jid].append(self.now)
                self._step_idx[jid] += 1
                self._start_step(jid)
            elif kind == "pump":
                self._pump()
            elif kind == "transfer_done":
                self._on_transfer_done(*payload)
            elif kind == "tick":
                self.client.tick(self.now)
                if self.trace_alloc:
                    self._sample_alloc()
                if len(self._done) + len(self._stopped) < len(self._jobs):
                    self._push(self.now + 5.0, "tick", None)
            elif kind == "stop_job":
                self._stopped.add(payload)
            elif kind == "chaos":
                self._strike(*payload)
            elif kind == "probe":
                payload(self)
        jct = {jid: t - self._jobs[jid].submit_time
               for jid, t in self._done.items()}
        if self._chaos is not None:       # never leave a worker wedged
            self._chaos.resume_all()
        util = self.link.busy_time / max(1e-9, self.now)
        reb = getattr(self.engine, "global_rebalancer", None)
        # remote mode: the daemon owns the kernel — ask over the wire
        # (best-effort: the trace may end with the daemon still away)
        src = self.client if self._remote else self.engine
        try:
            hit_ratio, stats = src.hit_ratio(), src.snapshot()
        except ConnectionError:         # incl. DaemonUnavailableError
            hit_ratio, stats = -1.0, {}
        return SimResult(jct=jct, hit_ratio=hit_ratio,
                         stats=stats, makespan=self.now,
                         link_utilization=util, step_trace=self._step_trace,
                         alloc_trace=self._alloc_trace,
                         chaos_log=self._chaos_log,
                         rebalance_trace=(list(reb.round_log)
                                          if reb is not None else []),
                         tier_stats=(self._tier.tier_stats()
                                     if self._tier is not None else {}),
                         link_bytes=self.link.bytes_moved)

    def _strike(self, kind: str, sid: int) -> None:
        if self._chaos is None:
            from .chaos import ChaosMonkey
            driver_like = (hasattr(self.engine, "_channels")
                           and hasattr(self.engine, "_kill_worker"))
            if driver_like or not (self.chaos_clients
                                   or self.chaos_daemon is not None):
                # preserves the TypeError for worker strikes against an
                # in-process engine with no other victims either
                self._chaos = ChaosMonkey(self.engine,
                                          clients=self.chaos_clients,
                                          daemon=self.chaos_daemon)
            else:
                self._chaos = ChaosMonkey(None,
                                          clients=self.chaos_clients,
                                          daemon=self.chaos_daemon)
        self._chaos.strike(kind, sid)
        self._chaos_log.append({"t": self.now, "kind": kind, "sid": sid})

    # ----------------------------------------------------------------- steps
    def _start_step(self, jid: int) -> None:
        if jid in self._stopped:
            return
        job = self._jobs[jid]
        i = self._step_idx[jid]
        if i >= len(job.steps):
            self._done[jid] = self.now
            return
        compute, reqs = job.steps[i]
        waits = 0
        local_cost = 0.0
        # batched client path: one kernel call per step batch (tick cadence
        # amortized per batch); the client hands each outcome's prefetch
        # candidates to the LinkExecutor, which puts them on the link at
        # background priority.  The sim then settles the demand blocks.
        results = self.client.read_batch(reqs, self.now)
        for res in results:
            for blk in res.blocks:
                if blk.hit:
                    local_cost += self.local_latency + blk.size / self.local_bw
                    if self.link.pending(blk.key):
                        # bytes still in flight (admitted at miss/prefetch
                        # issue time) — single-flight: wait on that transfer
                        self.link.promote(blk.key)
                        self._waiters.setdefault(blk.key, []).append(jid)
                        waits += 1
                else:
                    if self.link.pending(blk.key):
                        self.link.promote(blk.key)
                    elif self._tier is not None and \
                            self._tier.sim_read(blk.key, blk.size):
                        # spill-tier hit: the block is on local disk —
                        # serve it at disk cost, no link transfer
                        local_cost += (self.disk_latency
                                       + blk.size / self.disk_bw)
                        continue
                    else:
                        self.link.enqueue(blk.size, blk.key, demand=True,
                                          callback=None)
                    self._waiters.setdefault(blk.key, []).append(jid)
                    waits += 1
        self._outstanding[jid] = waits
        self._pump()
        if waits == 0:
            self._push(self.now + compute + local_cost, "compute_done", jid)
        else:
            # stash compute duration; applied when last byte lands
            self._pending_compute = getattr(self, "_pending_compute", {})
            self._pending_compute[jid] = compute + local_cost

    def _pump(self) -> None:
        while True:
            got = self.link.pump(self.now)
            if got is None:
                break
            finish, t = got
            self._push(finish, "transfer_done", (t.key, t.demand, t.callback))
            # link frees (busy end) possibly before 'finish' due to latency
            self._push(self.link.free_at, "pump", None)

    def _on_transfer_done(self, key: str, demand: bool, callback) -> None:
        if callback is not None:
            ppath, psize = callback
            self.client.complete_prefetch(ppath, psize, self.now)
        for jid in self._waiters.pop(key, ()):  # wake demand waiters
            if jid in self._stopped:
                continue
            self._outstanding[jid] -= 1
            if self._outstanding[jid] == 0:
                compute = self._pending_compute.pop(jid, 0.0)
                self._push(self.now + compute, "compute_done", jid)
        self._pump()

    # ----------------------------------------------------------------- traces
    def _sample_alloc(self) -> None:
        row = {"t": self.now}
        for path, cmu in self.engine.iter_workload_cmus():
            est = marginal_benefit(cmu, self.now, self.engine.cfg)
            row["/".join(path)] = {"quota": cmu.quota, "used": cmu.used,
                                   "benefit": est.benefit}
        self._alloc_trace.append(row)

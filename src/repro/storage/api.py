"""The unified storage API: BackingStore v2 + URI-addressed store registry.

The PR-3 client reduced "where do bytes come from" to a single blocking
``fetch_block(path, size)`` seam.  That was enough for the simulator but
not for real backends: no sub-block ranges (partial-extent reads
over-fetch whole blocks), no batching (multi-shard demand misses fetch
serially), no failure semantics (a flaky backend kills a worker or hangs
a reader), and no way to *name* a store.  This module is the redesigned
storage surface every backend plugs into (Hoard arXiv:1812.00669 draws
the same adapter line between cache service and storage backends):

* :class:`BackingStore` — the v2 protocol: ``fetch_range(path, offset,
  length)``, ``fetch_many(requests)``, ``capabilities()``, with the
  legacy ``fetch_block`` kept as a derived method;
* :class:`StoreCapabilities` — capability negotiation (native ranges,
  native batching, safe fan-out) so clients can plan fetches;
* :class:`StoreError` / :class:`TransientStoreError` — the typed error
  taxonomy, and :class:`RetryPolicy` — bounded retry + backoff on
  transient errors (permanent errors propagate immediately);
* :func:`register_scheme` / :func:`open_store` — the URI front door
  (``sim://``, ``file:///dir``, ``mem://``, ``faulty+<scheme>://``);
* :class:`StoreMetaIndex` — the dict-backed ``core.meta.StoreMeta``
  implementation shared by the simulated store, the local-filesystem
  walker and the in-memory test store;
* :class:`LegacyStoreAdapter` / :func:`as_backing_store` — the shim that
  keeps third-party one-method ``fetch_block`` stores working unchanged.

Addressing convention: fetch paths accept either a *file path* tuple or
a *block path* (file path + ``"#<n>"`` leaf, built by
``core.types.block_key``).  For a block path, ``offset`` is relative to
the block start; stores resolve it to an absolute file offset via their
``block_size``.
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)
from urllib.parse import parse_qsl, unquote, urlsplit, urlunsplit

import numpy as np

from ..core.types import MB, PathT, block_key, split_block_key

__all__ = [
    "BackingStore", "CircuitBreaker", "CircuitOpenError", "DeadlineError",
    "FaultyStore", "LegacyStoreAdapter", "MemStore",
    "RangeRequest", "RetryPolicy", "StoreCapabilities", "StoreError",
    "StoreMetaIndex", "TransientStoreError", "as_backing_store",
    "open_store", "register_scheme", "registered_schemes",
    "resolve_store_spec", "store_spec",
]

# One demand fetch: (file-or-block path, offset within it, length).
RangeRequest = Tuple[PathT, int, int]


# ---------------------------------------------------------------------------
# error taxonomy + retry
# ---------------------------------------------------------------------------

class StoreError(Exception):
    """Permanent storage failure: retrying cannot help (missing object,
    corrupt range, misconfigured backend).  Callers must propagate it and
    release any kernel state tied to the fetch."""


class TransientStoreError(StoreError):
    """Retryable storage failure (timeout, throttling, flaky link).  The
    client's :class:`RetryPolicy` absorbs these up to its attempt bound;
    past the bound the error propagates like a permanent one."""


class DeadlineError(StoreError):
    """The caller's time budget ran out before the fetch succeeded.

    Raised by :meth:`RetryPolicy.call` when ``deadline_s`` is set and the
    next retry (or the attempt just finished) would land past the budget.
    Permanent by design: a reader blocked on a sick store gets a fast,
    typed error instead of an unbounded wait — it can then fall back
    (degraded read) or surface the failure."""


class CircuitOpenError(TransientStoreError):
    """Fast-failed by an OPEN circuit breaker: the store has been failing
    consecutively and callers are short-circuited until the half-open
    probe window.  Transient by taxonomy (the breaker will half-open),
    but :class:`RetryPolicy` does **not** retry it — retrying against an
    open breaker is exactly the hammering the breaker exists to stop."""


class CircuitBreaker:
    """Per-store circuit breaker: CLOSED → OPEN after ``threshold``
    *consecutive* transient failures, OPEN → HALF_OPEN after
    ``reset_s``, HALF_OPEN → CLOSED on one success (or back to OPEN on
    failure).

    Only transient failures count (permanent errors already fail fast
    and retrying cannot help, so they carry no load signal).  While OPEN,
    ``before_call`` raises :class:`CircuitOpenError` immediately —
    callers get a fast error instead of burning their deadline against a
    store that has been failing for everyone.  In HALF_OPEN exactly one
    caller at a time is let through as the probe.

    Thread-safe; ``clock`` is injectable for virtual-time tests.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 5, reset_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.reset_s = reset_s
        self.clock = clock
        self._lock = threading.Lock()
        self._failures = 0            # consecutive transient failures
        self._state = self.CLOSED
        self._opened_at = 0.0
        self._probing = False         # half-open: one probe in flight
        self.trips = 0                # times the breaker opened
        self.fast_failures = 0        # calls short-circuited while open

    @property
    def state(self) -> str:
        with self._lock:
            return self._peek_state()

    def _peek_state(self) -> str:
        if (self._state == self.OPEN
                and self.clock() - self._opened_at >= self.reset_s):
            return self.HALF_OPEN
        return self._state

    def before_call(self) -> None:
        """Admission check: raises :class:`CircuitOpenError` while OPEN;
        in HALF_OPEN admits a single probe and fast-fails the rest."""
        with self._lock:
            state = self._peek_state()
            if state == self.CLOSED:
                return
            if state == self.HALF_OPEN and not self._probing:
                self._state = self.HALF_OPEN
                self._probing = True
                return
            self.fast_failures += 1
            raise CircuitOpenError(
                f"circuit breaker open ({self._failures} consecutive "
                f"transient failures; retry after {self.reset_s}s)")

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._state = self.CLOSED
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            self._probing = False
            if self._state == self.HALF_OPEN or \
                    self._failures >= self.threshold:
                if self._state != self.OPEN:
                    self.trips += 1
                self._state = self.OPEN
                self._opened_at = self.clock()

    def snapshot(self) -> dict:
        with self._lock:
            return {"state": self._peek_state(),
                    "consecutive_failures": self._failures,
                    "trips": self.trips,
                    "fast_failures": self.fast_failures}


@dataclass
class RetryPolicy:
    """Bounded retry + exponential backoff for transient store errors.

    Only :class:`TransientStoreError` is retried; permanent
    :class:`StoreError` and unrelated exceptions propagate immediately.
    ``sleep`` is injectable so tests (and virtual-clock callers) retry
    without wall-clock delay.

    ``deadline_s`` is the *total* time budget across all attempts: when
    set, an attempt is never started (and a backoff never slept) past
    ``start + deadline_s`` — the call raises :class:`DeadlineError`
    instead, so a hanging or endlessly-flaky store costs a bounded wait.
    ``breaker`` (a :class:`CircuitBreaker`, also overridable per call)
    is consulted before and after every attempt: an OPEN breaker fails
    the call immediately with :class:`CircuitOpenError` (never retried).
    """

    max_attempts: int = 3
    backoff_s: float = 0.005
    multiplier: float = 2.0
    max_backoff_s: float = 0.5
    deadline_s: Optional[float] = None
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)
    clock: Callable[[], float] = field(default=time.monotonic, repr=False)
    breaker: Optional[CircuitBreaker] = field(default=None, repr=False)

    def call(self, fn: Callable, *args,
             on_retry: Optional[Callable[[int, BaseException], None]] = None,
             breaker: Optional[CircuitBreaker] = None,
             deadline_s: Optional[float] = None):
        """Run ``fn(*args)``, retrying transient failures.  ``on_retry``
        (attempt number, error) fires before each re-attempt — the
        executor's retry accounting hooks in there.  ``breaker`` /
        ``deadline_s`` override the policy's own when given."""
        breaker = breaker if breaker is not None else self.breaker
        budget = deadline_s if deadline_s is not None else self.deadline_s
        deadline = None if budget is None else self.clock() + budget
        delay = self.backoff_s
        attempts = max(1, self.max_attempts)
        for attempt in range(1, attempts + 1):
            if breaker is not None:
                breaker.before_call()      # CircuitOpenError: never retried
            try:
                result = fn(*args)
            except CircuitOpenError:
                raise                      # a nested breaker fast-failed
            except TransientStoreError as e:
                if breaker is not None:
                    breaker.record_failure()
                if attempt >= attempts:
                    raise
                if deadline is not None and \
                        self.clock() + delay >= deadline:
                    raise DeadlineError(
                        f"retry budget ({budget}s) exhausted after "
                        f"{attempt} attempt(s): {e}") from e
                if on_retry is not None:
                    on_retry(attempt, e)
                self.sleep(delay)
                delay = min(delay * self.multiplier, self.max_backoff_s)
            else:
                if breaker is not None:
                    breaker.record_success()
                if deadline is not None and self.clock() > deadline:
                    # the attempt itself blew the budget (hung store):
                    # the caller asked for a bounded wait, so a late
                    # success still reports the deadline breach — but the
                    # data is here, so return it (the *next* call against
                    # the still-sick store is the breaker's job).
                    return result
                return result


# ---------------------------------------------------------------------------
# the v2 protocol
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StoreCapabilities:
    """What a store can do natively — the negotiation surface clients use
    to plan fetches (everything still *works* without a capability; the
    protocol's default methods fall back to derived implementations)."""

    ranges: bool = False       # sub-block ranged reads without over-fetch
    batching: bool = False     # fetch_many is better than a serial loop
    concurrency: int = 1       # safe parallel fan-out hint for callers

    def snapshot(self) -> dict:
        return {"ranges": self.ranges, "batching": self.batching,
                "concurrency": self.concurrency}


class BackingStore:
    """Protocol + derived methods for the byte source behind the cache.

    Implementations provide ``fetch_range``; ``fetch_many`` and the
    legacy ``fetch_block`` derive from it (override when the backend can
    do better — e.g. one filesystem open per file, one S3 multi-range
    request).  Failures must be raised as :class:`StoreError` /
    :class:`TransientStoreError` so the client's retry and cancellation
    paths can tell them apart.
    """

    def capabilities(self) -> StoreCapabilities:
        return StoreCapabilities()

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        """Bytes ``[offset, offset+length)`` of ``path`` (block-relative
        when ``path`` is a block path) as a uint8 array."""
        raise NotImplementedError

    def fetch_many(self, requests: Sequence[RangeRequest]
                   ) -> List[np.ndarray]:
        """Serve a batch of range requests, results in request order."""
        return [self.fetch_range(p, o, n) for p, o, n in requests]

    def fetch_block(self, path: PathT, size: int) -> np.ndarray:
        """Legacy v1 surface: the first ``size`` bytes of a block."""
        return self.fetch_range(path, 0, size)


class LegacyStoreAdapter(BackingStore):
    """v2 facade over a one-method ``fetch_block(path, size)`` store.

    Ranged reads over-fetch the block prefix and slice — exactly what
    every caller did before this API existed — so third-party stores
    written against the PR-3 protocol keep working unchanged (they just
    don't get the ranged/batched savings, and ``capabilities()`` says so).
    """

    def __init__(self, store) -> None:
        self.inner = store

    def capabilities(self) -> StoreCapabilities:
        return StoreCapabilities(ranges=False, batching=False, concurrency=1)

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        data = self.inner.fetch_block(path, offset + length)
        return np.asarray(data[offset:offset + length], dtype=np.uint8)

    def fetch_block(self, path: PathT, size: int) -> np.ndarray:
        return self.inner.fetch_block(path, size)

    def __getattr__(self, name):
        # StoreMeta passthrough: the wrapped store often doubles as the
        # kernel's metadata source (RemoteStore does).
        return getattr(self.inner, name)


def as_backing_store(store) -> Optional[BackingStore]:
    """Normalize anything byte-serving onto the v2 protocol.

    Detection is *type-level* (``__getattr__`` delegation on a wrapper
    must not masquerade as native v2 support — the wrapper's own gating
    or counting would be silently bypassed).  Returns ``None`` for
    metadata-only objects so callers keep the "no backing store"
    behavior.
    """
    if store is None:
        return None
    if callable(getattr(type(store), "fetch_range", None)):
        return store
    if callable(getattr(type(store), "fetch_block", None)):
        return LegacyStoreAdapter(store)
    return None


# ---------------------------------------------------------------------------
# shared StoreMeta implementation
# ---------------------------------------------------------------------------

class StoreMetaIndex:
    """Dict-backed ``core.meta.StoreMeta``: ordered listings, file sizes,
    subtree byte totals, block-key enumeration and the flattened global
    block index (dataset = top-level path component).  The simulated
    object store, the local-filesystem walker and the in-memory test
    store all serve metadata from this one implementation."""

    block_size: int = 4 * MB

    def __init__(self) -> None:
        self._files: Dict[PathT, int] = {}           # path -> size, walk order
        self._dirs: Dict[PathT, List[str]] = {}
        self._index: Dict[Tuple[PathT, str], int] = {}
        self._subtree_bytes: Dict[PathT, int] = {}
        self._flat_index: Dict[PathT, Tuple[int, int]] = {}

    # -- registration --------------------------------------------------------
    def _register_file(self, path: PathT, size: int) -> None:
        self._files[path] = size

    def _register_dir(self, parent: PathT, names: List[str]) -> None:
        self._dirs[parent] = names
        for i, n in enumerate(names):
            self._index[(parent, n)] = i

    def _invalidate_derived(self) -> None:
        self._subtree_bytes.clear()
        self._flat_index.clear()

    # -- StoreMeta protocol --------------------------------------------------
    def listing(self, path: PathT) -> List[str]:
        return self._dirs.get(path, [])

    def listing_size(self, path: PathT) -> int:
        return len(self._dirs.get(path, ()))

    def child_index(self, path: PathT, name: str) -> int:
        return self._index.get((path, name), 0)

    def is_file(self, path: PathT) -> bool:
        return path in self._files

    def file_size(self, path: PathT) -> int:
        return self._files.get(path, 0)

    def subtree_bytes(self, path: PathT) -> int:
        cached = self._subtree_bytes.get(path)
        if cached is not None:
            return cached
        total = 0
        for fpath, size in self._files.items():
            if fpath[:len(path)] == path:
                total += size
        self._subtree_bytes[path] = total
        return total

    def iter_block_keys(self, path: PathT,
                        block_size: Optional[int] = None
                        ) -> Iterator[Tuple[PathT, int]]:
        bs = block_size or self.block_size
        for fpath, size in self._files.items():
            if fpath[:len(path)] != path:
                continue
            nblocks = max(1, -(-size // bs))
            for b in range(nblocks):
                yield block_key(fpath, b), min(bs, size - b * bs)

    def flat_block_index(self, file_path: PathT, block: int,
                         block_size: Optional[int] = None) -> Tuple[int, int]:
        """Global block ordinal within the file's top-level component
        (walk order) — the flattened index space of §3.2."""
        if not self._flat_index:
            self._build_flat_index(block_size or self.block_size)
        start, total = self._flat_index.get(file_path, (0, 1))
        return start + block, total

    def _build_flat_index(self, block_size: int) -> None:
        per_top_cursor: Dict[str, int] = {}
        starts: Dict[PathT, int] = {}
        for fpath, size in self._files.items():   # insertion = walk order
            top = fpath[0]
            cur = per_top_cursor.get(top, 0)
            starts[fpath] = cur
            per_top_cursor[top] = cur + max(1, -(-size // block_size))
        for fpath in starts:
            self._flat_index[fpath] = (starts[fpath],
                                       per_top_cursor[fpath[0]])

    # -- shared range resolution --------------------------------------------
    def _absolute_range(self, path: PathT, offset: int,
                        length: int) -> Tuple[PathT, int]:
        """(file_path, absolute offset) for a file-or-block path."""
        file_path, b = split_block_key(path)
        if b is not None:
            offset += b * self.block_size
        return file_path, offset


# ---------------------------------------------------------------------------
# in-memory store (tests / fixtures)
# ---------------------------------------------------------------------------

class MemStore(StoreMetaIndex, BackingStore):
    """In-memory store: real bytes, real metadata, zero I/O — the test
    double for the full v2 + StoreMeta contract (``mem://``)."""

    def __init__(self, block_size: int = 4 * MB) -> None:
        super().__init__()
        self.block_size = block_size
        self._data: Dict[PathT, np.ndarray] = {}

    def add_file(self, path: PathT, data: bytes) -> None:
        path = tuple(path)
        if path not in self._files:
            for depth in range(len(path)):
                parent, name = path[:depth], path[depth]
                names = self._dirs.setdefault(parent, [])
                if (parent, name) not in self._index:
                    self._index[(parent, name)] = len(names)
                    names.append(name)
        self._register_file(path, len(data))
        self._data[path] = np.frombuffer(bytes(data), dtype=np.uint8).copy()
        self._invalidate_derived()

    def capabilities(self) -> StoreCapabilities:
        return StoreCapabilities(ranges=True, batching=True, concurrency=8)

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        file_path, abs_off = self._absolute_range(path, offset, length)
        data = self._data.get(file_path)
        if data is None:
            raise StoreError(f"mem://: no such file {'/'.join(file_path)}")
        end = abs_off + length
        if abs_off < 0 or end > len(data):
            raise StoreError(
                f"mem://: range [{abs_off}, {end}) outside "
                f"{'/'.join(file_path)} ({len(data)} bytes)")
        view = data[abs_off:end]
        # zero-copy, but never a *writable* window into the store: a
        # caller mutating ReadResult.data must not corrupt the backend
        view.flags.writeable = False
        return view


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

class FaultyStore(BackingStore):
    """Fault-injecting wrapper over any store (``faulty+<scheme>://``).

    Every fetch request independently draws from a seeded RNG: with
    ``permanent_rate`` it raises :class:`StoreError`, with ``fail_rate``
    a :class:`TransientStoreError`, otherwise it (optionally) sleeps an
    exponential latency jitter of mean ``jitter_s`` and delegates.
    Metadata calls pass through untouched, so the wrapped store still
    backs the kernel.  Injection counters (``injected_transient`` /
    ``injected_permanent``) make retry-accounting tests exact.

    Chaos modes for the fault harness:

    * ``hang_rate`` / ``hang_s`` — with probability ``hang_rate`` a fetch
      stalls for ``hang_s`` before delegating: a *bounded* hang, so a
      deadline-less caller is slow, not stuck forever, and tests never
      truly wedge.  A caller with ``RetryPolicy.deadline_s < hang_s``
      observes the stall as a deadline breach.
    * ``slow_s`` — constant latency added to every fetch (a uniformly
      sick store rather than a lottery).
    * ``corrupt_rate`` — the fetch succeeds but the payload comes back
      bit-flipped (XOR 0xFF), for end-to-end checksum/validation paths.

    Counters: ``injected_hangs``, ``injected_corrupt``.
    """

    def __init__(self, inner, *, fail_rate: float = 0.0,
                 permanent_rate: float = 0.0, jitter_s: float = 0.0,
                 hang_rate: float = 0.0, hang_s: float = 0.0,
                 slow_s: float = 0.0, corrupt_rate: float = 0.0,
                 seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        backing = as_backing_store(inner)
        if backing is None:
            raise TypeError(
                f"FaultyStore needs a byte-serving store, got {inner!r}")
        self.inner = inner            # metadata passthrough target
        self._backing = backing       # normalized fetch target
        self.fail_rate = fail_rate
        self.permanent_rate = permanent_rate
        self.jitter_s = jitter_s
        self.hang_rate = hang_rate
        self.hang_s = hang_s
        self.slow_s = slow_s
        self.corrupt_rate = corrupt_rate
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()   # Generator + counters: not MT-safe
        self._sleep = sleep
        self.injected_transient = 0
        self.injected_permanent = 0
        self.injected_hangs = 0
        self.injected_corrupt = 0

    def capabilities(self) -> StoreCapabilities:
        return self._backing.capabilities()

    def __getstate__(self):
        # picklable for spawn/forkserver shard workers (the lock is
        # process-local state; each process draws from its own copy of
        # the seeded RNG stream)
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def _roll(self, what: str) -> bool:
        # concurrent shard workers + readers all fetch through here —
        # draw and count under one lock so the injection counters stay
        # exact (the retry-accounting tests equate them to stats.retries).
        # Returns whether this fetch's payload should come back corrupt.
        with self._lock:
            r = self._rng.random()
            jitter = (float(self._rng.exponential(self.jitter_s))
                      if self.jitter_s > 0.0 else 0.0)
            hang = (self.hang_rate > 0.0 and self.hang_s > 0.0
                    and self._rng.random() < self.hang_rate)
            corrupt = (self.corrupt_rate > 0.0
                       and self._rng.random() < self.corrupt_rate)
            if hang:
                self.injected_hangs += 1
            if r < self.permanent_rate:
                self.injected_permanent += 1
                raise StoreError(f"injected permanent failure on {what}")
            if r < self.permanent_rate + self.fail_rate:
                self.injected_transient += 1
                raise TransientStoreError(
                    f"injected transient failure on {what}")
            if corrupt:
                self.injected_corrupt += 1
        stall = self.slow_s + jitter + (self.hang_s if hang else 0.0)
        if stall:
            self._sleep(stall)
        return corrupt

    @staticmethod
    def _mangle(data: np.ndarray) -> np.ndarray:
        # bit-flip every byte: unambiguous corruption that any checksum
        # (or byte-equality assertion) catches, with the right length
        return np.bitwise_xor(np.asarray(data, dtype=np.uint8), 0xFF)

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        corrupt = self._roll("/".join(path))
        data = self._backing.fetch_range(path, offset, length)
        return self._mangle(data) if corrupt else data

    def fetch_many(self, requests: Sequence[RangeRequest]
                   ) -> List[np.ndarray]:
        # inject per request: one bad range fails the batch, like a real
        # multi-range response with a failed part
        return [self.fetch_range(p, o, n) for p, o, n in requests]

    def fetch_block(self, path: PathT, size: int) -> np.ndarray:
        return self.fetch_range(path, 0, size)

    def __getattr__(self, name):
        return getattr(self.inner, name)


# ---------------------------------------------------------------------------
# URI scheme registry
# ---------------------------------------------------------------------------

_SCHEMES: Dict[str, Callable] = {}
_BUILTINS_LOADED = False


def register_scheme(scheme: str, factory: Callable) -> None:
    """Register ``factory(url, **params) -> store`` for ``scheme://``
    URIs.  ``url`` is the ``urlsplit`` result; ``params`` are the query
    items with numeric/bool coercion applied."""
    _SCHEMES[scheme] = factory


def registered_schemes() -> List[str]:
    _ensure_builtin_schemes()
    return sorted(_SCHEMES)


def _ensure_builtin_schemes() -> None:
    """Built-in backends register at import; imported lazily so
    ``storage.api`` stays importable on its own."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from . import local_fs, object_store  # noqa: F401  (register on import)
    from . import s3  # noqa: F401  (s3:// + mock-s3://)
    # cache:// — daemon endpoint addresses (repro.daemon), resolving to
    # a DaemonAddress handle rather than a byte store; open_cache turns
    # one into a connected RemoteCacheClient
    from ..daemon import uri as _daemon_uri  # noqa: F401


def _coerce(value: str):
    low = value.lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(value)
        except ValueError:
            continue
    return unquote(value)


def open_store(uri: str, **overrides):
    """The storage front door: ``open_store("scheme://...") -> store``.

    Built-in schemes:

    * ``sim://default`` — the simulated object store (``RemoteStore``);
      query params feed the transfer model (``latency_s``,
      ``bandwidth_Bps``).
    * ``file:///abs/dir`` — :class:`~repro.storage.local_fs.LocalFSStore`
      over a real directory tree (query: ``block_size``).
    * ``mem://`` — empty :class:`MemStore` (query: ``block_size``).
    * ``cache:///run/igt.sock`` / ``cache://host:port`` — a running
      cache daemon's endpoint (``repro.daemon``).  Resolves to a
      ``DaemonAddress`` handle, not a byte store; hand it (or the URI)
      to ``open_cache`` to connect a thin remote client.
    * ``s3://host:port/bucket`` — ranged object store over HTTP
      (``repro.storage.s3.S3Store``; query: ``block_size``,
      ``timeout_s``); ``mock-s3://<name>/<bucket>?dirs=D&files=N&
      file_kb=K&seed=S`` — the same store pointed at a deterministic
      in-process loopback server built from the URI spec.
    * ``tiered+<scheme>://...`` — the inner scheme's store wrapped in a
      :class:`~repro.storage.tiers.TieredStore` (RAM tier +
      spill-to-disk tier with pattern-aware placement); query params
      configure the tiers (``ram_mb``/``ram_bytes``,
      ``disk_mb``/``disk_bytes``, ``spill_dir``, ``mode``,
      ``target_hit_rate``, ``hit_window``).
    * ``faulty+<scheme>://...`` — the inner scheme's store wrapped in a
      :class:`FaultyStore`; query params configure the injector
      (``fail_rate``, ``permanent_rate``, ``jitter_s``, ``hang_rate``,
      ``hang_s``, ``slow_s``, ``corrupt_rate``, ``seed``).

    Wrapper schemes compose left-to-right (``faulty+tiered+sim://...``
    injects faults *above* the tiers; ``tiered+faulty+mem://...`` hides
    injected faults behind tier hits).  The composed URI is stamped on
    the outermost wrapper, so ``store_spec`` reconstructs the whole
    stack — injector, tiers and inner store — in a respawned worker.

    ``overrides`` win over query params.  Unknown schemes raise
    ``ValueError`` listing what is registered.
    """
    _ensure_builtin_schemes()
    url = urlsplit(uri)
    if not url.scheme:
        raise ValueError(f"store URI {uri!r} has no scheme "
                         f"(expected one of {registered_schemes()})")
    params = {k: _coerce(v) for k, v in parse_qsl(url.query)}
    params.update(overrides)
    if url.scheme.startswith("faulty+"):
        inner_uri = urlunsplit((url.scheme[len("faulty+"):], url.netloc,
                                url.path, "", ""))
        fault_keys = ("fail_rate", "permanent_rate", "jitter_s",
                      "hang_rate", "hang_s", "slow_s", "corrupt_rate",
                      "seed", "sleep")
        fault_kw = {k: params.pop(k) for k in fault_keys if k in params}
        inner = open_store(inner_uri, **params)
        wrapper = FaultyStore(inner, **fault_kw)
        # stamp the *composed* URI on the wrapper: without it,
        # ``store_spec`` would read ``uri``/``reopen_by_uri`` through
        # ``__getattr__`` delegation from the inner store and a respawned
        # worker would silently reconstruct the stack *without* fault
        # injection (the registry double-wrap bug)
        _record_uri(wrapper, uri)
        return wrapper
    if url.scheme.startswith("tiered+"):
        from .tiers import TIER_KEYS, TieredStore
        inner_uri = urlunsplit((url.scheme[len("tiered+"):], url.netloc,
                                url.path, "", ""))
        tier_kw = {k: params.pop(k) for k in TIER_KEYS if k in params}
        inner = open_store(inner_uri, **params)
        wrapper = TieredStore(inner, **tier_kw)
        _record_uri(wrapper, uri)
        return wrapper
    factory = _SCHEMES.get(url.scheme)
    if factory is None:
        raise ValueError(f"unknown store scheme {url.scheme!r}; registered: "
                         f"{registered_schemes()}")
    store = factory(url, **params)
    _record_uri(store, uri)
    return store


def _record_uri(store, uri: str) -> None:
    """Best-effort provenance stamp: a URI-opened store remembers its URI
    so it can be *re-opened in another process* (``store_spec``).  Stores
    with ``__slots__``/immutable instances simply stay unstamped."""
    try:
        store.uri = uri
    except (AttributeError, TypeError):  # pragma: no cover - exotic stores
        pass


def store_spec(store):
    """Picklable recipe to reconstruct ``store`` in a worker process.

    The multi-process shard driver gives every worker its own store
    instance (per-process file handles / connections, per-process
    capability negotiation) instead of sharing one across the fork:

    * a URI string travels as ``("uri", uri)`` — the worker calls
      ``open_store`` afresh, re-negotiating capabilities against its own
      instance; a store *object* does so only when its class opts in
      with ``reopen_by_uri = True`` (``LocalFSStore``: the whole state
      derives from the walked directory, so a re-open is faithful —
      unlike e.g. a ``RemoteStore`` whose datasets were registered after
      opening, which must travel as the object itself);
    * anything else travels as ``("object", store)`` — verbatim under a
      ``fork`` start method (the child inherits the parent's heap), by
      pickle under ``spawn`` (the store must then be picklable).
    """
    if isinstance(store, str):
        return ("uri", store)
    uri = getattr(store, "uri", None)
    if isinstance(uri, str) and getattr(store, "reopen_by_uri", False):
        return ("uri", uri)
    return ("object", store)


def resolve_store_spec(spec, **overrides):
    """Worker-side inverse of :func:`store_spec`."""
    kind, payload = spec
    if kind == "uri":
        return open_store(payload, **overrides)
    if kind == "object":
        return payload
    raise ValueError(f"unknown store spec kind {kind!r}")


def _mem_factory(url, **params):
    return MemStore(**params)


register_scheme("mem", _mem_factory)


# ---------------------------------------------------------------------------
# deterministic content synthesis (shared with the simulated store)
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15


def path_seed(path: PathT) -> int:
    """64-bit content seed for a file path (blake2b of the joined path)."""
    return int.from_bytes(
        hashlib.blake2b("/".join(path).encode(),
                        digest_size=8).digest(), "little")


def synth_range(seed: int, offset: int, length: int) -> np.ndarray:
    """Deterministic pseudo-random bytes ``[offset, offset+length)`` of
    the infinite stream keyed by ``seed`` (vectorized splitmix64 over the
    64-bit word counter).  Counter-based, so any sub-range can be
    synthesized directly — ``synth_range(s, o, n)`` equals
    ``synth_range(s, 0, o+n)[o:]`` without generating the prefix."""
    if length <= 0:
        return np.empty(0, dtype=np.uint8)
    w0, w1 = offset >> 3, (offset + length - 1) >> 3
    x = (np.arange(w0, w1 + 1, dtype=np.uint64)
         + np.uint64(seed & _MASK64)) * np.uint64(_GOLDEN)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    if not x.dtype.isnative or x.dtype.byteorder == ">":  # pragma: no cover
        x = x.astype("<u8")
    raw = x.view(np.uint8)
    start = offset - (w0 << 3)
    return raw[start:start + length]

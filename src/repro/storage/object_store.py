"""The remote object store + transfer cost model.

Implements the ``repro.core.meta.StoreMeta`` protocol for IGTCache and a
shared-link transfer model calibrated to the paper's testbed (§5.1): ~150 ms
request latency, ~1 Gbps aggregate remote bandwidth.  The link is a single
FIFO resource — concurrent jobs and background prefetches contend for it,
which is exactly the effect the hierarchical-prefetch experiment (Fig. 7/9)
depends on.

Content is synthesized deterministically from the block key (for the real
training pipeline); the simulator only uses sizes/latencies.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core.types import MB, PathT
from .datasets import DatasetSpec, FileEntry


@dataclass
class TransferModel:
    """Shared remote link: latency + bandwidth, FIFO service."""

    latency_s: float = 0.150          # paper: ~150 ms to S3
    bandwidth_Bps: float = 125e6      # paper: ~1 Gbps
    # local cache service (DRAM/SSD over NFS) — effectively free vs remote
    local_latency_s: float = 0.0005
    local_bandwidth_Bps: float = 6e9

    def remote_time(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps

    def local_time(self, nbytes: int) -> float:
        return self.local_latency_s + nbytes / self.local_bandwidth_Bps


class RemoteStore:
    """Dataset registry + metadata resolution + content synthesis."""

    def __init__(self, transfer: Optional[TransferModel] = None) -> None:
        self.datasets: Dict[str, DatasetSpec] = {}
        self.transfer = transfer or TransferModel()
        self._files: Dict[PathT, FileEntry] = {}
        self._dirs: Dict[PathT, List[str]] = {}
        self._index: Dict[Tuple[PathT, str], int] = {}
        self._subtree_bytes: Dict[PathT, int] = {}
        self._flat_index: Dict[PathT, Tuple[int, int]] = {}

    # -- registry -------------------------------------------------------------
    def add(self, spec: DatasetSpec) -> None:
        self.datasets[spec.name] = spec
        for f in spec.files:
            self._files[f.path] = f
        for parent, names in spec.dirs.items():
            self._dirs[parent] = names
            for i, n in enumerate(names):
                self._index[(parent, n)] = i
        # root listing across datasets
        roots = sorted(self.datasets.keys())
        self._dirs[()] = roots
        for i, n in enumerate(roots):
            self._index[((), n)] = i
        self._subtree_bytes.clear()
        self._flat_index.clear()

    # -- StoreMeta protocol -----------------------------------------------------
    def listing(self, path: PathT) -> List[str]:
        return self._dirs.get(path, [])

    def listing_size(self, path: PathT) -> int:
        return len(self._dirs.get(path, ()))

    def child_index(self, path: PathT, name: str) -> int:
        return self._index.get((path, name), 0)

    def is_file(self, path: PathT) -> bool:
        return path in self._files

    def file_size(self, path: PathT) -> int:
        f = self._files.get(path)
        return f.size if f is not None else 0

    def subtree_bytes(self, path: PathT) -> int:
        cached = self._subtree_bytes.get(path)
        if cached is not None:
            return cached
        total = 0
        for fpath, f in self._files.items():
            if fpath[:len(path)] == path:
                total += f.size
        self._subtree_bytes[path] = total
        return total

    def iter_block_keys(self, path: PathT,
                        block_size: int = 4 * MB) -> Iterator[Tuple[PathT, int]]:
        for fpath, f in self._files.items():
            if fpath[:len(path)] != path:
                continue
            nblocks = max(1, -(-f.size // block_size))
            for b in range(nblocks):
                yield fpath + (f"#{b}",), min(block_size, f.size - b * block_size)

    def flat_block_index(self, file_path: PathT, block: int,
                         block_size: int = 4 * MB) -> Tuple[int, int]:
        """Global block ordinal within the file's dataset (traversal order)."""
        if not self._flat_index:
            self._build_flat_index(block_size)
        start, total = self._flat_index.get(file_path, (0, 1))
        return start + block, total

    def _build_flat_index(self, block_size: int) -> None:
        per_ds_cursor: Dict[str, int] = {}
        starts: Dict[PathT, int] = {}
        for fpath, f in self._files.items():  # insertion = traversal order
            ds = fpath[0]
            cur = per_ds_cursor.get(ds, 0)
            starts[fpath] = cur
            per_ds_cursor[ds] = cur + max(1, -(-f.size // block_size))
        for fpath in starts:
            self._flat_index[fpath] = (starts[fpath], per_ds_cursor[fpath[0]])

    # -- content (for the real training pipeline) --------------------------------
    def fetch_block(self, block_path: PathT, size: int) -> np.ndarray:
        """Deterministic synthetic bytes for a block (seeded by its key)."""
        seed = int.from_bytes(
            hashlib.blake2b("/".join(block_path).encode(),
                            digest_size=8).digest(), "little")
        rng = np.random.default_rng(seed)
        return rng.integers(0, 256, size=size, dtype=np.uint8)

"""The simulated remote object store + transfer cost model (``sim://``).

Implements the ``repro.core.meta.StoreMeta`` protocol for IGTCache (via
the shared :class:`~repro.storage.api.StoreMetaIndex`) and a shared-link
transfer model calibrated to the paper's testbed (§5.1): ~150 ms request
latency, ~1 Gbps aggregate remote bandwidth.  The link is a single FIFO
resource — concurrent jobs and background prefetches contend for it,
which is exactly the effect the hierarchical-prefetch experiment
(Fig. 7/9) depends on.

Content is synthesized deterministically from the block key (for the real
training pipeline); the simulator only uses sizes/latencies.  Synthesis
is the v2 ranged path: a per-file blake2b seed is hashed **once** and
cached (the old code rebuilt a digest and a ``default_rng`` per block on
the hot demand path), and bytes come from a counter-based generator
(``api.synth_range``) so any sub-range materializes directly —
``fetch_range(p, o, n) == fetch_block(p, o+n)[o:]`` without generating
the prefix.  ``benchmarks/store_micro.py`` asserts synthesis stays far
under the simulated transfer time, so the sim's cost model, not content
generation, dominates any measured run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from ..core.types import PathT
from .api import (BackingStore, StoreCapabilities, StoreMetaIndex,
                  path_seed, register_scheme, synth_range)
from .datasets import DatasetSpec


@dataclass
class TransferModel:
    """Shared remote link: latency + bandwidth, FIFO service."""

    latency_s: float = 0.150          # paper: ~150 ms to S3
    bandwidth_Bps: float = 125e6      # paper: ~1 Gbps
    # local cache service (DRAM/SSD over NFS) — effectively free vs remote
    local_latency_s: float = 0.0005
    local_bandwidth_Bps: float = 6e9

    def remote_time(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps

    def local_time(self, nbytes: int) -> float:
        return self.local_latency_s + nbytes / self.local_bandwidth_Bps


class RemoteStore(StoreMetaIndex, BackingStore):
    """Dataset registry + metadata resolution + content synthesis."""

    def __init__(self, transfer: Optional[TransferModel] = None) -> None:
        super().__init__()
        self.datasets: Dict[str, DatasetSpec] = {}
        self.transfer = transfer or TransferModel()
        # hoisted digest state: one blake2b per *file*, reused by every
        # block-level fetch of that file (the demand hot path)
        self._seed_cache: Dict[PathT, int] = {}

    # -- registry -------------------------------------------------------------
    def add(self, spec: DatasetSpec) -> None:
        self.datasets[spec.name] = spec
        for f in spec.files:
            self._register_file(f.path, f.size)
        for parent, names in spec.dirs.items():
            self._register_dir(parent, names)
        # root listing across datasets
        self._register_dir((), sorted(self.datasets.keys()))
        self._invalidate_derived()

    # -- content (BackingStore v2) --------------------------------------------
    def capabilities(self) -> StoreCapabilities:
        return StoreCapabilities(ranges=True, batching=False, concurrency=8)

    def _file_seed(self, file_path: PathT) -> int:
        seed = self._seed_cache.get(file_path)
        if seed is None:
            seed = path_seed(file_path)
            self._seed_cache[file_path] = seed
        return seed

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        """Deterministic synthetic bytes for any sub-range — generated
        directly, no prefix over-synthesis.  Each file is one content
        stream seeded by its path; a block path is resolved to the
        absolute file offset (``StoreMetaIndex._absolute_range``), so
        file-path and block-path addressing return identical bytes —
        the same coherence contract the real stores keep."""
        file_path, abs_off = self._absolute_range(path, offset, length)
        return synth_range(self._file_seed(file_path), abs_off, length)

    def fetch_block(self, path: PathT, size: int) -> np.ndarray:
        """Legacy v1 surface: first ``size`` bytes of the block at
        ``path`` (kept verbatim — third-party callers and the token
        pipeline address content this way)."""
        return self.fetch_range(path, 0, size)


# The class is the repo's object-store *simulator*; the alias names it as
# such where the distinction matters (the ``faulty+sim://`` wrapper docs).
ObjectStoreSim = RemoteStore


def _sim_factory(url, **params):
    transfer_keys = ("latency_s", "bandwidth_Bps", "local_latency_s",
                     "local_bandwidth_Bps")
    transfer_kw = {k: params.pop(k) for k in transfer_keys if k in params}
    if params:
        raise ValueError(f"sim://: unknown parameters {sorted(params)}")
    return RemoteStore(TransferModel(**transfer_kw))


register_scheme("sim", _sim_factory)

"""The storage layer: URI-addressed stores behind one v2 protocol.

``open_store(uri)`` is the front door — ``sim://`` (simulated S3-style
object store), ``file:///dir`` (real directory tree), ``mem://``
(in-memory test store), ``s3://host:port/bucket`` (ranged HTTP object
store, with ``mock-s3://`` as its deterministic in-process double),
``tiered+<scheme>://`` (RAM + spill-to-disk tiers with pattern-aware
placement), ``faulty+<scheme>://`` (seeded fault injection).
All of them satisfy ``core.meta.StoreMeta`` for the kernel and the
ranged/batched ``BackingStore`` v2 protocol for the client; legacy
one-method ``fetch_block`` stores keep working through
``as_backing_store``.  See docs/API.md "Storage API" and "Tiered
storage".
"""
from .api import (BackingStore, CircuitBreaker, CircuitOpenError,
                  DeadlineError, FaultyStore, LegacyStoreAdapter, MemStore,
                  RetryPolicy, StoreCapabilities, StoreError, StoreMetaIndex,
                  TransientStoreError, as_backing_store, open_store,
                  register_scheme, registered_schemes)
from .datasets import DatasetSpec, make_dataset
from .local_fs import LocalFSStore
from .object_store import ObjectStoreSim, RemoteStore, TransferModel
from .s3 import MockS3Server, S3Store
from .tiers import DiskTier, TieredStore, TierStats

__all__ = [
    "BackingStore", "CircuitBreaker", "CircuitOpenError", "DatasetSpec",
    "DeadlineError", "DiskTier", "FaultyStore", "LegacyStoreAdapter",
    "LocalFSStore", "MemStore", "MockS3Server", "ObjectStoreSim",
    "RemoteStore", "RetryPolicy", "S3Store", "StoreCapabilities",
    "StoreError", "StoreMetaIndex", "TieredStore", "TierStats",
    "TransferModel", "TransientStoreError", "as_backing_store",
    "make_dataset", "open_store", "register_scheme", "registered_schemes",
]

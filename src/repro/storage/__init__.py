"""Simulated disaggregated remote storage (S3-style) for IGTCache."""
from .datasets import DatasetSpec, make_dataset
from .object_store import RemoteStore, TransferModel

__all__ = ["DatasetSpec", "RemoteStore", "TransferModel", "make_dataset"]

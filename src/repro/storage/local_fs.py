"""LocalFSStore: the unified cache over a real directory tree (``file://``).

This is the adapter that turns the repo from simulator-only into a system
you can point at actual data: one walk of a directory snapshots its
geometry into the ``StoreMeta`` protocol the kernel observes (listings in
sorted order — the stable traversal-index space §3.2 needs), and the v2
``BackingStore`` surface serves real bytes with true ranged reads
(``seek`` + exact-length ``read``) and file-grouped batching (one open
per file per ``fetch_many`` call).

The snapshot is deliberate: datasets are immutable for the lifetime of a
run (the same assumption ``core.meta.LevelCache`` memoizes on).  Call
:meth:`refresh` — and ``engine.invalidate_meta_cache()`` — if the tree
changes mid-run.
"""
from __future__ import annotations

import os
from typing import List, Sequence

import numpy as np

from ..core.types import MB, PathT
from .api import (BackingStore, RangeRequest, StoreCapabilities, StoreError,
                  StoreMetaIndex, TransientStoreError, register_scheme)

__all__ = ["LocalFSStore"]


class LocalFSStore(StoreMetaIndex, BackingStore):
    """Directory-tree store: sorted-listing metadata snapshot + ranged
    reads of the underlying files."""

    # the whole store state derives from the walked directory, so a
    # worker process can faithfully reconstruct it from the URI alone
    # (storage.api.store_spec → per-process re-open + re-negotiation)
    reopen_by_uri = True

    def __init__(self, root: str, block_size: int = 4 * MB) -> None:
        super().__init__()
        self.root = os.path.realpath(root)
        self.block_size = block_size
        if not os.path.isdir(self.root):
            raise StoreError(f"file://: not a directory: {self.root}")
        self.refresh()

    # -- snapshot walk -------------------------------------------------------
    def refresh(self) -> None:
        """(Re)walk the tree.  Listings hold sorted child names (dirs and
        files interleaved, as ``readdir`` order would be after sort), so
        child indices are stable across runs and processes."""
        self._files.clear()
        self._dirs.clear()
        self._index.clear()
        self._invalidate_derived()
        self._walk(())

    def _walk(self, rel: PathT) -> None:
        names: List[str] = []
        subdirs: List[str] = []
        files: List[tuple] = []
        with os.scandir(self._fs_path(rel)) as it:
            for entry in sorted(it, key=lambda e: e.name):
                if entry.is_dir(follow_symlinks=False):
                    names.append(entry.name)
                    subdirs.append(entry.name)
                elif entry.is_file(follow_symlinks=False):
                    names.append(entry.name)
                    files.append((entry.name, entry.stat().st_size))
        self._register_dir(rel, names)
        for name, size in files:
            self._register_file(rel + (name,), size)
        for name in subdirs:
            self._walk(rel + (name,))

    # -- path resolution -----------------------------------------------------
    def _fs_path(self, rel: PathT) -> str:
        for comp in rel:
            if not comp or comp in (".", "..") or os.sep in comp:
                raise StoreError(f"file://: invalid path component {comp!r}")
        return os.path.join(self.root, *rel)

    # -- BackingStore v2 -----------------------------------------------------
    def capabilities(self) -> StoreCapabilities:
        return StoreCapabilities(ranges=True, batching=True, concurrency=4)

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        file_path, abs_off = self._absolute_range(path, offset, length)
        return self._read(file_path, abs_off, length)

    def fetch_many(self, requests: Sequence[RangeRequest]
                   ) -> List[np.ndarray]:
        """File-grouped batch: requests touching the same file share one
        open file descriptor (results stay in request order)."""
        resolved = [self._absolute_range(p, o, n) + (n,)
                    for p, o, n in requests]
        out: List[np.ndarray] = [None] * len(resolved)  # type: ignore
        by_file: dict = {}
        for i, (fpath, off, length) in enumerate(resolved):
            by_file.setdefault(fpath, []).append((i, off, length))
        for fpath, group in by_file.items():
            with self._open(fpath) as f:
                for i, off, length in group:
                    out[i] = self._read_fd(f, fpath, off, length)
        return out

    # -- I/O helpers ---------------------------------------------------------
    def _open(self, file_path: PathT):
        fs = self._fs_path(file_path)
        try:
            return open(fs, "rb")
        except FileNotFoundError as e:
            raise StoreError(f"file://: no such file: {fs}") from e
        except OSError as e:
            raise TransientStoreError(f"file://: open failed: {fs}: {e}") \
                from e

    def _read(self, file_path: PathT, offset: int,
              length: int) -> np.ndarray:
        with self._open(file_path) as f:
            return self._read_fd(f, file_path, offset, length)

    def _read_fd(self, f, file_path: PathT, offset: int,
                 length: int) -> np.ndarray:
        if length <= 0:
            return np.empty(0, dtype=np.uint8)
        try:
            f.seek(offset)
            data = f.read(length)
        except OSError as e:
            raise TransientStoreError(
                f"file://: read failed: {'/'.join(file_path)}: {e}") from e
        if len(data) != length:
            # metadata snapshot and file disagree — the tree changed
            # underneath us; that is a caller problem, not a retry case
            raise StoreError(
                f"file://: short read on {'/'.join(file_path)}: wanted "
                f"[{offset}, {offset + length}), got {len(data)} bytes "
                f"(tree changed since the snapshot? call refresh())")
        return np.frombuffer(data, dtype=np.uint8)


def _file_factory(url, **params):
    # file:///abs/dir → ('', '/abs/dir'); file://rel/dir → ('rel', '/dir');
    # plain concatenation reassembles both (join would drop the netloc
    # in front of an absolute path)
    from urllib.parse import unquote
    return LocalFSStore(unquote(url.netloc + url.path), **params)


register_scheme("file", _file_factory)

"""Ranged object-store scheme: ``s3://`` over HTTP, plus ``mock-s3://``.

:class:`S3Store` is the real far side of the tiered hierarchy — a
BackingStore v2 implementation over ranged HTTP GETs (``Range:
bytes=a-b`` → 206 Partial Content), speaking to any endpoint that serves
the two-request protocol below.  It deliberately implements **no retry
of its own**: failures are raised as the typed taxonomy
(:class:`TransientStoreError` for 5xx / timeouts / connection drops,
:class:`StoreError` for 404/416) so the client's existing
``RetryPolicy`` / ``CircuitBreaker`` / deadline semantics apply
unchanged, exactly as they do for every other scheme.

Protocol (subset of S3's REST shape, enough for a read-only cache):

* ``GET /<bucket>?list`` → ``{"objects": [[key, size], ...]}`` — the
  bucket listing, loaded once at open to build the kernel's metadata
  tree (dataset top = bucket name, directories from key prefixes);
* ``GET /<bucket>/<key>`` with an optional ``Range`` header → the object
  bytes (206 for a satisfied range, 200 full-body fallback is sliced).

:class:`MockS3Server` is the deterministic in-process double for tier-1:
a ``ThreadingHTTPServer`` on ``127.0.0.1:<ephemeral>`` that serves the
same protocol from objects registered via :meth:`MockS3Server.add_object`
— explicit bytes, or synthesized on the fly from the shared
``path_seed``/``synth_range`` stream so a multi-GB bucket costs no RAM.
No test touches the network: the socket never leaves loopback.

The ``mock-s3://<name>/<bucket>?dirs=D&files=N&file_kb=K&seed=S`` scheme
goes one step further for the process driver: the URI *is* the bucket
spec.  A per-process registry maps (name, bucket, spec) to a running
mock server, so ``store_spec``/``resolve_store_spec`` round-trips — a
respawned shard worker re-opens the URI and gets its own identical
deterministic server (content is seeded by path, not by process).
"""
from __future__ import annotations

import http.client
import json
import socket
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Sequence, Tuple
from urllib.parse import quote, unquote, urlsplit

import numpy as np

from ..core.types import MB, PathT
from .api import (BackingStore, RangeRequest, StoreCapabilities, StoreError,
                  StoreMetaIndex, TransientStoreError, path_seed,
                  register_scheme, synth_range)

__all__ = ["MockS3Server", "S3Store", "mock_object_bytes"]


def _object_seed(bucket: str, key: str, seed: int = 0) -> int:
    """Content seed for one object: the shared path seed, shifted by the
    bucket-level ``seed`` knob so distinct mock buckets differ."""
    path = (bucket,) + tuple(key.split("/"))
    return (path_seed(path) ^ (seed * 0x9E3779B97F4A7C15)) & ((1 << 64) - 1)


def mock_object_bytes(bucket: str, key: str, offset: int, length: int,
                      seed: int = 0) -> np.ndarray:
    """Expected bytes of a synthesized mock-s3 object range — the oracle
    tests compare fetched payloads against."""
    return synth_range(_object_seed(bucket, key, seed), offset, length)


# ---------------------------------------------------------------------------
# the deterministic in-process server
# ---------------------------------------------------------------------------

class MockS3Server:
    """Loopback HTTP object server for tier-1 (no network, no deps).

    Objects are either explicit bytes or ``("synth", seed, size)`` specs
    materialized per request window — registering a large object costs
    nothing until someone reads it.
    """

    def __init__(self) -> None:
        # bucket -> key -> ("bytes", ndarray) | ("synth", seed, size)
        self._objects: Dict[str, Dict[str, tuple]] = {}
        self._lock = threading.Lock()
        server = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *a):   # keep test output clean
                pass

            def do_GET(self):
                server._handle(self)

        self.httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        self.httpd.daemon_threads = True
        self.host, self.port = self.httpd.server_address[:2]
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="mock-s3", daemon=True)
        self._thread.start()

    # -- registration --------------------------------------------------------
    def add_object(self, bucket: str, key: str,
                   data: Optional[bytes] = None,
                   size: Optional[int] = None, seed: int = 0) -> None:
        """Register one object: explicit ``data`` bytes, or a synthesized
        body of ``size`` bytes keyed by (bucket, key, seed)."""
        with self._lock:
            objs = self._objects.setdefault(bucket, {})
            if data is not None:
                arr = np.frombuffer(bytes(data), dtype=np.uint8).copy()
                objs[key] = ("bytes", arr)
            elif size is not None:
                objs[key] = ("synth", _object_seed(bucket, key, seed),
                             int(size))
            else:
                raise ValueError("add_object needs data= or size=")

    def populate(self, bucket: str, dirs: int = 2, files: int = 4,
                 file_kb: int = 64, seed: int = 0) -> None:
        """The canonical synthetic bucket layout the ``mock-s3://`` scheme
        builds from its URI spec: ``<dd>/<iii>.bin`` keys."""
        for d in range(int(dirs)):
            for i in range(int(files)):
                self.add_object(bucket, f"{d:02d}/{i:03d}.bin",
                                size=int(file_kb) * 1024, seed=int(seed))

    def uri(self, bucket: str) -> str:
        """An ``s3://`` URI addressing ``bucket`` on this server."""
        return f"s3://{self.host}:{self.port}/{quote(bucket)}"

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()

    # -- request handling ----------------------------------------------------
    def _object_size(self, entry: tuple) -> int:
        return len(entry[1]) if entry[0] == "bytes" else entry[2]

    def _object_range(self, entry: tuple, start: int, length: int) -> bytes:
        if entry[0] == "bytes":
            return entry[1][start:start + length].tobytes()
        return synth_range(entry[1], start, length).tobytes()

    def _handle(self, req: BaseHTTPRequestHandler) -> None:
        url = urlsplit(req.path)
        parts = [unquote(p) for p in url.path.split("/") if p]
        if not parts:
            return self._error(req, 404, "no bucket")
        bucket, key = parts[0], "/".join(parts[1:])
        with self._lock:
            objs = self._objects.get(bucket)
            entry = objs.get(key) if (objs and key) else None
        if objs is None:
            return self._error(req, 404, f"no such bucket {bucket!r}")
        if not key and url.query == "list":
            with self._lock:
                listing = {"objects": [[k, self._object_size(e)]
                                       for k, e in sorted(objs.items())]}
            body = json.dumps(listing).encode()
            req.send_response(200)
            req.send_header("Content-Type", "application/json")
            req.send_header("Content-Length", str(len(body)))
            req.end_headers()
            req.wfile.write(body)
            return
        if entry is None:
            return self._error(req, 404, f"no such key {key!r}")
        total = self._object_size(entry)
        rng = req.headers.get("Range")
        if rng:
            try:
                unit, _, spec = rng.partition("=")
                lo_s, _, hi_s = spec.partition("-")
                if unit.strip() != "bytes" or not lo_s:
                    raise ValueError(rng)
                lo = int(lo_s)
                hi = int(hi_s) if hi_s else total - 1
            except ValueError:
                return self._error(req, 400, f"bad range {rng!r}")
            if lo >= total or hi < lo:
                return self._error(req, 416, f"unsatisfiable range {rng!r}")
            hi = min(hi, total - 1)
            body = self._object_range(entry, lo, hi - lo + 1)
            req.send_response(206)
            req.send_header("Content-Range", f"bytes {lo}-{hi}/{total}")
        else:
            body = self._object_range(entry, 0, total)
            req.send_response(200)
        req.send_header("Content-Type", "application/octet-stream")
        req.send_header("Content-Length", str(len(body)))
        req.end_headers()
        req.wfile.write(body)

    def _error(self, req: BaseHTTPRequestHandler, code: int,
               msg: str) -> None:
        body = msg.encode()
        req.send_response(code)
        req.send_header("Content-Type", "text/plain")
        req.send_header("Content-Length", str(len(body)))
        req.end_headers()
        req.wfile.write(body)


# ---------------------------------------------------------------------------
# the client store
# ---------------------------------------------------------------------------

class S3Store(StoreMetaIndex, BackingStore):
    """Read-only ranged object store over HTTP (``s3://host:port/bucket``).

    Metadata comes from one listing request at open (the whole kernel
    tree derives from it), so a worker respawn re-opening the URI is
    faithful — the class opts into ``reopen_by_uri``.  Connections are
    per-thread keep-alive (``fetch_many`` and the threaded executor's
    workers each reuse their own socket); any transport error drops the
    thread's connection and surfaces as :class:`TransientStoreError` for
    the client's retry machinery.
    """

    reopen_by_uri = True

    def __init__(self, host: str, port: int, bucket: str,
                 block_size: int = 4 * MB, timeout_s: float = 10.0) -> None:
        super().__init__()
        self.host = host
        self.port = int(port)
        self.bucket = bucket
        self.block_size = int(block_size)
        self.timeout_s = float(timeout_s)
        self._local = threading.local()
        self._load_listing()

    # -- transport -----------------------------------------------------------
    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=self.timeout_s)
            self._local.conn = conn
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            try:
                conn.close()
            except OSError:  # pragma: no cover - close is best-effort
                pass
            self._local.conn = None

    def _request(self, target: str,
                 headers: Optional[dict] = None) -> Tuple[int, bytes, dict]:
        try:
            conn = self._conn()
            conn.request("GET", target, headers=headers or {})
            resp = conn.getresponse()
            body = resp.read()
            return resp.status, body, dict(resp.getheaders())
        except (http.client.HTTPException, socket.timeout,
                ConnectionError, OSError) as e:
            # a dropped/hung/refused connection is the canonical transient
            # failure: reset the keep-alive socket and let RetryPolicy
            # decide how many more times this store is worth trying
            self._drop_conn()
            raise TransientStoreError(
                f"s3://{self.host}:{self.port}: {type(e).__name__}: {e}"
            ) from e

    # -- metadata ------------------------------------------------------------
    def _load_listing(self) -> None:
        status, body, _ = self._request(f"/{quote(self.bucket)}?list")
        if status != 200:
            raise StoreError(
                f"s3://{self.host}:{self.port}/{self.bucket}: listing "
                f"failed with HTTP {status}")
        try:
            objects = json.loads(body.decode())["objects"]
        except (ValueError, KeyError) as e:
            raise StoreError(f"s3://: malformed listing: {e}") from e
        for key, size in objects:
            path = (self.bucket,) + tuple(str(key).split("/"))
            self._add_path(path, int(size))
        self._invalidate_derived()

    def _add_path(self, path: PathT, size: int) -> None:
        if path in self._files:
            return
        for depth in range(len(path)):
            parent, name = path[:depth], path[depth]
            names = self._dirs.setdefault(parent, [])
            if (parent, name) not in self._index:
                self._index[(parent, name)] = len(names)
                names.append(name)
        self._register_file(path, size)

    def _key_for(self, file_path: PathT) -> str:
        if not file_path or file_path[0] != self.bucket:
            raise StoreError(f"s3://: path {'/'.join(file_path)} outside "
                             f"bucket {self.bucket!r}")
        return "/".join(file_path[1:])

    # -- BackingStore v2 -----------------------------------------------------
    def capabilities(self) -> StoreCapabilities:
        return StoreCapabilities(ranges=True, batching=True, concurrency=4)

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        file_path, abs_off = self._absolute_range(path, offset, length)
        if not self.is_file(file_path):
            raise StoreError(f"s3://: no such object "
                             f"{'/'.join(file_path)}")
        size = self.file_size(file_path)
        end = abs_off + length
        if abs_off < 0 or end > size:
            raise StoreError(f"s3://: range [{abs_off}, {end}) outside "
                             f"{'/'.join(file_path)} ({size} bytes)")
        if length <= 0:
            return np.empty(0, dtype=np.uint8)
        key = self._key_for(file_path)
        target = f"/{quote(self.bucket)}/{quote(key)}"
        headers = {"Range": f"bytes={abs_off}-{end - 1}"}
        status, body, _ = self._request(target, headers)
        if status == 206:
            data = body
        elif status == 200:
            data = body[abs_off:end]     # server ignored the range header
        elif status in (404, 416):
            raise StoreError(f"s3://: HTTP {status} for {target}")
        elif 500 <= status < 600:
            raise TransientStoreError(f"s3://: HTTP {status} for {target}")
        else:
            raise StoreError(f"s3://: unexpected HTTP {status} for {target}")
        if len(data) != length:
            raise TransientStoreError(
                f"s3://: short read for {target}: wanted {length} bytes, "
                f"got {len(data)}")
        arr = np.frombuffer(data, dtype=np.uint8)
        arr.flags.writeable = False
        return arr

    def fetch_many(self, requests: Sequence[RangeRequest]
                   ) -> List[np.ndarray]:
        # one keep-alive connection serves the whole batch in order —
        # the "batching" capability is connection reuse, not pipelining
        return [self.fetch_range(p, o, n) for p, o, n in requests]

    # -- process-driver plumbing --------------------------------------------
    def __getstate__(self):
        state = self.__dict__.copy()
        del state["_local"]      # per-thread sockets never cross a fork
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._local = threading.local()


# ---------------------------------------------------------------------------
# scheme factories
# ---------------------------------------------------------------------------

def _s3_factory(url, **params):
    host = url.hostname or "127.0.0.1"
    port = url.port or 80
    parts = [unquote(p) for p in url.path.split("/") if p]
    if not parts:
        raise ValueError(f"s3:// URI needs a bucket path: {url!r}")
    return S3Store(host, port, parts[0], **params)


register_scheme("s3", _s3_factory)


# (name, bucket, frozen spec) -> MockS3Server; process-lifetime servers so
# the same mock-s3:// URI resolves to the same endpoint within a process,
# and a *respawned worker* re-creates an identical one from the URI alone
_MOCK_SERVERS: Dict[tuple, MockS3Server] = {}
_MOCK_LOCK = threading.Lock()


def _mock_s3_factory(url, **params):
    name = url.netloc or "default"
    parts = [unquote(p) for p in url.path.split("/") if p]
    bucket = parts[0] if parts else "data"
    spec = {k: params.pop(k) for k in ("dirs", "files", "file_kb", "seed")
            if k in params}
    reg_key = (name, bucket, tuple(sorted(spec.items())))
    with _MOCK_LOCK:
        server = _MOCK_SERVERS.get(reg_key)
        if server is None:
            server = MockS3Server()
            server.populate(bucket, **spec)
            _MOCK_SERVERS[reg_key] = server
    return S3Store(server.host, server.port, bucket, **params)


register_scheme("mock-s3", _mock_s3_factory)

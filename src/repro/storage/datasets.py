"""Dataset layout generators mirroring Table 1 of the paper.

Three storage granularities:
  * ``big_files``   — few large files, items smaller than a block
                      (BookCorpus: 74M records / 16 files; SQuAD: 1 file)
  * ``flat_files``  — one directory of many small files
                      (PASCAL-VOC, VoxForge, COCO images)
  * ``dir_tree``    — many directories each holding a subset of items
                      (ImageNet: 1k class dirs; ICOADS: 2k date dirs)

Layouts are metadata-only: file content is synthesized deterministically on
fetch, so a "400 GB" dataset costs a few dicts of metadata.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..core.types import MB, PathT


@dataclass
class FileEntry:
    path: PathT
    size: int


@dataclass
class DatasetSpec:
    """One dataset in the remote store."""

    name: str
    layout: str                      # big_files | flat_files | dir_tree
    files: List[FileEntry] = field(default_factory=list)
    # directory listing: parent path -> ordered child names
    dirs: Dict[PathT, List[str]] = field(default_factory=dict)
    total_bytes: int = 0
    n_items: int = 0                 # logical data items (records/images/...)

    def root(self) -> PathT:
        return (self.name,)


def make_dataset(name: str, layout: str, *,
                 n_files: int = 16, file_size: int = 512 * MB,
                 n_dirs: int = 0, files_per_dir: int = 0,
                 small_file_size: int = 128 * 1024,
                 n_items: Optional[int] = None) -> DatasetSpec:
    """Build a dataset layout.

    big_files:   ``<name>/data-{i:05d}.arrow`` × n_files, each ``file_size``.
    flat_files:  ``<name>/files/{i:07d}.bin`` × n_files, each small_file_size.
    dir_tree:    ``<name>/{d:05d}/{i:05d}.bin`` n_dirs × files_per_dir.
    """
    spec = DatasetSpec(name=name, layout=layout)
    root = (name,)
    if layout == "big_files":
        names = [f"data-{i:05d}.arrow" for i in range(n_files)]
        spec.dirs[root] = names
        for fn in names:
            spec.files.append(FileEntry(root + (fn,), file_size))
        spec.n_items = n_items or n_files * max(1, file_size // (16 * 1024))
    elif layout == "flat_files":
        sub = root + ("files",)
        spec.dirs[root] = ["files"]
        names = [f"{i:07d}.bin" for i in range(n_files)]
        spec.dirs[sub] = names
        for fn in names:
            spec.files.append(FileEntry(sub + (fn,), small_file_size))
        spec.n_items = n_items or n_files
    elif layout == "dir_tree":
        dnames = [f"{d:05d}" for d in range(n_dirs)]
        spec.dirs[root] = dnames
        for d in dnames:
            dpath = root + (d,)
            fnames = [f"{i:05d}.bin" for i in range(files_per_dir)]
            spec.dirs[dpath] = fnames
            for fn in fnames:
                spec.files.append(FileEntry(dpath + (fn,), small_file_size))
        spec.n_items = n_items or n_dirs * files_per_dir
    else:
        raise ValueError(f"unknown layout {layout!r}")
    spec.total_bytes = sum(f.size for f in spec.files)
    return spec

"""Tiered cache storage: a RAM block tier over a spill-to-disk tier.

The kernel cache is *accounting-only*: it decides which blocks deserve
residency, but the repo carried no payload store — a "hit" still fetched
its bytes from the backing store.  :class:`TieredStore` closes that gap
as a new layer between the client and any backing store: a **RAM tier**
holding whole-block payloads, spilling its evictions to a **local-disk
tier** (real checksummed files under a spill directory with their own
capacity, LRU order and promote-on-hit), composed behind the ordinary
``BackingStore`` v2 surface — ``open_cache`` stacks (thread driver,
process driver, the PR 8 daemon) get tiering with zero API changes:

    store = open_store("tiered+file:///data?ram_mb=64&disk_mb=256")
    client = open_cache(store, capacity, fetch_bytes=True)

Placement is **pattern-aware**, reusing the classifier verdicts the
engine already produces.  The engine duck-types two optional hooks on
its ``meta`` object (see ``core.igtcache``):

* ``note_pattern(top, pattern, pin_ram)`` — the per-dataset placement
  hint (``core.allocation.placement_hint``), pushed on change;
* ``note_evicted(key, size)`` — every kernel eviction, the spill signal.

Policy (HugeCTR's HMEM-Cache host-memory block tier is the exemplar —
SNIPPETS.md snippet 1):

* **SEQUENTIAL** extents are disk-eligible but not worth RAM residency:
  block fills write *through* to the disk tier (a re-scan streams from
  local disk instead of the remote), never displacing RAM blocks;
* **SKEWED** hot blocks pin in RAM: their entries are sticky — the RAM
  LRU prefers non-sticky victims;
* **RANDOM / UNKNOWN** follow target-hit-rate-gated admission: when the
  tier's recent hit rate already meets ``target_hit_rate`` and RAM is
  full, new insertions are skipped ("if the actual hit rate is greater
  than this value, no eviction/insertion will happen").

Two modes share one policy engine:

* ``mode="bytes"`` (default) — real payloads: RAM dict + spill files
  (``IGTS`` header, CRC-32 payload checksum, atomic tmp+rename writes,
  warm-restart re-index).  A truncated or checksum-failing spill file
  degrades to a clean miss (the file is dropped, bytes re-fetched from
  the inner store — corrupt bytes never reach a caller); a full spill
  dir falls back to RAM-only with a counted stat.
* ``mode="index"`` — residency accounting only (no payloads, no files):
  the discrete-event ``sim.cluster.ClusterSim`` moves no bytes, so it
  consults ``sim_read(key, size)`` per missed block to decide local-disk
  vs remote-link cost (the tier-aware bytes-moved model).
"""
from __future__ import annotations

import os
import struct
import tempfile
import threading
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.types import MB, PathT, split_block_key
from .api import (BackingStore, RangeRequest, StoreCapabilities,
                  as_backing_store)

__all__ = ["DiskTier", "TIER_KEYS", "TieredStore", "TierStats"]

# query/override keys open_store routes to the TieredStore constructor
# (everything else configures the inner scheme)
TIER_KEYS = ("ram_bytes", "disk_bytes", "ram_mb", "disk_mb", "spill_dir",
             "mode", "target_hit_rate", "hit_window")

SEQUENTIAL, RANDOM, SKEWED, UNKNOWN = ("sequential", "random", "skewed",
                                       "unknown")


class TierStats:
    """Counter block for one :class:`TieredStore` (all under its lock)."""

    __slots__ = ("ram_hits", "disk_hits", "misses", "pass_through",
                 "ram_hit_bytes", "disk_hit_bytes", "remote_bytes",
                 "spills", "spill_bytes", "spill_errors", "promotes",
                 "ram_evictions", "disk_evictions", "checksum_failures",
                 "admission_skips", "restored", "prefetch_disk_hits",
                 "prefetch_disk_bytes")

    def __init__(self) -> None:
        for name in self.__slots__:
            setattr(self, name, 0)

    def snapshot(self) -> dict:
        return {name: getattr(self, name) for name in self.__slots__}


# spill-file header: magic, format version, key length; CRC-32 and byte
# length of the payload (the key itself follows, then the payload)
_MAGIC = b"IGTS"
_HEADER = struct.Struct("<4sBHIQ")
_VERSION = 1


class DiskTier:
    """The spill tier: capacity-bounded LRU of whole-block entries.

    ``payload=True`` keeps real files under ``root`` (one per block,
    checksummed, written atomically via tmp+rename so a crash never
    leaves a half-visible entry); ``payload=False`` is the index-only
    mode for simulators that track residency without moving bytes.  Not
    thread-safe on its own — the owning :class:`TieredStore` serializes
    access under one lock.
    """

    def __init__(self, capacity: int, root: Optional[str] = None,
                 payload: bool = True,
                 stats: Optional[TierStats] = None) -> None:
        self.capacity = capacity
        self.root = root
        self.payload = payload
        self.stats = stats if stats is not None else TierStats()
        self.used = 0
        # key -> (size, filename-or-None), LRU order (oldest first)
        self.index: "OrderedDict[str, Tuple[int, Optional[str]]]" = \
            OrderedDict()
        self._spill_fails = 0        # consecutive write failures
        self.disabled = False        # spill-dir-full / sick-disk fallback
        if capacity <= 0:
            # RAM-only configuration: the disk tier exists but never admits
            self.disabled = True
        elif payload:
            if root is None:
                raise ValueError("payload disk tier needs a spill dir")
            os.makedirs(root, exist_ok=True)
            self._reindex()

    # -- warm restart --------------------------------------------------------
    def _reindex(self) -> None:
        """Re-adopt spill files left by a previous process (daemon or
        worker restart with a warm spill directory).  Unparseable files
        are deleted; LRU order follows mtime."""
        entries: List[Tuple[float, str, str, int]] = []
        try:
            names = sorted(os.listdir(self.root))
        except OSError:
            return
        for name in names:
            if not name.endswith(".blk"):
                continue
            fpath = os.path.join(self.root, name)
            try:
                with open(fpath, "rb") as f:
                    head = f.read(_HEADER.size)
                    magic, ver, klen, _crc, size = _HEADER.unpack(head)
                    if magic != _MAGIC or ver != _VERSION:
                        raise ValueError("bad spill header")
                    key = f.read(klen).decode("utf-8")
                mtime = os.path.getmtime(fpath)
            except (OSError, ValueError, struct.error, UnicodeDecodeError):
                self._unlink(fpath)
                continue
            entries.append((mtime, key, name, size))
        for _mtime, key, name, size in sorted(entries):
            self.index[key] = (size, name)
            self.used += size
            self.stats.restored += 1
        while self.used > self.capacity:
            if not self.evict_lru():
                break

    # -- entry plumbing ------------------------------------------------------
    @staticmethod
    def _fname(key: str) -> str:
        import hashlib
        return hashlib.blake2b(key.encode(), digest_size=12).hexdigest() \
            + ".blk"

    def _unlink(self, fpath: str) -> None:
        try:
            os.unlink(fpath)
        except OSError:
            pass

    def __contains__(self, key: str) -> bool:
        return key in self.index

    def touch(self, key: str) -> None:
        if key in self.index:
            self.index.move_to_end(key)

    def put(self, key: str, size: int,
            data: Optional[np.ndarray] = None) -> bool:
        """Admit one block (re-admitting an existing key is a cheap LRU
        refresh — the spill file is already on disk).  Returns False when
        the entry could not be admitted (disk disabled / write failed)."""
        if key in self.index:
            self.index.move_to_end(key)
            return True
        if self.disabled or size > self.capacity:
            return False
        while self.used + size > self.capacity:
            if not self.evict_lru():
                return False
        name: Optional[str] = None
        if self.payload:
            if data is None:
                return False         # nothing to write (no payload in hand)
            name = self._fname(key)
            if not self._write(key, data, name):
                return False
        self.index[key] = (size, name)
        self.used += size
        self.stats.spills += 1
        self.stats.spill_bytes += size
        return True

    def _write(self, key: str, data: np.ndarray, name: str) -> bool:
        kb = key.encode("utf-8")
        payload = np.ascontiguousarray(data, dtype=np.uint8).tobytes()
        head = _HEADER.pack(_MAGIC, _VERSION, len(kb),
                            zlib.crc32(payload) & 0xFFFFFFFF, len(payload))
        fpath = os.path.join(self.root, name)
        tmp = os.path.join(self.root, f".{name}.{os.getpid()}.tmp")
        try:
            with open(tmp, "wb") as f:
                f.write(head)
                f.write(kb)
                f.write(payload)
            os.replace(tmp, fpath)
        except OSError:
            # spill dir full / sick disk: count it, drop the entry, and
            # after a few consecutive failures stop trying (RAM-only
            # fallback) instead of hammering a dead device
            self._unlink(tmp)
            self.stats.spill_errors += 1
            self._spill_fails += 1
            if self._spill_fails >= 8:
                self.disabled = True
            return False
        self._spill_fails = 0
        return True

    def get(self, key: str) -> Optional[np.ndarray]:
        """Payload for ``key`` (refreshes LRU), or None.  A truncated or
        checksum-failing file is dropped and reported as a miss — corrupt
        bytes never reach the caller."""
        entry = self.index.get(key)
        if entry is None:
            return None
        size, name = entry
        if not self.payload or name is None:
            self.index.move_to_end(key)
            return None
        fpath = os.path.join(self.root, name)
        try:
            with open(fpath, "rb") as f:
                head = f.read(_HEADER.size)
                magic, ver, klen, crc, length = _HEADER.unpack(head)
                if magic != _MAGIC or ver != _VERSION:
                    raise ValueError("bad spill header")
                fkey = f.read(klen).decode("utf-8")
                payload = f.read(length)
            if fkey != key or len(payload) != length or \
                    zlib.crc32(payload) & 0xFFFFFFFF != crc:
                raise ValueError("spill payload corrupt")
        except (OSError, ValueError, struct.error, UnicodeDecodeError):
            self.stats.checksum_failures += 1
            self.remove(key)
            return None
        self.index.move_to_end(key)
        arr = np.frombuffer(payload, dtype=np.uint8)
        arr.flags.writeable = False
        return arr

    def remove(self, key: str) -> None:
        entry = self.index.pop(key, None)
        if entry is None:
            return
        size, name = entry
        self.used -= size
        if self.payload and name is not None:
            self._unlink(os.path.join(self.root, name))

    def evict_lru(self) -> bool:
        if not self.index:
            return False
        key, (size, name) = self.index.popitem(last=False)
        self.used -= size
        self.stats.disk_evictions += 1
        if self.payload and name is not None:
            self._unlink(os.path.join(self.root, name))
        return True


class _RamEntry:
    __slots__ = ("data", "size", "sticky")

    def __init__(self, data: Optional[np.ndarray], size: int,
                 sticky: bool) -> None:
        self.data = data
        self.size = size
        self.sticky = sticky


class TieredStore(BackingStore):
    """RAM + spill-to-disk payload tiers over any byte-serving store.

    Transparent to the kernel: metadata calls pass through to ``inner``
    (which keeps backing the engine's ``StoreMeta``), and every fetch
    returns exactly the bytes the inner store would have served — the
    tiers only change *where* they come from.  Only whole-block fills
    (offset 0, length = the block's populated size) are admitted; partial
    ranges are served by slicing a resident block, or pass through
    uncached (a 4 KB range must never masquerade as a 4 MB block).
    """

    def __init__(self, inner, *, ram_bytes: int = 64 * MB,
                 disk_bytes: int = 256 * MB,
                 ram_mb: Optional[int] = None,
                 disk_mb: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 mode: str = "bytes",
                 target_hit_rate: float = 0.8,
                 hit_window: int = 256) -> None:
        backing = as_backing_store(inner)
        if backing is None:
            raise TypeError(
                f"TieredStore needs a byte-serving store, got {inner!r}")
        if mode not in ("bytes", "index"):
            raise ValueError(f"unknown tier mode {mode!r}; expected "
                             f"'bytes' or 'index'")
        if ram_mb is not None:
            ram_bytes = int(ram_mb) * MB
        if disk_mb is not None:
            disk_bytes = int(disk_mb) * MB
        self.inner = inner            # metadata passthrough target
        self._backing = backing       # normalized fetch target
        self.mode = mode
        self.ram_bytes = int(ram_bytes)
        self.disk_bytes = int(disk_bytes)
        self.target_hit_rate = float(target_hit_rate)
        self.hit_window = max(16, int(hit_window))
        if mode == "bytes" and spill_dir is None and disk_bytes > 0:
            spill_dir = tempfile.mkdtemp(prefix="igt-spill-")
        self.spill_dir = spill_dir
        self.stats = TierStats()
        self._ram: "OrderedDict[str, _RamEntry]" = OrderedDict()
        self._ram_used = 0
        self.disk = DiskTier(self.disk_bytes, spill_dir,
                             payload=(mode == "bytes"), stats=self.stats)
        # placement hints: dataset top component -> (pattern, pin_ram)
        self._patterns: Dict[str, Tuple[str, bool]] = {}
        # recent-window hit-rate for the HMEM-style admission gate
        self._win_lookups = 0
        self._win_hits = 0
        self._recent_rate: Optional[float] = None
        self._lock = threading.Lock()

    # -- wrapper plumbing ----------------------------------------------------
    def capabilities(self) -> StoreCapabilities:
        return self._backing.capabilities()

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __getstate__(self):
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    @property
    def reopen_by_uri(self) -> bool:
        # a tiered stack is reconstructable from its URI exactly when the
        # inner store is (tiered+file:// yes; tiered+sim:// with datasets
        # registered post-open must travel as the object)
        return bool(getattr(self.inner, "reopen_by_uri", False))

    # -- placement hooks (driven by core.igtcache) ---------------------------
    def note_pattern(self, top: str, pattern: str,
                     pin_ram: bool = False) -> None:
        """Engine placement hint for the dataset rooted at ``top``."""
        with self._lock:
            self._patterns[str(top)] = (str(pattern), bool(pin_ram))

    def note_evicted(self, key: str, size: int) -> None:
        """Kernel eviction: the block leaves RAM-worthiness — spill it.

        In bytes mode the payload (when the RAM tier holds it) moves to
        the disk tier; in index mode the key is admitted to the disk
        residency index (the simulator's spill signal)."""
        with self._lock:
            pattern, _pin = self._pattern_for(key)
            if self.mode == "index":
                if self._admission_gated(pattern):
                    self.stats.admission_skips += 1
                    return
                self.disk.put(key, size)
                return
            entry = self._ram.pop(key, None)
            if entry is None:
                return               # no payload in hand: nothing to spill
            self._ram_used -= entry.size
            self.disk.put(key, entry.size, entry.data)

    # -- fetch path ----------------------------------------------------------
    def _block_info(self, path: PathT, offset: int,
                    length: int) -> Tuple[Optional[str], int]:
        """(residency key, populated block length) when ``path`` is a
        block path and the range fits inside it; (None, 0) otherwise."""
        file_path, b = split_block_key(path)
        if b is None:
            return None, 0
        try:
            bs = int(self.inner.block_size)
            fsize = int(self.inner.file_size(file_path))
        except (AttributeError, TypeError):
            return None, 0
        blk_len = min(bs, fsize - b * bs)
        if blk_len <= 0 or offset < 0 or offset + length > blk_len:
            return None, 0
        return "/".join(path), blk_len

    def _pattern_for(self, key: str) -> Tuple[str, bool]:
        top = key.split("/", 1)[0]
        return self._patterns.get(top, (UNKNOWN, False))

    def _note_lookup(self, hit: bool) -> None:
        self._win_lookups += 1
        if hit:
            self._win_hits += 1
        if self._win_lookups >= self.hit_window:
            self._recent_rate = self._win_hits / self._win_lookups
            self._win_lookups = 0
            self._win_hits = 0

    def _admission_gated(self, pattern: str) -> bool:
        """HMEM-Cache idiom: when the tier already meets its target hit
        rate, RANDOM/UNKNOWN insertions (and their eviction churn) are
        skipped.  SEQUENTIAL and SKEWED placement is structural and never
        gated."""
        if pattern in (SEQUENTIAL, SKEWED):
            return False
        return (self._recent_rate is not None
                and self._recent_rate >= self.target_hit_rate)

    def _ram_put(self, key: str, data: np.ndarray, size: int,
                 sticky: bool) -> None:
        if size > self.ram_bytes:
            return
        old = self._ram.pop(key, None)
        if old is not None:
            self._ram_used -= old.size
        while self._ram_used + size > self.ram_bytes:
            if not self._ram_evict_one():
                return
        self._ram[key] = _RamEntry(data, size, sticky)
        self._ram_used += size

    def _ram_evict_one(self) -> bool:
        """LRU with SKEWED pinning: prefer the oldest non-sticky entry;
        only when everything is sticky does a sticky block leave."""
        victim = None
        for k, e in self._ram.items():
            if not e.sticky:
                victim = k
                break
        if victim is None:
            if not self._ram:
                return False
            victim = next(iter(self._ram))
        entry = self._ram.pop(victim)
        self._ram_used -= entry.size
        self.stats.ram_evictions += 1
        self.disk.put(victim, entry.size, entry.data)
        return True

    def _admit_fill(self, key: str, data: np.ndarray, size: int) -> None:
        """Place one freshly fetched whole block per the pattern hint."""
        pattern, pin = self._pattern_for(key)
        if pattern == SEQUENTIAL:
            # streamed data: disk-eligible, never worth RAM residency
            self.disk.put(key, size, data)
            return
        if self._ram_used + size > self.ram_bytes \
                and self._admission_gated(pattern):
            self.stats.admission_skips += 1
            return
        self._ram_put(key, data, size, sticky=(pattern == SKEWED or pin))

    def fetch_range(self, path: PathT, offset: int,
                    length: int) -> np.ndarray:
        key, blk_len = self._block_info(path, offset, length)
        if key is None:
            with self._lock:
                self.stats.pass_through += 1
            return self._backing.fetch_range(path, offset, length)
        with self._lock:
            got = self._serve_resident(key, offset, length)
        if got is not None:
            return got
        full = (offset == 0 and length == blk_len)
        if not full:
            # partial miss: move only the requested bytes, uncached
            with self._lock:
                self.stats.pass_through += 1
            return self._backing.fetch_range(path, offset, length)
        data = self._backing.fetch_range(path, 0, blk_len)
        self._fill(key, data, blk_len)
        return data

    def _serve_resident(self, key: str, offset: int,
                        length: int) -> Optional[np.ndarray]:
        """Tier lookup under the lock: RAM slice, else disk payload with
        promote-on-hit (the disk entry is retained, so re-spilling the
        block later is a free LRU refresh)."""
        entry = self._ram.get(key)
        if entry is not None and entry.data is not None:
            self._ram.move_to_end(key)
            self._note_lookup(hit=True)
            self.stats.ram_hits += 1
            self.stats.ram_hit_bytes += length
            return entry.data[offset:offset + length]
        data = self.disk.get(key)
        if data is not None:
            self._note_lookup(hit=True)
            self.stats.disk_hits += 1
            self.stats.disk_hit_bytes += length
            pattern, pin = self._pattern_for(key)
            if pattern != SEQUENTIAL:   # sequential streams from disk
                self.stats.promotes += 1
                self._ram_put(key, data, len(data),
                              sticky=(pattern == SKEWED or pin))
            return data[offset:offset + length]
        self._note_lookup(hit=False)
        self.stats.misses += 1
        self.stats.remote_bytes += length
        return None

    def _fill(self, key: str, data: np.ndarray, size: int) -> None:
        arr = np.array(data, dtype=np.uint8, copy=True)
        arr.flags.writeable = False
        with self._lock:
            self._admit_fill(key, arr, size)

    def fetch_many(self, requests: Sequence[RangeRequest]
                   ) -> List[np.ndarray]:
        """Tier-resident ranges served locally; the remainder goes to the
        inner store as **one** batched ``fetch_many`` (preserving the
        per-shard demand-batching win), then whole-block fills are
        admitted per the placement policy."""
        out: List[Optional[np.ndarray]] = [None] * len(requests)
        miss_idx: List[int] = []
        miss_reqs: List[RangeRequest] = []
        fills: List[Tuple[int, str, int]] = []  # (out idx, key, blk_len)
        with self._lock:
            for i, (path, offset, length) in enumerate(requests):
                key, blk_len = self._block_info(path, offset, length)
                if key is None:
                    self.stats.pass_through += 1
                    miss_idx.append(i)
                    miss_reqs.append((path, offset, length))
                    continue
                got = self._serve_resident(key, offset, length)
                if got is not None:
                    out[i] = got
                elif offset == 0 and length == blk_len:
                    miss_idx.append(i)
                    miss_reqs.append((path, 0, blk_len))
                    fills.append((i, key, blk_len))
                else:
                    self.stats.pass_through += 1
                    miss_idx.append(i)
                    miss_reqs.append((path, offset, length))
        if miss_reqs:
            fetched = self._backing.fetch_many(miss_reqs)
            for i, data in zip(miss_idx, fetched):
                out[i] = data
            for i, key, blk_len in fills:
                self._fill(key, out[i], blk_len)
        return out  # type: ignore[return-value]

    def fetch_block(self, path: PathT, size: int) -> np.ndarray:
        return self.fetch_range(path, 0, size)

    # -- simulator surface (mode="index", but works for both) ---------------
    def sim_read(self, key: str, size: int, prefetch: bool = False) -> bool:
        """Residency probe for the discrete-event simulator: True when
        the missed block is disk-tier resident (serve at local-disk cost
        instead of a remote-link transfer).  Non-sequential hits promote
        (the entry leaves the disk index — the kernel re-admits the block
        to its RAM accounting); sequential data streams from disk and
        stays.  A miss admits the key per the placement policy, modelling
        the write-through/spill the bytes-mode fill path performs."""
        with self._lock:
            pattern, _pin = self._pattern_for(key)
            if key in self.disk:
                if prefetch:
                    self.stats.prefetch_disk_hits += 1
                    self.stats.prefetch_disk_bytes += size
                else:
                    self._note_lookup(hit=True)
                    self.stats.disk_hits += 1
                    self.stats.disk_hit_bytes += size
                if pattern == SEQUENTIAL:
                    self.disk.touch(key)
                else:
                    self.stats.promotes += 1
                    self.disk.remove(key)
                return True
            if not prefetch:
                self._note_lookup(hit=False)
                self.stats.misses += 1
                self.stats.remote_bytes += size
                if self._admission_gated(pattern):
                    self.stats.admission_skips += 1
                else:
                    self.disk.put(key, size)
            return False

    # -- observability -------------------------------------------------------
    def tier_stats(self) -> dict:
        with self._lock:
            snap = self.stats.snapshot()
            snap.update({
                "mode": self.mode,
                "ram_bytes": self.ram_bytes,
                "disk_bytes": self.disk_bytes,
                "ram_used": self._ram_used,
                "disk_used": self.disk.used,
                "ram_blocks": len(self._ram),
                "disk_blocks": len(self.disk.index),
                "disk_disabled": self.disk.disabled,
                "spill_dir": self.spill_dir,
                "target_hit_rate": self.target_hit_rate,
                "patterns": dict(self._patterns),
            })
            return snap

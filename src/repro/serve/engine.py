"""Batched serving engine: continuous batching over a decode step, with
RAG-style retrieval reads flowing through IGTCache (a *skewed* stream the
cache learns to LRU).

The engine keeps a fixed decode batch; finished sequences' slots are refilled
from the request queue (continuous batching).  Retrieval is simulated: each
request reads k passages from the knowledge dataset through the cache before
its prompt is admitted — that is the paper's RAG workload shape.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CacheClient, IGTCache, NullExecutor
from ..models.config import ModelConfig
from ..models.transformer import decode_step, forward, init_decode_state


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S_prompt,)
    max_new: int = 16
    retrieved: int = 0
    output: List[int] = field(default_factory=list)
    submitted: float = 0.0
    finished: float = 0.0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int = 4,
                 max_seq: int = 512,
                 cache_engine: Optional["IGTCache | CacheClient"] = None,
                 knowledge_dataset: Optional[str] = None,
                 retrieval_k: int = 4, zipf_a: float = 1.3,
                 seed: int = 0) -> None:
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        if cache_engine is not None and not isinstance(cache_engine,
                                                       CacheClient):
            # bare kernel: wrap it so its prefetch candidates are cancelled
            # rather than silently dropped (the kernel's pending table
            # would otherwise suppress re-issuing those blocks forever)
            cache_engine = CacheClient(cache_engine,
                                       executor=NullExecutor())
        self.cache = cache_engine
        self.knowledge = knowledge_dataset
        self.retrieval_k = retrieval_k
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        self.queue: Deque[Request] = deque()
        self.done: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * batch
        self.state = init_decode_state(cfg, batch, max_seq)
        self._decode = jax.jit(
            lambda p, s, t: decode_step(p, cfg, s, t))

    # ---------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        req.submitted = time.monotonic()
        self.queue.append(req)

    def _retrieve(self, req: Request) -> None:
        """RAG retrieval: zipf-hot passage reads through the unified cache
        client (prefetch candidates run on its executor)."""
        if self.cache is None or self.knowledge is None:
            return
        ds = self.cache.meta.datasets[self.knowledge]
        n = len(ds.files)
        for _ in range(self.retrieval_k):
            r = int((self.rng.zipf(self.zipf_a) - 1) % n)
            f = ds.files[r]
            self.cache.read(f.path, 0, min(f.size, 64 * 1024),
                            time.monotonic())
            req.retrieved += 1

    def _admit(self) -> None:
        for i in range(self.batch):
            if self._slots[i] is None and self.queue:
                req = self.queue.popleft()
                self._retrieve(req)
                self._slots[i] = req

    # ----------------------------------------------------------------- step
    def run(self, max_steps: int = 1000) -> List[Request]:
        """Decode until queue + slots drain (token-level continuous batching).

        Prompts are fed token-by-token through the decode path (simple and
        uniform; a production prefill path exists in serve_step.py)."""
        feed_pos = [0] * self.batch
        for _ in range(max_steps):
            self._admit()
            if all(s is None for s in self._slots) and not self.queue:
                break
            toks = np.zeros((self.batch, 1), np.int32)
            for i, req in enumerate(self._slots):
                if req is None:
                    continue
                if feed_pos[i] < len(req.prompt):
                    toks[i, 0] = req.prompt[feed_pos[i]]
                elif req.output:
                    toks[i, 0] = req.output[-1]
            logits, self.state = self._decode(self.params, self.state,
                                              jnp.asarray(toks))
            nxt = np.asarray(logits[:, -1].argmax(-1))
            for i, req in enumerate(self._slots):
                if req is None:
                    continue
                if feed_pos[i] < len(req.prompt):
                    feed_pos[i] += 1
                    if feed_pos[i] == len(req.prompt):
                        req.output.append(int(nxt[i]))
                else:
                    req.output.append(int(nxt[i]))
                    if len(req.output) >= req.max_new:
                        req.finished = time.monotonic()
                        self.done.append(req)
                        self._slots[i] = None
                        feed_pos[i] = 0
        return self.done

"""Serving steps: prefill (full-sequence forward) and decode (one token with
a KV/SSM cache), with per-shape sharding — the decode_32k / long_500k cells
lower ``serve_step``, not ``train_step``.

For long_500k (batch=1) the KV cache is *sequence-sharded* over the data axis
(batch cannot shard); decode attention contracts over the sharded axis and
XLA inserts the reduction — the roofline's collective term shows it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeSpec
from ..models.ssm import ssm_dims
from ..models.transformer import (build_specs, decode_step, forward,
                                  init_decode_state)
from ..sharding import (LogicalRules, logical_sharding, sharding_ctx,
                        shardings_for)

CACHE_AXES = {
    "pos": (),
    "k": ("layers", "cache_batch", "cache_seq", "kv_heads", None),
    "v": ("layers", "cache_batch", "cache_seq", "kv_heads", None),
    "conv": ("layers", "cache_batch", None, "ssm_inner"),
    "ssd": ("layers", "cache_batch", "ssm_heads", None, None),
    "shared_k": (None, "cache_batch", "cache_seq", "kv_heads", None),
    "shared_v": (None, "cache_batch", "cache_seq", "kv_heads", None),
    "img_kv": (None, "cache_batch", None, "kv_heads", None),
}


def decode_state_structs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    state = init_decode_state(cfg, 1, 8)  # tiny template for the pytree
    B, S = shape.global_batch, shape.seq_len

    def fix(path, leaf):
        name = path
        if name == "pos":
            return jax.ShapeDtypeStruct((), jnp.int32)
        if name in ("k", "v", "shared_k", "shared_v"):
            L = leaf.shape[0]
            KV, hd = cfg.n_kv_heads, cfg.hd
            return jax.ShapeDtypeStruct((L, B, S, KV, hd), jnp.bfloat16)
        if name == "conv":
            L = leaf.shape[0]
            return jax.ShapeDtypeStruct((L, B) + leaf.shape[2:], jnp.bfloat16)
        if name == "ssd":
            L = leaf.shape[0]
            return jax.ShapeDtypeStruct((L, B) + leaf.shape[2:], jnp.float32)
        if name == "img_kv":
            return jax.ShapeDtypeStruct(
                (leaf.shape[0], B) + leaf.shape[2:], jnp.bfloat16)
        raise KeyError(name)

    out = {}
    for k, v in state.items():
        if k == "img_kv":
            out[k] = tuple(fix("img_kv", leaf) for leaf in v)
        else:
            out[k] = fix(k, v)
    return out


def decode_state_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                           rules: Optional[LogicalRules] = None):
    structs = decode_state_structs(cfg, shape)
    out = {}
    for k, v in structs.items():
        if k == "img_kv":
            out[k] = tuple(
                logical_sharding(CACHE_AXES["img_kv"], leaf.shape, mesh, rules)
                for leaf in v)
        else:
            out[k] = logical_sharding(CACHE_AXES[k], v.shape, mesh, rules)
    return out


def make_serve_step(cfg: ModelConfig, mesh: Mesh,
                    rules: Optional[LogicalRules] = None, unroll: int = 1):
    def serve_step(params, state, tokens=None, inputs_embeds=None):
        with sharding_ctx(mesh, rules):
            logits, state = decode_step(params, cfg, state, tokens,
                                        inputs_embeds=inputs_embeds,
                                        unroll=unroll)
        return logits, state
    return serve_step


def make_prefill(cfg: ModelConfig, mesh: Mesh,
                 rules: Optional[LogicalRules] = None, unroll: int = 1):
    def prefill(params, tokens=None, inputs_embeds=None, img_embeds=None):
        with sharding_ctx(mesh, rules):
            logits, _ = forward(params, cfg, tokens,
                                inputs_embeds=inputs_embeds,
                                img_embeds=img_embeds, remat="none",
                                unroll=unroll)
        return logits
    return prefill


def lower_serve_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                     rules: Optional[LogicalRules] = None, unroll: int = 1):
    """AOT-lower one decode step at (batch, kv_len = shape.seq_len)."""
    specs = build_specs(cfg)
    params_s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))
    params_sh = shardings_for(specs, mesh, rules)
    state_s = decode_state_structs(cfg, shape)
    state_sh = decode_state_shardings(cfg, shape, mesh, rules)
    B = shape.global_batch
    if cfg.family == "audio":
        tok_s = None
        emb_s = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
        emb_sh = logical_sharding(("batch", "seq", "act_embed"),
                                  emb_s.shape, mesh, rules)
        step = make_serve_step(cfg, mesh, rules, unroll)
        jitted = jax.jit(
            lambda p, s, e: step(p, s, inputs_embeds=e),
            in_shardings=(params_sh, state_sh, emb_sh),
            out_shardings=(None, state_sh), donate_argnums=(1,))
        return jitted.lower(params_s, state_s, emb_s)
    tok_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = logical_sharding(("batch", "seq"), tok_s.shape, mesh, rules)
    step = make_serve_step(cfg, mesh, rules, unroll)
    jitted = jax.jit(
        step,
        in_shardings=(params_sh, state_sh, tok_sh),
        out_shardings=(None, state_sh), donate_argnums=(1,))
    return jitted.lower(params_s, state_s, tok_s)


def lower_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  rules: Optional[LogicalRules] = None, unroll: int = 1):
    specs = build_specs(cfg)
    params_s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))
    params_sh = shardings_for(specs, mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    fn = make_prefill(cfg, mesh, rules, unroll)
    kwargs_s = {}
    kwargs_sh = {}
    if cfg.family == "audio":
        kwargs_s["inputs_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                         jnp.bfloat16)
    else:
        kwargs_s["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm":
        kwargs_s["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    names = {"tokens": ("batch", "seq"),
             "inputs_embeds": ("batch", "seq", "act_embed"),
             "img_embeds": ("batch", "seq", "act_embed")}
    keys = sorted(kwargs_s)
    args_s = tuple(kwargs_s[k] for k in keys)
    args_sh = tuple(logical_sharding(names[k], kwargs_s[k].shape, mesh, rules)
                    for k in keys)

    def positional(p, *vals):
        return fn(p, **dict(zip(keys, vals)))

    jitted = jax.jit(
        positional,
        in_shardings=(params_sh,) + args_sh,
        out_shardings=None)
    return jitted.lower(params_s, *args_s)

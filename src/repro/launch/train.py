"""End-to-end training driver: IGTCache-fed data pipeline → sharded train
step → checkpoint/restart — the paper's cache as the first-class data plane
of an LM trainer.

Example (CPU, ~15M model, a few hundred steps):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 100 --batch 4 --seq 256

``--arch <id>`` selects any assigned architecture; ``--reduced`` swaps in the
same-family smoke config so the driver runs on CPU.  On a TPU pod the same
driver runs the full config over ``make_production_mesh()``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..core import CacheConfig, bundle_client
from ..core.types import MB
from ..data.pipeline import CachedTokenPipeline, make_token_dataset
from ..models.config import ShapeSpec
from ..models.transformer import init_params
from ..sharding import shardings_for
from ..models.transformer import build_specs
from ..storage.object_store import RemoteStore
from ..train.checkpoint import CheckpointManager
from ..train.fault import PreemptionGuard, StragglerDetector
from ..train.optimizer import AdamWConfig, init_state
from ..train.train_step import make_train_step
from .mesh import make_local_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--cache-mb", type=int, default=256)
    ap.add_argument("--cache-bundle", default="igtcache",
                    help="igtcache | juicefs | prefetch_none | ...")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh() if jax.device_count() == 1 else None
    if mesh is None:
        from .mesh import make_production_mesh
        mesh = make_production_mesh()

    # ---- the paper's technique as the data plane -------------------------
    store = RemoteStore()
    n_shards = 8
    shard_bytes = max(8 * MB, args.batch * (args.seq + 1) * 4 * args.steps
                      // n_shards)
    store.add(make_token_dataset("train_corpus", n_shards, shard_bytes))
    cache_cfg = CacheConfig(min_share=16 * MB, rebalance_quantum=16 * MB,
                            rebalance_period=10.0)
    # one constructor path: the client owns prefetch execution (per-shard
    # background workers) and byte movement; the trainer never loops over
    # candidates by hand
    client = bundle_client(args.cache_bundle, store, args.cache_mb * MB,
                           cfg=cache_cfg, executor="threaded")
    engine = client.engine
    pipe = CachedTokenPipeline(store, client, "train_corpus",
                               seq_len=args.seq, batch=args.batch,
                               vocab=cfg.vocab)

    # ---- model / optimizer ------------------------------------------------
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    opt_state = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, mesh, None, remat="full"),
                      donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt_dir)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        start_step = extra.get("step", ckpt.latest_step())
        print(f"[train] resumed from step {start_step}")

    straggler = StragglerDetector()

    def on_preempt():
        ckpt.save(step, (params, opt_state), {"step": step})
        print(f"[train] preempted — checkpointed step {step}")

    step = start_step
    t_start = time.time()
    with PreemptionGuard(on_preempt):
        it = pipe.batches(epochs=1000)
        losses = []
        for step in range(start_step, args.steps):
            batch_np = next(it)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            straggler.record(0, time.time() - t0)
            if (step + 1) % args.log_every == 0:
                s = engine.snapshot()
                print(f"[train] step {step+1:5d} loss {loss:7.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"CHR {s['hit_ratio']:.3f} "
                      f"({time.time()-t0:.2f}s/step)", flush=True)
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, (params, opt_state),
                                {"step": step + 1})
    ckpt.wait()
    ckpt.save(args.steps, (params, opt_state), {"step": args.steps})
    pipe.close()
    client.close()
    s = engine.snapshot()
    dt = time.time() - t_start
    print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"cache CHR {s['hit_ratio']:.3f}, "
          f"prefetch_hits {s['prefetch_hits']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

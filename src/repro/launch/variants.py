"""Named sharding variants for §Perf hillclimbing.

Each variant is one edit to the logical rules table; the dry-run records it
so before/after roofline terms are directly comparable.
"""
from __future__ import annotations

from typing import Dict


def apply_variant(rules: Dict, arch: str, shape: str, variant: str) -> Dict:
    rules = dict(rules)
    if variant == "baseline":
        return rules
    if variant == "fsdp_pod":
        # FSDP over (pod, data) instead of data only — param all-gathers
        # cross pods; trades collective for memory headroom.
        rules["embed"] = ("pod", "data")
        return rules
    if variant == "no_fsdp":
        # replicate params over data (pure DP + TP): kills the per-layer
        # all-gathers, costs memory.
        rules["embed"] = None
        return rules
    if variant == "seq_shard":
        # Megatron-style sequence parallelism: between blocks, activations
        # are sharded on the SEQ dim over the model axis; XLA inserts
        # all-gather before attention/MLP and reduce-scatter after — same
        # wire bytes as the 2 all-reduces but the inter-block activations
        # (and their remat copies) shrink by the TP degree.
        rules["seq"] = "model"
        rules["act_embed"] = None
        return rules
    if variant == "ep_capacity":
        # MoE: shard the dispatch buffer's capacity dim over data — the
        # expert GEMMs compute per-chip capacity (1/16 of global) and the
        # token→expert movement becomes a proper all-to-all.
        rules["moe_capacity"] = "data"
        return rules
    if variant == "ep_only":
        # MoE: keep expert parallelism (experts over model) but drop tensor
        # parallelism for attention/dense/vocab — kills the per-layer
        # activation all-reduces; attention params get FSDP over both axes.
        for k in ("heads", "kv_heads", "ffn", "vocab", "embed_vocab",
                  "act_heads", "act_ffn", "act_vocab"):
            rules[k] = None
        rules["embed"] = ("data", "model")
        return rules
    if variant == "expert_data":
        # experts sharded over (data, model) — more expert parallelism for
        # big-E MoE, fewer experts per chip.
        rules["experts"] = ("data", "model")
        return rules
    if variant == "vocab_data":
        # shard the vocab/lm_head over (data, model): halves the logits
        # all-reduce payload per axis.
        rules["vocab"] = ("data", "model")
        rules["act_vocab"] = ("data", "model")
        return rules
    if variant == "cache_seq_model":
        # decode: KV cache sequence dim over model axis instead of batch TP
        rules["cache_seq"] = "model"
        rules["kv_heads"] = None
        return rules
    if variant == "pure_fsdp":
        # No tensor parallelism: both mesh axes act as FSDP/DP.  Kills the
        # 2-per-layer Megatron all-reduces of full activations; params are
        # fully sharded and all-gathered per layer instead.  Right when
        # (param bytes × 3 passes) < (2 × tokens_loc × d × L × 2 AR passes).
        for k in ("heads", "kv_heads", "ffn", "experts", "vocab",
                  "embed_vocab", "ssm_inner", "ssm_heads", "act_heads",
                  "act_ffn", "act_experts", "act_vocab"):
            rules[k] = None
        rules["embed"] = ("data", "model")
        rules["batch"] = ("pod", "data", "model")
        rules["cache_batch"] = ("pod", "data", "model")
        return rules
    if variant == "batch_dp":
        # batch shards over (pod, data) only — required when microbatching
        # shrinks the per-microbatch batch below the full device count.
        rules["batch"] = ("pod", "data")
        rules["cache_batch"] = ("pod", "data")
        return rules
    if variant == "embed_replicated":
        # Replicate the embedding TABLE over model (lm_head stays sharded):
        # removes the involuntary-rematerialization resharding XLA reports
        # on the vocab-sharded gather.
        rules["embed_vocab"] = None
        return rules
    if variant == "decode_weights_stationary":
        # Decode: no FSDP on params (weights stay resident; batch is tiny so
        # the per-layer weight all-gathers dominate otherwise) + KV cache
        # sequence-sharded over the model axis (4 KV heads cannot split a
        # 16-way axis; the seq dim can).
        rules["embed"] = None
        rules["cache_seq"] = "model"
        rules["kv_heads"] = None
        return rules
    raise KeyError(f"unknown variant {variant!r}")

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the process entry (the XLA_FLAGS line above runs before any jax
import).  For each cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis → results JSON

Results append incrementally to --out (resumable); §Dry-run/§Roofline of
EXPERIMENTS.md are generated from that file.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from ..configs import CONFIGS, SHAPES, get_config
from ..roofline.analysis import analyze, parse_collectives
from .mesh import make_production_mesh

DEFAULT_OUT = Path("dryrun_results.json")


def mesh_for(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    raise ValueError(name)


def rules_for(arch: str, shape_name: str, variant: str = "baseline"):
    """Per-cell sharding rules.  ``variant`` may be '+'-composed, e.g.
    'pure_fsdp+chunked_loss'; 'chunked_loss' toggles the CE impl instead of
    the rules (handled by the caller)."""
    from ..sharding import DEFAULT_RULES
    rules = dict(DEFAULT_RULES)
    if shape_name == "long_500k":
        # batch=1: sequence-shard the cache over the data axis
        rules["cache_seq"] = "data"
        rules["cache_batch"] = None
    for v in variant.split("+"):
        if v in ("baseline", "chunked_loss") or v.startswith("micro"):
            continue
        from .variants import apply_variant
        rules = apply_variant(rules, arch, shape_name, v)
    return rules


def loss_for(variant: str) -> str:
    return "chunked" if "chunked_loss" in variant.split("+") else "dense"


def micro_for(variant: str) -> int:
    for v in variant.split("+"):
        if v.startswith("micro"):
            return int(v[5:])
    return 1


def _lower(cfg, shape, mesh, rules, remat, unroll=1, loss_impl="dense",
           microbatches=1):
    if shape.kind == "train":
        from ..train.train_step import lower_train_step
        return lower_train_step(cfg, shape, mesh, rules, remat=remat,
                                unroll=unroll, loss_impl=loss_impl,
                                microbatches=microbatches)
    if shape.kind == "prefill":
        from ..serve.serve_step import lower_prefill
        return lower_prefill(cfg, shape, mesh, rules, unroll=unroll)
    from ..serve.serve_step import lower_serve_step
    return lower_serve_step(cfg, shape, mesh, rules, unroll=unroll)


def _compile_cost(cfg, shape, mesh, rules, remat, loss_impl="dense",
                  microbatches=1):
    """(flops, bytes, collective-bytes, collective-counts) of one compile.
    The scan is fully UNROLLED here so XLA's cost analysis counts every
    layer (it counts a while body once)."""
    compiled = _lower(cfg, shape, mesh, rules, remat,
                      unroll=cfg.n_layers, loss_impl=loss_impl,
                      microbatches=microbatches).compile()
    cost = dict(compiled.cost_analysis() or {})
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    return flops, nbytes, coll.total_bytes, coll.counts, coll.bytes_by_kind


def run_cell(arch: str, shape_name: str, mesh_name: str,
             variant: str = "baseline", remat: str = "full"):
    """One dry-run cell.

    XLA's cost analysis counts a `while` (scan) body ONCE regardless of trip
    count, so per-layer costs are recovered by compiling two shallow
    variants (L = p and L = 2p, p = the cross/shared-block period) and
    extrapolating linearly in depth; the full-depth compile then provides
    the proof-of-compile, the memory analysis and the true parameter/cache
    footprints.
    """
    import dataclasses
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "full-attention arch skips long_500k (DESIGN.md)"}
    mesh = mesh_for(mesh_name)
    chips = mesh.devices.size
    rules = rules_for(arch, shape_name, variant)
    loss_impl = loss_for(variant)
    micro = micro_for(variant)
    t0 = time.time()
    try:
        with mesh:
            # --- per-layer cost via depth extrapolation -------------------
            p = max(1, cfg.cross_attn_every or 0, cfg.shared_attn_every or 0)
            l1, l2 = p, 2 * p
            c1 = _compile_cost(dataclasses.replace(cfg, n_layers=l1),
                               shape, mesh, rules, remat, loss_impl, micro)
            c2 = _compile_cost(dataclasses.replace(cfg, n_layers=l2),
                               shape, mesh, rules, remat, loss_impl, micro)
            L = cfg.n_layers
            scale = (L - l1) / max(1, (l2 - l1))
            # clamp: cost must be monotone in depth (guards fusion noise)
            flops = max(c1[0], c1[0] + (c2[0] - c1[0]) * scale)
            nbytes = max(c1[1], c1[1] + (c2[1] - c1[1]) * scale)
            coll_bytes = max(c1[2], c1[2] + (c2[2] - c1[2]) * scale)
            coll_counts = {
                k: int(c1[3].get(k, 0)
                       + (c2[3].get(k, 0) - c1[3].get(k, 0)) * scale)
                for k in set(c1[3]) | set(c2[3])}
            coll_by_kind = {
                k: c1[4].get(k, 0) + (c2[4].get(k, 0) - c1[4].get(k, 0)) * scale
                for k in set(c1[4]) | set(c2[4])}
            # --- full-depth proof compile + memory ------------------------
            lowered = _lower(cfg, shape, mesh, rules, remat,
                             loss_impl=loss_impl, microbatches=micro)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
        mem_stats = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_stats[attr] = int(v)
            live = (mem_stats.get("argument_size_in_bytes", 0)
                    + mem_stats.get("temp_size_in_bytes", 0)
                    + mem_stats.get("output_size_in_bytes", 0)
                    - mem_stats.get("alias_size_in_bytes", 0))
            mem_stats["bytes_per_device"] = live
        roof = analyze(arch, shape, mesh_name, chips,
                       {"flops": flops, "bytes accessed": nbytes},
                       "", cfg, mem_stats)
        roof.collective_gbytes = coll_bytes / 1e9
        roof.collective_s = coll_bytes / 50e9
        roof.collectives = coll_counts
        roof.collective_bytes_by_kind = {k: v / 1e9
                                         for k, v in coll_by_kind.items()}
        row = roof.row()
        row.update({
            "status": "ok", "variant": variant, "remat": remat,
            "compile_s": round(time.time() - t0, 1),
            "memory": mem_stats,
            "kind": shape.kind,
            "params_b": round(cfg.param_count() / 1e9, 3),
            "active_params_b": round(cfg.active_param_count() / 1e9, 3),
        })
        return row
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "variant": variant, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
                "compile_s": round(time.time() - t0, 1)}


def load_results(path: Path):
    if path.exists():
        return json.loads(path.read_text())
    return []


def save_results(path: Path, rows):
    path.write_text(json.dumps(rows, indent=1, default=str))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in --out")
    args = ap.parse_args()

    archs = list(CONFIGS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    rows = load_results(args.out)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
            for r in rows if r.get("status") in ("ok", "skipped")}
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                key = (arch, shape, mesh, args.variant)
                if key in done and not args.force:
                    continue
                print(f"[dryrun] {arch} × {shape} × {mesh} "
                      f"({args.variant}) ...", flush=True)
                row = run_cell(arch, shape, mesh, args.variant, args.remat)
                print(f"  -> {row.get('status')} "
                      f"({row.get('compile_s', '?')}s) "
                      f"dominant={row.get('dominant', '-')}", flush=True)
                rows = [r for r in rows
                        if (r["arch"], r["shape"], r["mesh"],
                            r.get("variant", "baseline")) != key]
                rows.append(row)
                save_results(args.out, rows)
    bad = [r for r in rows if r.get("status") == "error"]
    print(f"[dryrun] {len(rows)} cells recorded, {len(bad)} errors")
    for r in bad:
        print("  ERROR:", r["arch"], r["shape"], r["mesh"], "-", r["error"])
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — only the dry-run (which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import)
actually builds the 256/512-device meshes.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh():
    """1-device mesh for CPU smoke tests (same axis names, all size 1)."""
    return _mk((1, 1), ("data", "model"))

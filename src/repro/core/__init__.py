"""IGTCache — the paper's primary contribution.

A unified, pattern/granularity-adaptive cache for heterogeneous AI workloads:
AccessStreamTree (§3.1) + K-S hypothesis-test pattern recognition (§3.2) +
adaptive prefetch/eviction/allocation (§3.3).
"""
from .access_stream_tree import (AccessStream, AccessStreamTree,
                                 ObservedChain, analyze_streams)
from .baselines import BUNDLES, bundle, bundle_engine
from .cache import CacheManageUnit, UnifiedCache, block_key
from .igtcache import EngineOptions, IGTCache, ReadOutcome, informative_depth
from .ks import ks_critical, ks_test_random, triangular_cdf
from .meta import LevelCache
from .pattern import (PatternResult, classify, classify_batch,
                      detect_sequential, fit_adaptive_ttl,
                      fit_adaptive_ttl_batch)
from .sharded import (GlobalRebalancer, ShardedIGTCache, make_engine,
                      shard_index)
from .types import AccessRecord, CacheConfig, CacheStats, GB, MB, PathT, Pattern

__all__ = [
    "AccessRecord", "AccessStream", "AccessStreamTree", "BUNDLES",
    "CacheConfig", "CacheManageUnit", "CacheStats", "EngineOptions", "GB",
    "GlobalRebalancer", "IGTCache", "LevelCache", "MB", "ObservedChain",
    "PathT", "Pattern", "PatternResult", "ReadOutcome", "ShardedIGTCache",
    "UnifiedCache", "analyze_streams", "block_key", "bundle",
    "bundle_engine", "classify",
    "classify_batch", "detect_sequential", "fit_adaptive_ttl",
    "fit_adaptive_ttl_batch", "informative_depth", "ks_critical",
    "ks_test_random", "make_engine", "shard_index", "triangular_cdf",
]

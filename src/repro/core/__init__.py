"""IGTCache — the paper's primary contribution.

A unified, pattern/granularity-adaptive cache for heterogeneous AI workloads:
AccessStreamTree (§3.1) + K-S hypothesis-test pattern recognition (§3.2) +
adaptive prefetch/eviction/allocation (§3.3).

Public API is two layers (docs/API.md): the *kernel* (``IGTCache`` /
``ShardedIGTCache`` — a deterministic state machine driven with explicit
timestamps) and the *client* (``CacheClient`` via ``open_cache`` — owns
byte movement through a ``BackingStore`` and prefetch execution through a
``PrefetchExecutor``).
"""
from .access_stream_tree import (AccessStream, AccessStreamTree,
                                 ObservedChain, analyze_streams)
from .baselines import BUNDLES, bundle, bundle_client, bundle_engine
from .cache import CacheManageUnit, UnifiedCache, path_key
from .client import (BackingStore, CacheClient, ClientStats, ExecutorStats,
                     KernelGuard, NullExecutor, PrefetchExecutor, ReadResult,
                     SimExecutor, ThreadedExecutor, open_cache)
from .faults import (RestartBudget, SHARD_DOWN, SHARD_RESTARTING, SHARD_UP,
                     ShardUnavailableError)
from .igtcache import EngineOptions, IGTCache, ReadOutcome, informative_depth
from .ks import ks_critical, ks_test_random, triangular_cdf
from .meta import LevelCache
from .pattern import (PatternResult, classify, classify_batch,
                      detect_sequential, fit_adaptive_ttl,
                      fit_adaptive_ttl_batch)
from .procdriver import ProcessExecutor, ProcessShardedCache, ShmArena
from .sharded import (DemandSummary, GlobalRebalancer, ShardDemandTracker,
                      ShardRouting, ShardedIGTCache, make_engine,
                      shard_index, split_capacity)
from .types import (AccessRecord, CacheConfig, CacheStats, GB, MB, PathT,
                    Pattern, block_key, split_block_key)

__all__ = [
    "AccessRecord", "AccessStream", "AccessStreamTree", "BUNDLES",
    "BackingStore", "CacheClient", "CacheConfig", "CacheManageUnit",
    "CacheStats", "ClientStats", "DemandSummary", "EngineOptions",
    "ExecutorStats", "GB",
    "GlobalRebalancer", "IGTCache", "KernelGuard", "LevelCache", "MB",
    "NullExecutor", "ObservedChain",
    "PathT", "Pattern", "PatternResult", "PrefetchExecutor",
    "ProcessExecutor", "ProcessShardedCache", "ReadOutcome",
    "ReadResult", "RestartBudget", "SHARD_DOWN", "SHARD_RESTARTING",
    "SHARD_UP", "ShardDemandTracker", "ShardRouting", "ShardUnavailableError",
    "ShardedIGTCache",
    "ShmArena", "SimExecutor", "ThreadedExecutor",
    "UnifiedCache", "analyze_streams", "block_key", "bundle",
    "bundle_client", "bundle_engine", "classify",
    "classify_batch", "detect_sequential", "fit_adaptive_ttl",
    "fit_adaptive_ttl_batch", "informative_depth", "ks_critical",
    "ks_test_random", "make_engine", "open_cache", "path_key",
    "shard_index", "split_block_key", "split_capacity", "triangular_cdf",
]

"""Path-hash sharded engine behind a unified facade (scaling PR).

The paper's engine is one Python state machine; every access of every job
serializes through it.  ``ShardedIGTCache`` splits the *observe/recognize*
hot path into N independent ``IGTCache`` shards — each with its own
AccessStreamTree, chain/ctx caches, LevelCache and ``UnifiedCache``
partition — while keeping *space allocation* cluster-wide, the split Hoard
(arXiv:1812.00669) uses for distributed DL caches (shard by key, global
placement view).

Routing granularity: the **top-level path component** (the dataset root).
A whole dataset maps to one shard, so every AccessStream — directory
levels, file level, block level, and the CMU's flattened dataset-granular
window — observes exactly the accesses it would observe unsharded:
recognition state is bitwise-identical per dataset, and sharding only
partitions *capacity*.  That skew (a hot random dataset stuck in a
quarter-capacity shard next to sequential streams that need nothing) is
what the cross-shard ``GlobalRebalancer`` repairs: it merges per-CMU
``marginal_benefit`` estimates across shards and moves quota *and the
backing shard capacity* from the cluster-wide minimum-benefit donor to the
maximum-benefit taker, so the paper's skew-aware space allocation (§4.3)
still operates over the whole cache.

``ShardedIGTCache(n_shards=1)`` is bitwise-identical to ``IGTCache`` on
any trace (tests/test_equivalence.py pins this): one shard holds the full
capacity, every call forwards to it, and the global layer stays inert.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .allocation import DemandEstimate, Rebalancer, marginal_benefit
from .cache import CacheManageUnit, path_key
from .igtcache import EngineOptions, IGTCache, ReadOutcome
from .meta import StoreMeta
from .sketch import CountMinSketch, SpaceSaving
from .types import CacheConfig, CacheStats, PathT, Pattern


def shard_index(path: PathT, n_shards: int) -> int:
    """Deterministic shard for a path: CRC-32 of the top-level component.

    Stable across processes and runs (unlike the salted builtin ``hash``),
    so the same path always lands on the same shard — the routing invariant
    tests/test_sharded.py pins.
    """
    if n_shards <= 1:
        return 0
    top = path[0] if path else ""
    return zlib.crc32(top.encode("utf-8")) % n_shards


class ShardRouting:
    """Memoized path → shard routing, shared by every shard driver.

    The CRC-32 of the top-level component is computed **once per
    dataset**: routing for every subsequent access of that dataset is a
    single dict lookup (datasets are few; the memo is unbounded by
    design).  Both the in-process ``ShardedIGTCache`` facade and the
    multi-process ``core.procdriver.ProcessShardedCache`` inherit this,
    so the two drivers cannot drift on placement — a path routes to the
    same shard index under either."""

    def _init_routing(self, n_shards: int) -> None:
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        # top-level component -> shard id (memoized CRC-32)
        self._route: Dict[str, int] = {}

    def shard_id(self, path: PathT) -> int:
        if self.n_shards == 1:
            return 0
        top = path[0] if path else ""
        sid = self._route.get(top)
        if sid is None:
            sid = shard_index(path, self.n_shards)
            self._route[top] = sid
        return sid

    def bucket_by_shard(self, items: Sequence,
                        path_of=None) -> Dict[int, List[tuple]]:
        """Group indexed items by owning shard:
        ``{sid: [(original_index, item), ...]}`` — the one split-and-
        reassemble-in-order primitive every batched fan-out uses (both
        drivers' ``read_batch``, both executors' ``fetch_demand``), so
        ordering/empty-bucket edge cases cannot drift between copies.
        ``path_of`` extracts the routing path (default: ``item[0]``,
        the shape of read requests and range requests)."""
        buckets: Dict[int, List[tuple]] = {}
        if path_of is None:
            for i, item in enumerate(items):
                buckets.setdefault(self.shard_id(item[0]), []).append(
                    (i, item))
        else:
            for i, item in enumerate(items):
                buckets.setdefault(self.shard_id(path_of(item)), []).append(
                    (i, item))
        return buckets


@dataclass
class DemandSummary:
    """One CMU's demand estimate, serialized for the cross-shard
    allocation round.

    This is the wire format of the rebalance-summary protocol: worker
    processes ship these rows to the driver instead of live
    ``CacheManageUnit`` objects, and the in-process facade builds the
    same rows from its shards, so both drivers run the identical greedy
    rule (``GlobalRebalancer.plan_moves``).  ``demand_limit`` carries
    enough state to re-evaluate ``wants_more`` after a mid-round quota
    move (RANDOM streams stop wanting at ``dataset_bytes``); patterns
    whose demand does not depend on quota leave it ``None``.

    ``want``/``floor``/``free`` are the adaptive planner's sizing
    fields (``quantum_policy="adaptive"``): ``want`` is the measured
    unmet demand in bytes (sketch-derived for SKEWED streams), ``floor``
    the pattern-aware minimum quota below which the stream starves, and
    ``free`` the bytes the CMU could donate without evicting anything.
    The fixed-quantum planner ignores them.
    """

    shard: int                 # owning shard index
    key: PathT                 # CMU root path (unique within its shard)
    benefit: float             # marginal benefit B (quota-independent)
    wants_more: bool           # unmet demand at current quota
    can_take: bool             # workload CMU; shard defaults only donate
    quota: int
    headroom: int              # donatable bytes (see tracker._row)
    demand_limit: Optional[float] = None   # wants_more := quota < limit
    want: int = 0              # unmet demand, bytes (adaptive sizing)
    floor: int = 0             # pattern-aware minimum quota
    free: int = 0              # quota - used (donatable without eviction)


# Rough per-row wire cost (fixed fields as packed ints/floats + framing);
# used only for the summary-bytes accounting in rebalance stats.
_ROW_OVERHEAD = 64


@dataclass
class ShardSummary:
    """One shard's complete demand summary for a cross-shard round.

    Exact :class:`DemandSummary` rows are shipped only for the shard's
    default CMU plus the top ``cfg.topk`` workload CMUs (ranked by
    unmet demand + donatable headroom); the remainder is aggregated
    into the ``tail_*`` counters, and the per-block heat detail rides
    in the two O(KB) sketch payloads (``core.sketch``).  Total payload
    is therefore bounded regardless of how many CMUs or distinct
    blocks the shard serves.
    """

    shard: int
    rows: List[DemandSummary] = field(default_factory=list)
    n_cmus: int = 0            # workload CMUs on the shard
    tail_cmus: int = 0         # workload CMUs beyond the exact-row cap
    tail_quota: int = 0
    tail_want: int = 0
    ghost_mass: int = 0        # ghost hits folded this interval
    cms_payload: bytes = b""   # serialized CountMinSketch (block heat)
    topk_payload: bytes = b""  # serialized SpaceSaving (heavy hitters)

    def payload_bytes(self) -> int:
        rows_cost = sum(len("/".join(r.key)) + _ROW_OVERHEAD
                        for r in self.rows)
        return (len(self.cms_payload) + len(self.topk_payload)
                + rows_cost + 48)


class GlobalRebalancer(Rebalancer):
    """Cross-shard space allocation: the paper's greedy max-B ← min-B rule
    over the *merged* CMU population of all shards.

    Within a shard, the per-shard ``Rebalancer`` (inside each ``IGTCache``
    tick) already shifts quota between co-located CMUs; this layer handles
    the moves those rounds cannot see — donor and taker living in
    *different* shards.  A cross-shard move shifts both the CMU quota and
    the backing pool capacity (``UnifiedCache.adjust_capacity``), so total
    capacity is conserved and every shard keeps ``sum(quota) == capacity``.

    Ghost-window coherence: shard-local rounds fire on each shard's own
    read-triggered tick cadence and reset the per-round BufferWindow
    counters, so at global-round time the windows of different shards span
    different (phase-dependent) intervals.  SKEWED demand is therefore
    measured from the windows' *cumulative* counters as a delta over this
    layer's own round interval — every CMU is compared over the same span
    of simulated time regardless of local reset phase.  The other patterns'
    benefits don't read the per-round window, so ``marginal_benefit`` is
    used as-is.
    """

    def __init__(self, cfg: CacheConfig) -> None:
        super().__init__(cfg)
        self.tracker = ShardDemandTracker(cfg)
        # (donor rkey, taker rkey) pairs of the previous round — the
        # adaptive planner refuses to reverse a fresh flow (ping-pong
        # damping beyond scalar hysteresis, needed once moves are
        # demand-sized rather than one-quantum)
        self._flow: set = set()
        # per-round stats, newest last (bounded); SimResult surfaces these
        self.round_log: List[dict] = []
        self.last_stats: Optional[dict] = None
        # cluster-wide heat view merged from the shards' shipped sketches
        self.cluster_heat: Optional[CountMinSketch] = None
        self.cluster_hot: Optional[SpaceSaving] = None

    def _estimate(self, cmu: CacheManageUnit, now: float) -> DemandEstimate:
        return self.tracker.estimate(cmu, now)

    def plan_moves(self, rows: Sequence[DemandSummary],
                   max_moves: Optional[int] = None
                   ) -> List[Tuple[DemandSummary, DemandSummary, int]]:
        """Plan one cross-shard round over serialized demand rows — pure
        planning, no engine access.  Both drivers run this: the
        in-process facade applies the returned moves to live CMUs, the
        process driver ships them to workers as quota/capacity deltas.
        Rows are mutated in place (quota, headroom, ``want``,
        ``wants_more`` via ``demand_limit``) so successive moves see
        the post-move state, exactly like a live-object round would.

        ``cfg.quantum_policy`` selects the planner: ``"adaptive"``
        (default) sizes each move by the taker's measured unmet demand
        with pattern-aware floors; ``"fixed"`` is the legacy
        one-quantum-per-move greedy loop, kept verbatim for comparison
        (the ``rebalance_path`` benchmark axis measures both)."""
        if self.cfg.quantum_policy == "fixed":
            return self._plan_moves_fixed(rows, max_moves)
        return self._plan_moves_adaptive(rows, max_moves)

    def _plan_moves_fixed(self, rows: Sequence[DemandSummary],
                          max_moves: Optional[int] = None
                          ) -> List[Tuple[DemandSummary, DemandSummary, int]]:
        """The paper's greedy max-B ← min-B rule, one quantum per move."""
        moves: List[Tuple[DemandSummary, DemandSummary, int]] = []
        if not rows or len({r.shard for r in rows}) < 2:
            return moves
        if max_moves is None:
            max_moves = len(rows)
        quantum = self.cfg.rebalance_quantum
        for _ in range(max_moves):
            takers = [r for r in rows if r.can_take and r.wants_more]
            if not takers:
                break
            taker = max(takers, key=lambda r: r.benefit)
            # donors restricted to OTHER shards: co-located pairs are the
            # shard-local rebalancer's job
            donors = [r for r in rows
                      if r.headroom >= quantum and r.shard != taker.shard]
            if not donors:
                break
            donor = min(donors, key=lambda r: r.benefit)
            if not self.clears_hysteresis(donor.benefit, taker.benefit):
                break
            amt = min(quantum, donor.headroom)
            if amt <= 0:
                break
            for row, delta in ((donor, -amt), (taker, amt)):
                row.quota += delta
                row.headroom += delta
                if row.demand_limit is not None:
                    row.wants_more = row.quota < row.demand_limit
            moves.append((donor, taker, amt))
        return moves

    def _plan_moves_adaptive(self, rows: Sequence[DemandSummary],
                             max_moves: Optional[int] = None
                             ) -> List[Tuple[DemandSummary, DemandSummary,
                                             int]]:
        """Demand-sized planning: two phases.

        Phase 1 (floor top-up, hysteresis-exempt): any workload CMU
        below its pattern floor is starving — born after the shard
        defaults drained, or an active sequential stream squeezed below
        its prefetch window — and is topped up from the lowest-benefit
        donors regardless of benefit ordering.  Retried every round, so
        a top-up that finds no donor today succeeds when capacity
        frees up (one-shot seeding provably strands CMUs).

        Phase 2 (want-sized moves): the greedy max-B ← min-B rule, but
        each move carries ``min(taker.want, donor budget)`` instead of
        one fixed quantum, so convergence no longer needs O(gap/quantum)
        rounds as shard count grows.  A donor's budget is its free
        (unused) bytes plus a forced-eviction allowance that scales
        with the benefit gap and shard count and vanishes near
        convergence — the adaptive quantum.  Fresh donor→taker flows
        from the previous round must not reverse this round (cooldown),
        which replaces the one-quantum loop's implicit damping."""
        moves: List[Tuple[DemandSummary, DemandSummary, int]] = []
        shard_ids = {r.shard for r in rows}
        if not rows or len(shard_ids) < 2:
            return moves
        if max_moves is None:
            max_moves = 4 * len(rows)
        min_share = self.cfg.min_share
        n_shards = len(shard_ids)
        base_q = self.cfg.rebalance_quantum
        prev_flow = self._flow
        flow: set = set()
        hot_spent: Dict[tuple, int] = {}

        def rk(r: DemandSummary) -> tuple:
            return (r.shard, tuple(r.key))

        def apply(donor: DemandSummary, taker: DemandSummary,
                  amt: int) -> None:
            for row, delta in ((donor, -amt), (taker, amt)):
                row.quota += delta
                row.headroom += delta
                if row.demand_limit is not None:
                    row.wants_more = row.quota < row.demand_limit
            donor.free = max(0, donor.free - amt)
            taker.want = max(0, taker.want - amt)
            flow.add((rk(donor), rk(taker)))
            moves.append((donor, taker, amt))

        def hot_room(donor: DemandSummary, taker: DemandSummary) -> int:
            # Forced-eviction (hot-byte) allowance for this donor: grows
            # with the donor/taker benefit gap and the shard count
            # (big imbalances at high n must close fast), shrinks to one
            # quantum near the hysteresis threshold.
            ratio = taker.benefit / max(donor.benefit, 1e-18)
            scale = max(1.0, min(
                4.0 * n_shards,
                n_shards * math.log2(max(1.0, ratio / self.HYSTERESIS))))
            cap = int(base_q * scale)
            return max(0, cap - hot_spent.get(rk(donor), 0))

        # ------------------------- phase 1: floor top-up ----------------
        for taker in sorted([r for r in rows if r.can_take],
                            key=lambda r: -r.benefit):
            guard = 0
            while taker.quota < taker.floor and guard < 64:
                guard += 1
                donors = [d for d in rows
                          if d.shard != taker.shard and d.headroom > 0
                          and (not d.can_take or d.quota > min_share)
                          and (rk(taker), rk(d)) not in flow]
                if not donors:
                    break
                donor = min(donors, key=lambda d: (d.benefit, -d.free))
                amt = min(taker.floor - taker.quota, donor.headroom)
                if amt <= 0:
                    break
                apply(donor, taker, amt)

        # ------------------------- phase 2: want-sized moves ------------
        for _ in range(max_moves - len(moves)):
            takers = [r for r in rows if r.can_take and r.want > 0]
            if not takers:
                break
            progressed = False
            for taker in sorted(takers, key=lambda r: -r.benefit):
                cands = []
                for d in rows:
                    if d.shard == taker.shard or d.headroom <= 0:
                        continue
                    if not self.clears_hysteresis(d.benefit, taker.benefit):
                        continue
                    if ((rk(taker), rk(d)) in prev_flow
                            or (rk(taker), rk(d)) in flow):
                        continue      # would reverse a fresh flow
                    avail = min(d.headroom, d.free + hot_room(d, taker))
                    if avail > 0:
                        cands.append((d, avail))
                if not cands:
                    continue
                donor, avail = min(cands,
                                   key=lambda e: (e[0].benefit, -e[1]))
                amt = min(taker.want, avail)
                if amt <= 0:
                    continue
                hot = max(0, amt - donor.free)
                if hot:
                    hot_spent[rk(donor)] = hot_spent.get(rk(donor), 0) + hot
                apply(donor, taker, amt)
                progressed = True
                break
            if not progressed:
                break
        self._flow = flow
        return moves

    def note_round(self, now: float, summaries: Sequence[ShardSummary],
                   moves: Sequence[tuple]) -> dict:
        """Record per-round stats and merge the shards' shipped sketches
        into the cluster-wide heat view.  Both drivers call this once
        per round; ``sim.cluster`` surfaces ``round_log`` as the
        ``rebalance_trace``."""
        heat: Optional[CountMinSketch] = None
        hot: Optional[SpaceSaving] = None
        for s in summaries:
            if s.cms_payload:
                c = CountMinSketch.deserialize(s.cms_payload)
                heat = c if heat is None else heat.merge(c)
            if s.topk_payload:
                t = SpaceSaving.deserialize(s.topk_payload)
                hot = t if hot is None else hot.merge(t)
        self.cluster_heat, self.cluster_hot = heat, hot
        stat = {
            "t": now,
            "policy": self.cfg.quantum_policy,
            "moves": len(moves),
            "bytes_moved": int(sum(m[2] for m in moves)),
            "max_move": int(max((m[2] for m in moves), default=0)),
            "summary_bytes": int(sum(s.payload_bytes() for s in summaries)),
            "ghost_mass": int(sum(s.ghost_mass for s in summaries)),
            "hot_blocks": len(hot.counts) if hot is not None else 0,
        }
        self.last_stats = stat
        self.round_log.append(stat)
        if len(self.round_log) > 4096:
            del self.round_log[:len(self.round_log) - 4096]
        return stat

    def urgent(self, shards: Sequence[IGTCache]) -> bool:
        """True when some workload CMU sits below its minimum share — a
        stream created after the defaults drained would otherwise wait a
        full period with zero quota (adaptive policy only)."""
        if self.cfg.quantum_policy == "fixed":
            return False
        for eng in shards:
            for _, c in eng.iter_workload_cmus():
                if c.quota < self.cfg.min_share:
                    return True
        return False

    def urgent_due(self, now: float, shards: Sequence[IGTCache]) -> bool:
        """Rate-limited starvation trigger: an early round may fire at
        most every period/4 (a starving CMU with no donors anywhere must
        not force a round per tick)."""
        if now - self.last_round < max(1.0, self.cfg.rebalance_period / 4):
            return False
        return self.urgent(shards)

    def rebalance_shards(self, shards: Sequence[IGTCache], now: float,
                         max_moves: Optional[int] = None) -> List[tuple]:
        """In-process round: summarize each shard (the same rows a worker
        would ship), plan with the shared greedy rule, apply to the live
        engines.  A cross-shard move shifts CMU quota and backing pool
        capacity together, so total capacity is conserved and every
        shard keeps ``sum(quota) == capacity``.

        The facade plans over the full row set (it holds the live
        objects anyway); the process driver plans over the wire
        summaries' capped rows.  The cap only binds past ``cfg.topk``
        workload CMUs per shard, where the tail carries negligible
        weight by construction."""
        self.last_round = now
        rows: List[DemandSummary] = []
        live: List[CacheManageUnit] = []     # rows[i] describes live[i]
        owner: List[IGTCache] = []
        summaries: List[ShardSummary] = []
        for sid, eng in enumerate(shards):
            for row, cmu in self.tracker.summarize(eng, sid, now,
                                                   mark=False):
                rows.append(row)
                live.append(cmu)
                owner.append(eng)
            got = self.tracker.summaries.get(sid)
            if got is not None:
                summaries.append(got)
        self.tracker.mark_all(live)
        index = {id(r): i for i, r in enumerate(rows)}
        moves: List[tuple] = []
        if len(shards) < 2:
            self.note_round(now, summaries, moves)
            return moves
        for d_row, t_row, amt in self.plan_moves(rows, max_moves):
            donor, taker = live[index[id(d_row)]], live[index[id(t_row)]]
            d_eng, t_eng = owner[index[id(d_row)]], owner[index[id(t_row)]]
            donor.set_quota(donor.quota - amt)
            d_eng.cache.adjust_capacity(-amt)
            t_eng.cache.adjust_capacity(amt)
            taker.set_quota(taker.quota + amt)
            moves.append((donor, taker, amt))
        self.note_round(now, summaries, moves)
        return moves


class ShardDemandTracker:
    """Per-shard demand summarization for the cross-shard round.

    Lives next to the engine it measures: in-process the facade's
    ``GlobalRebalancer`` holds one for all shards; under the process
    driver each worker holds its own and ships the rows over the pipe
    (the ``rebalance_summary`` command).  SKEWED demand is measured from
    the BufferWindows' *cumulative* counters as deltas over this
    tracker's own round interval — shard-local rounds reset the
    per-round counters on their own read-triggered phase, so the
    cumulative delta is the only phase-independent signal (see
    ``allocation.BufferWindow``)."""

    def __init__(self, cfg: CacheConfig) -> None:
        self.cfg = cfg
        # cmu -> (total_hits, total_probes) at the end of our last round
        self._ghost_mark: Dict[CacheManageUnit, Tuple[int, int]] = {}
        # cmu -> EMA-smoothed benefit (adaptive policy only): want-sized
        # moves amplify one noisy interval into a large transfer, so the
        # planner sees a half-life-one-round smoothed B instead
        self._ema: Dict[CacheManageUnit, float] = {}
        # sid -> last wire summary built by summarize()
        self.summaries: Dict[int, ShardSummary] = {}

    def estimate(self, cmu: CacheManageUnit, now: float) -> DemandEstimate:
        est = marginal_benefit(cmu, now, self.cfg)
        if cmu.effective_pattern() is Pattern.SKEWED:
            bw = cmu.buffer_window
            th, tp = self._ghost_mark.get(cmu, (0, 0))
            dh, dp = bw.total_hits - th, bw.total_probes - tp
            f = dh / dp if dp else 0.0
            est = DemandEstimate(cmu.arrival_rate(now) * f / bw.w,
                                 dh > 0, est.can_shrink)
        if self.cfg.quantum_policy != "fixed":
            prev = self._ema.get(cmu)
            b = est.benefit if prev is None else 0.5 * prev + 0.5 * est.benefit
            self._ema[cmu] = b
            est = DemandEstimate(b, est.wants_more, est.can_shrink)
        return est

    def _row(self, cmu: CacheManageUnit, sid: int, now: float,
             can_take: bool, sketch=None) -> DemandSummary:
        est = self.estimate(cmu, now)
        limit: Optional[float] = None
        pat = cmu.effective_pattern()
        min_share = self.cfg.min_share
        if pat is Pattern.RANDOM:
            limit = float(cmu.dataset_bytes)
        elif pat is Pattern.UNKNOWN and can_take:
            # wants_more was `used >= 0.95 * quota` — express as a quota
            # threshold so mid-round moves re-evaluate it
            limit = cmu.used / 0.95 if cmu.used else 0.0
        # ---- adaptive sizing: want / floor / free ----------------------
        want = 0
        floor = min_share
        if can_take:
            if pat is Pattern.RANDOM:
                # insatiable below the dataset (paper §3.3)
                want = max(0, cmu.dataset_bytes - cmu.quota)
            elif pat is Pattern.SKEWED:
                # unmet working set = distinct ghost-hit blocks this
                # interval: tracked heavy hitters exactly (SpaceSaving
                # lower bounds), the cold tail upper-bounded by the
                # unattributed ghost-hit mass (>= 1 hit per block)
                th, _tp = self._ghost_mark.get(cmu, (0, 0))
                dh = cmu.buffer_window.total_hits - th
                if dh > 0:
                    distinct = dh
                    if sketch is not None and sketch.cms.total > 0:
                        head, head_mass = sketch.distinct_under(
                            path_key(cmu.root_path) + "/")
                        distinct = head + max(0, dh - head_mass)
                    want = distinct * self.cfg.block_size
            elif pat is Pattern.UNKNOWN:
                if cmu.used >= 0.95 * cmu.quota:
                    want = max(0, int(cmu.used / 0.95) - cmu.quota)
            else:       # SEQUENTIAL: wants nothing beyond its prefetch
                # window, but squeezing an *active* stream below that
                # window thrashes the readahead (issue → evict before
                # access), so the floor covers it
                if cmu.arrival_rate(now) > 1e-3:
                    floor = max(min_share, self.cfg.prefetch_budget_bytes)
            if cmu.dataset_bytes:
                want = min(want, max(0, cmu.dataset_bytes - cmu.quota))
                floor = min(floor, cmu.dataset_bytes)
        if can_take or self.cfg.quantum_policy == "fixed":
            headroom = cmu.quota - min_share
        else:
            # zero-floor defaults (adaptive): a shard default exists to
            # lend capacity, reserving min_share on every one of N
            # shards locks away N×min_share the workload CMUs need
            headroom = cmu.quota
        return DemandSummary(
            shard=sid, key=cmu.root_path, benefit=est.benefit,
            wants_more=est.wants_more, can_take=can_take, quota=cmu.quota,
            headroom=headroom, demand_limit=limit, want=int(want),
            floor=int(floor), free=max(0, cmu.quota - cmu.used))

    def summarize(self, eng: IGTCache, sid: int, now: float,
                  mark: bool = True
                  ) -> List[Tuple[DemandSummary, CacheManageUnit]]:
        """Demand rows for one shard, plus the wire :class:`ShardSummary`
        (stashed in ``self.summaries[sid]``).

        The shard's *default* CMU is included as a donor-only row
        (``can_take=False``): a shard whose datasets happen to be
        all-sequential — or that drew no dataset at all — must not hold
        1/N of the cluster capacity hostage.  Mirrors the shard-local
        round, which also passes the default CMU as a donor.

        One demand-sketch measurement interval spans one call: the
        sketch is folded, read for the rows, serialized into the wire
        summary, then reset.

        ``mark=True`` (the single-shard / worker-resident case) advances
        the ghost marks to now; a tracker measuring several shards must
        pass ``mark=False`` per shard and call :meth:`mark_all` once
        with every shard's CMUs — marking per shard would reset the
        other shards' intervals early."""
        sketch = getattr(eng.cache, "demand_sketch", None)
        if sketch is not None:
            sketch.fold()
        pairs: List[Tuple[DemandSummary, CacheManageUnit]] = []
        for c in eng.workload_cmus():
            pairs.append((self._row(c, sid, now, True, sketch), c))
        d = eng.cache.default_cmu
        pairs.append((self._row(d, sid, now, False, sketch), d))
        self.summaries[sid] = self._wire(sid, [r for r, _ in pairs], sketch)
        if sketch is not None:
            sketch.reset()
        if mark:
            self.mark_all(c for _, c in pairs)
        return pairs

    def _wire(self, sid: int, rows: List[DemandSummary],
              sketch) -> ShardSummary:
        """Bounded wire summary: default row + top-k workload rows by
        demand weight, tail aggregated, sketches serialized."""
        work = [r for r in rows if r.can_take]
        work.sort(key=lambda r: -(r.want + max(0, r.headroom)))
        keep, tail = work[:self.cfg.topk], work[self.cfg.topk:]
        return ShardSummary(
            shard=sid,
            rows=[r for r in rows if not r.can_take] + keep,
            n_cmus=len(work),
            tail_cmus=len(tail),
            tail_quota=sum(r.quota for r in tail),
            tail_want=sum(r.want for r in tail),
            ghost_mass=sketch.noted if sketch is not None else 0,
            cms_payload=(sketch.cms.serialize()
                         if sketch is not None and sketch.cms.total else b""),
            topk_payload=(sketch.topk.serialize()
                          if sketch is not None and sketch.topk.counts
                          else b""))

    def mark_all(self, cmus) -> None:
        """Start the next measurement interval at the current cumulative
        ghost counters.  Marks (and benefit EMAs) of CMUs no longer
        summarized — TTL-removed or evicted since last round — are
        pruned in the same pass, so the tables stay bounded by the live
        CMU population without rebuilding the dict every round."""
        marks = self._ghost_mark
        seen = set()
        for c in cmus:
            marks[c] = (c.buffer_window.total_hits,
                        c.buffer_window.total_probes)
            seen.add(id(c))
        stale = [c for c in marks if id(c) not in seen]
        for c in stale:
            del marks[c]
            self._ema.pop(c, None)


def split_capacity(capacity: int, n_shards: int) -> List[int]:
    """Initial per-shard capacity partition (both drivers use this)."""
    base, rem = divmod(capacity, n_shards)
    return [base + (1 if i < rem else 0) for i in range(n_shards)]


class ShardedIGTCache(ShardRouting):
    """N path-hash ``IGTCache`` shards behind the engine's public API.

    Exactly the surface callers use — ``read``, ``read_batch``,
    ``read_serial``, ``complete_prefetch``, ``cancel_prefetch``, ``pin``,
    ``never_cache``, ``tick``, ``stats``, ``hit_ratio``, ``snapshot`` —
    so the cluster simulator, the training pipeline and the baselines run
    sharded without knowing it.
    """

    def __init__(self, meta: StoreMeta, capacity: int,
                 cfg: Optional[CacheConfig] = None,
                 options: Optional[EngineOptions] = None,
                 n_shards: int = 1) -> None:
        self._init_routing(n_shards)
        self.meta = meta
        self.cfg = cfg or CacheConfig()
        self.options = options or EngineOptions()
        self.capacity = capacity
        self.shards: List[IGTCache] = [
            IGTCache(meta, cap, cfg=self.cfg, options=self.options)
            for cap in split_capacity(capacity, n_shards)
        ]
        self.global_rebalancer = GlobalRebalancer(self.cfg)

    # ------------------------------------------------------------- routing
    def shard_for(self, path: PathT) -> IGTCache:
        return self.shards[self.shard_id(path)]

    # ------------------------------------------------------------ user API
    def pin(self, path: PathT) -> None:
        for s in self.shards:          # prefix may be shorter than the
            s.pin(path)                # routing key — broadcast is exact

    def never_cache(self, path: PathT) -> None:
        for s in self.shards:
            s.never_cache(path)

    def invalidate_meta_cache(self) -> None:
        for s in self.shards:
            s.invalidate_meta_cache()

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: float) -> ReadOutcome:
        return self.shard_for(file_path).read(file_path, offset, size, now)

    def read_serial(self, file_path: PathT, offset: int, size: int,
                    now: float) -> ReadOutcome:
        return self.shard_for(file_path).read_serial(file_path, offset,
                                                     size, now)

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: float) -> List[ReadOutcome]:
        """Split the batch by shard, serve each sub-batch on its shard
        (tick cadence amortized per shard, as in the unsharded engine),
        and reassemble outcomes in the original request order."""
        if self.n_shards == 1:
            return self.shards[0].read_batch(requests, now)
        buckets = self.bucket_by_shard(requests)
        outs: List[Optional[ReadOutcome]] = [None] * len(requests)
        for sid, items in buckets.items():
            got = self.shards[sid].read_batch([r for _, r in items], now)
            for (i, _), out in zip(items, got):
                outs[i] = out
        return outs  # type: ignore[return-value]

    # ------------------------------------------------------------- prefetch
    def complete_prefetch(self, path: PathT, size: int, now: float) -> bool:
        return self.shard_for(path).complete_prefetch(path, size, now)

    def cancel_prefetch(self, path: PathT) -> None:
        self.shard_for(path).cancel_prefetch(path)

    # ------------------------------------------------------------------ tick
    def tick(self, now: float) -> None:
        """Per-shard maintenance plus, when due, the cross-shard allocation
        round.  The global layer is phase-independent of the shards'
        read-triggered local rounds: SKEWED demand is measured from
        cumulative ghost counters over the global round's own interval
        (see GlobalRebalancer), so ordering here is not load-bearing."""
        if self.n_shards > 1 and self.options.allocation == "adaptive":
            gr = self.global_rebalancer
            if gr.due(now) or gr.urgent_due(now, self.shards):
                gr.rebalance_shards(self.shards, now)
        for s in self.shards:
            s.tick(now)

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> CacheStats:
        """Point-in-time merge of the shards' counters.

        Unlike ``IGTCache.stats`` this is a *snapshot*, not the live
        counter object — re-read the property for fresh values.  The
        semantic is deliberately identical at every shard count (a live
        view would only be possible at ``n_shards == 1``)."""
        return CacheStats.merged(s.stats for s in self.shards)

    def hit_ratio(self) -> float:
        return self.stats.hit_ratio

    def used_bytes(self) -> int:
        return sum(s.cache.used_bytes() for s in self.shards)

    def node_count(self) -> int:
        return sum(s.tree.node_count() for s in self.shards)

    def workload_cmus(self) -> List[CacheManageUnit]:
        return [c for s in self.shards for c in s.workload_cmus()]

    def iter_workload_cmus(self):
        for s in self.shards:
            yield from s.iter_workload_cmus()

    def shard_capacities(self) -> List[int]:
        return [s.cache.capacity for s in self.shards]

    def snapshot(self) -> dict:
        s = self.stats.snapshot()
        s["nodes"] = self.node_count()
        s["cmus"] = sum(len(sh.cache.cmus) - 1 for sh in self.shards)
        s["used_bytes"] = self.used_bytes()
        return s

    # ---------------------------------------------------------- warm restart
    def warm_state(self) -> dict:
        """Cluster-wide warm-restart manifest: per-shard CMU/residency
        manifests merged (every key names its shard via path routing, so
        the merge loses nothing); pins/bans are broadcast state — any
        shard's copy is the full set."""
        states = [s.warm_state() for s in self.shards]
        merged = {"cmus": [], "resident": [], "verdicts": {},
                  "pins": states[0]["pins"],
                  "never_cache": states[0]["never_cache"]}
        for st in states:
            merged["cmus"].extend(st["cmus"])
            merged["resident"].extend(st["resident"])
            merged["verdicts"].update(st["verdicts"])
        return merged

    def warm_admit(self, state: dict, now: float) -> dict:
        """Route a merged manifest back onto the shards (the same
        path-hash routing reads use, so every entry lands on the shard
        that journaled it) and sum the restore counters."""
        per = [{"cmus": [], "resident": [], "verdicts": {},
                "pins": state.get("pins", ()),
                "never_cache": state.get("never_cache", ())}
               for _ in self.shards]
        for row in state.get("cmus", ()):
            per[self.shard_id(tuple(row["root"]))]["cmus"].append(row)
        for key, size in state.get("resident", ()):
            per[self.shard_id(tuple(key.split("/")))]["resident"].append(
                (key, size))
        for top, verdict in (state.get("verdicts") or {}).items():
            per[self.shard_id((str(top),))]["verdicts"][top] = verdict
        total: Dict[str, int] = {}
        for shard, st in zip(self.shards, per):
            got = shard.warm_admit(st, now)
            for k, v in got.items():
                total[k] = total.get(k, 0) + v
        # pins/bans were replayed once per shard; report the set size
        total["pins"] = len(state.get("pins", ()))
        return total


# Either engine satisfies the same public read/prefetch/tick/stats surface;
# callers (cluster sim, training pipeline, benchmarks) annotate with this.
Engine = Union[IGTCache, ShardedIGTCache]


def make_engine(meta: StoreMeta, capacity: int,
                cfg: Optional[CacheConfig] = None,
                options: Optional[EngineOptions] = None,
                n_shards: int = 1) -> Engine:
    """Engine constructor shared by sim/benchmarks/examples: the plain
    state machine for ``n_shards=1`` (zero facade overhead), the sharded
    facade otherwise."""
    if n_shards == 1:
        return IGTCache(meta, capacity, cfg=cfg, options=options)
    return ShardedIGTCache(meta, capacity, cfg=cfg, options=options,
                           n_shards=n_shards)

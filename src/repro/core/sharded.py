"""Path-hash sharded engine behind a unified facade (scaling PR).

The paper's engine is one Python state machine; every access of every job
serializes through it.  ``ShardedIGTCache`` splits the *observe/recognize*
hot path into N independent ``IGTCache`` shards — each with its own
AccessStreamTree, chain/ctx caches, LevelCache and ``UnifiedCache``
partition — while keeping *space allocation* cluster-wide, the split Hoard
(arXiv:1812.00669) uses for distributed DL caches (shard by key, global
placement view).

Routing granularity: the **top-level path component** (the dataset root).
A whole dataset maps to one shard, so every AccessStream — directory
levels, file level, block level, and the CMU's flattened dataset-granular
window — observes exactly the accesses it would observe unsharded:
recognition state is bitwise-identical per dataset, and sharding only
partitions *capacity*.  That skew (a hot random dataset stuck in a
quarter-capacity shard next to sequential streams that need nothing) is
what the cross-shard ``GlobalRebalancer`` repairs: it merges per-CMU
``marginal_benefit`` estimates across shards and moves quota *and the
backing shard capacity* from the cluster-wide minimum-benefit donor to the
maximum-benefit taker, so the paper's skew-aware space allocation (§4.3)
still operates over the whole cache.

``ShardedIGTCache(n_shards=1)`` is bitwise-identical to ``IGTCache`` on
any trace (tests/test_equivalence.py pins this): one shard holds the full
capacity, every call forwards to it, and the global layer stays inert.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .allocation import DemandEstimate, Rebalancer, marginal_benefit
from .cache import CacheManageUnit
from .igtcache import EngineOptions, IGTCache, ReadOutcome
from .meta import StoreMeta
from .types import CacheConfig, CacheStats, PathT, Pattern


def shard_index(path: PathT, n_shards: int) -> int:
    """Deterministic shard for a path: CRC-32 of the top-level component.

    Stable across processes and runs (unlike the salted builtin ``hash``),
    so the same path always lands on the same shard — the routing invariant
    tests/test_sharded.py pins.
    """
    if n_shards <= 1:
        return 0
    top = path[0] if path else ""
    return zlib.crc32(top.encode("utf-8")) % n_shards


class GlobalRebalancer(Rebalancer):
    """Cross-shard space allocation: the paper's greedy max-B ← min-B rule
    over the *merged* CMU population of all shards.

    Within a shard, the per-shard ``Rebalancer`` (inside each ``IGTCache``
    tick) already shifts quota between co-located CMUs; this layer handles
    the moves those rounds cannot see — donor and taker living in
    *different* shards.  A cross-shard move shifts both the CMU quota and
    the backing pool capacity (``UnifiedCache.adjust_capacity``), so total
    capacity is conserved and every shard keeps ``sum(quota) == capacity``.

    Ghost-window coherence: shard-local rounds fire on each shard's own
    read-triggered tick cadence and reset the per-round BufferWindow
    counters, so at global-round time the windows of different shards span
    different (phase-dependent) intervals.  SKEWED demand is therefore
    measured from the windows' *cumulative* counters as a delta over this
    layer's own round interval — every CMU is compared over the same span
    of simulated time regardless of local reset phase.  The other patterns'
    benefits don't read the per-round window, so ``marginal_benefit`` is
    used as-is.
    """

    def __init__(self, cfg: CacheConfig) -> None:
        super().__init__(cfg)
        # cmu -> (total_hits, total_probes) at the end of our last round
        self._ghost_mark: Dict[CacheManageUnit, Tuple[int, int]] = {}

    def _estimate(self, cmu: CacheManageUnit, now: float) -> DemandEstimate:
        est = marginal_benefit(cmu, now, self.cfg)
        if cmu.effective_pattern() is Pattern.SKEWED:
            bw = cmu.buffer_window
            th, tp = self._ghost_mark.get(cmu, (0, 0))
            dh, dp = bw.total_hits - th, bw.total_probes - tp
            f = dh / dp if dp else 0.0
            est = DemandEstimate(cmu.arrival_rate(now) * f / bw.w,
                                 dh > 0, est.can_shrink)
        return est

    def rebalance_shards(self, shards: Sequence[IGTCache], now: float,
                         max_moves: Optional[int] = None) -> List[tuple]:
        self.last_round = now
        owner: Dict[CacheManageUnit, IGTCache] = {}
        takers_pool: List[CacheManageUnit] = []
        donors_pool: List[CacheManageUnit] = []
        for eng in shards:
            for c in eng.workload_cmus():
                owner[c] = eng
                takers_pool.append(c)
                donors_pool.append(c)
            # A shard's *default* CMU donates cross-shard too (never takes):
            # otherwise a shard whose datasets happen to be all-sequential —
            # or that drew no dataset at all — holds 1/N of the cluster
            # capacity hostage.  Mirrors the shard-local round, which also
            # passes the default CMU to the rebalancer as a donor.
            d = eng.cache.default_cmu
            owner[d] = eng
            donors_pool.append(d)
        moves: List[tuple] = []
        if not takers_pool or len(shards) < 2:
            self._mark_ghosts(donors_pool)
            return moves
        if max_moves is None:
            max_moves = len(donors_pool)
        est = {c: self._estimate(c, now) for c in donors_pool}
        for _ in range(max_moves):
            takers = [c for c in takers_pool if est[c].wants_more]
            if not takers:
                break
            taker = max(takers, key=lambda c: est[c].benefit)
            # donors restricted to OTHER shards: co-located pairs are the
            # shard-local rebalancer's job
            donors = [c for c in donors_pool
                      if est[c].can_shrink and owner[c] is not owner[taker]]
            got = self.pick_move(est, donors, [taker])
            if got is None:
                break
            donor, taker, amt = got
            d_eng, t_eng = owner[donor], owner[taker]
            donor.set_quota(donor.quota - amt)
            d_eng.cache.adjust_capacity(-amt)
            t_eng.cache.adjust_capacity(amt)
            taker.set_quota(taker.quota + amt)
            moves.append((donor, taker, amt))
            est[donor] = self._estimate(donor, now)
            est[taker] = self._estimate(taker, now)
        self._mark_ghosts(donors_pool)
        return moves

    def _mark_ghosts(self, cmus: Sequence[CacheManageUnit]) -> None:
        """Start the next measurement interval at the current cumulative
        ghost counters (dropping marks of TTL-removed CMUs)."""
        self._ghost_mark = {
            c: (c.buffer_window.total_hits, c.buffer_window.total_probes)
            for c in cmus}


class ShardedIGTCache:
    """N path-hash ``IGTCache`` shards behind the engine's public API.

    Exactly the surface callers use — ``read``, ``read_batch``,
    ``read_serial``, ``complete_prefetch``, ``cancel_prefetch``, ``pin``,
    ``never_cache``, ``tick``, ``stats``, ``hit_ratio``, ``snapshot`` —
    so the cluster simulator, the training pipeline and the baselines run
    sharded without knowing it.
    """

    def __init__(self, meta: StoreMeta, capacity: int,
                 cfg: Optional[CacheConfig] = None,
                 options: Optional[EngineOptions] = None,
                 n_shards: int = 1) -> None:
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.meta = meta
        self.cfg = cfg or CacheConfig()
        self.options = options or EngineOptions()
        self.n_shards = n_shards
        self.capacity = capacity
        base, rem = divmod(capacity, n_shards)
        self.shards: List[IGTCache] = [
            IGTCache(meta, base + (1 if i < rem else 0), cfg=self.cfg,
                     options=self.options)
            for i in range(n_shards)
        ]
        self.global_rebalancer = GlobalRebalancer(self.cfg)
        # top-level component -> shard id (datasets are few; unbounded is fine)
        self._route: Dict[str, int] = {}

    # ------------------------------------------------------------- routing
    def shard_id(self, path: PathT) -> int:
        if self.n_shards == 1:
            return 0
        top = path[0] if path else ""
        sid = self._route.get(top)
        if sid is None:
            sid = shard_index(path, self.n_shards)
            self._route[top] = sid
        return sid

    def shard_for(self, path: PathT) -> IGTCache:
        return self.shards[self.shard_id(path)]

    # ------------------------------------------------------------ user API
    def pin(self, path: PathT) -> None:
        for s in self.shards:          # prefix may be shorter than the
            s.pin(path)                # routing key — broadcast is exact

    def never_cache(self, path: PathT) -> None:
        for s in self.shards:
            s.never_cache(path)

    def invalidate_meta_cache(self) -> None:
        for s in self.shards:
            s.invalidate_meta_cache()

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: float) -> ReadOutcome:
        return self.shard_for(file_path).read(file_path, offset, size, now)

    def read_serial(self, file_path: PathT, offset: int, size: int,
                    now: float) -> ReadOutcome:
        return self.shard_for(file_path).read_serial(file_path, offset,
                                                     size, now)

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: float) -> List[ReadOutcome]:
        """Split the batch by shard, serve each sub-batch on its shard
        (tick cadence amortized per shard, as in the unsharded engine),
        and reassemble outcomes in the original request order."""
        if self.n_shards == 1:
            return self.shards[0].read_batch(requests, now)
        buckets: Dict[int, List[Tuple[int, Tuple[PathT, int, int]]]] = {}
        for i, req in enumerate(requests):
            buckets.setdefault(self.shard_id(req[0]), []).append((i, req))
        outs: List[Optional[ReadOutcome]] = [None] * len(requests)
        for sid, items in buckets.items():
            got = self.shards[sid].read_batch([r for _, r in items], now)
            for (i, _), out in zip(items, got):
                outs[i] = out
        return outs  # type: ignore[return-value]

    # ------------------------------------------------------------- prefetch
    def complete_prefetch(self, path: PathT, size: int, now: float) -> bool:
        return self.shard_for(path).complete_prefetch(path, size, now)

    def cancel_prefetch(self, path: PathT) -> None:
        self.shard_for(path).cancel_prefetch(path)

    # ------------------------------------------------------------------ tick
    def tick(self, now: float) -> None:
        """Per-shard maintenance plus, when due, the cross-shard allocation
        round.  The global layer is phase-independent of the shards'
        read-triggered local rounds: SKEWED demand is measured from
        cumulative ghost counters over the global round's own interval
        (see GlobalRebalancer), so ordering here is not load-bearing."""
        if (self.n_shards > 1 and self.options.allocation == "adaptive"
                and self.global_rebalancer.due(now)):
            self.global_rebalancer.rebalance_shards(self.shards, now)
        for s in self.shards:
            s.tick(now)

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> CacheStats:
        """Point-in-time merge of the shards' counters.

        Unlike ``IGTCache.stats`` this is a *snapshot*, not the live
        counter object — re-read the property for fresh values.  The
        semantic is deliberately identical at every shard count (a live
        view would only be possible at ``n_shards == 1``)."""
        return CacheStats.merged(s.stats for s in self.shards)

    def hit_ratio(self) -> float:
        return self.stats.hit_ratio

    def used_bytes(self) -> int:
        return sum(s.cache.used_bytes() for s in self.shards)

    def node_count(self) -> int:
        return sum(s.tree.node_count() for s in self.shards)

    def workload_cmus(self) -> List[CacheManageUnit]:
        return [c for s in self.shards for c in s.workload_cmus()]

    def iter_workload_cmus(self):
        for s in self.shards:
            yield from s.iter_workload_cmus()

    def shard_capacities(self) -> List[int]:
        return [s.cache.capacity for s in self.shards]

    def snapshot(self) -> dict:
        s = self.stats.snapshot()
        s["nodes"] = self.node_count()
        s["cmus"] = sum(len(sh.cache.cmus) - 1 for sh in self.shards)
        s["used_bytes"] = self.used_bytes()
        return s


# Either engine satisfies the same public read/prefetch/tick/stats surface;
# callers (cluster sim, training pipeline, benchmarks) annotate with this.
Engine = Union[IGTCache, ShardedIGTCache]


def make_engine(meta: StoreMeta, capacity: int,
                cfg: Optional[CacheConfig] = None,
                options: Optional[EngineOptions] = None,
                n_shards: int = 1) -> Engine:
    """Engine constructor shared by sim/benchmarks/examples: the plain
    state machine for ``n_shards=1`` (zero facade overhead), the sharded
    facade otherwise."""
    if n_shards == 1:
        return IGTCache(meta, capacity, cfg=cfg, options=options)
    return ShardedIGTCache(meta, capacity, cfg=cfg, options=options,
                           n_shards=n_shards)

"""Path-hash sharded engine behind a unified facade (scaling PR).

The paper's engine is one Python state machine; every access of every job
serializes through it.  ``ShardedIGTCache`` splits the *observe/recognize*
hot path into N independent ``IGTCache`` shards — each with its own
AccessStreamTree, chain/ctx caches, LevelCache and ``UnifiedCache``
partition — while keeping *space allocation* cluster-wide, the split Hoard
(arXiv:1812.00669) uses for distributed DL caches (shard by key, global
placement view).

Routing granularity: the **top-level path component** (the dataset root).
A whole dataset maps to one shard, so every AccessStream — directory
levels, file level, block level, and the CMU's flattened dataset-granular
window — observes exactly the accesses it would observe unsharded:
recognition state is bitwise-identical per dataset, and sharding only
partitions *capacity*.  That skew (a hot random dataset stuck in a
quarter-capacity shard next to sequential streams that need nothing) is
what the cross-shard ``GlobalRebalancer`` repairs: it merges per-CMU
``marginal_benefit`` estimates across shards and moves quota *and the
backing shard capacity* from the cluster-wide minimum-benefit donor to the
maximum-benefit taker, so the paper's skew-aware space allocation (§4.3)
still operates over the whole cache.

``ShardedIGTCache(n_shards=1)`` is bitwise-identical to ``IGTCache`` on
any trace (tests/test_equivalence.py pins this): one shard holds the full
capacity, every call forwards to it, and the global layer stays inert.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .allocation import DemandEstimate, Rebalancer, marginal_benefit
from .cache import CacheManageUnit
from .igtcache import EngineOptions, IGTCache, ReadOutcome
from .meta import StoreMeta
from .types import CacheConfig, CacheStats, PathT, Pattern


def shard_index(path: PathT, n_shards: int) -> int:
    """Deterministic shard for a path: CRC-32 of the top-level component.

    Stable across processes and runs (unlike the salted builtin ``hash``),
    so the same path always lands on the same shard — the routing invariant
    tests/test_sharded.py pins.
    """
    if n_shards <= 1:
        return 0
    top = path[0] if path else ""
    return zlib.crc32(top.encode("utf-8")) % n_shards


class ShardRouting:
    """Memoized path → shard routing, shared by every shard driver.

    The CRC-32 of the top-level component is computed **once per
    dataset**: routing for every subsequent access of that dataset is a
    single dict lookup (datasets are few; the memo is unbounded by
    design).  Both the in-process ``ShardedIGTCache`` facade and the
    multi-process ``core.procdriver.ProcessShardedCache`` inherit this,
    so the two drivers cannot drift on placement — a path routes to the
    same shard index under either."""

    def _init_routing(self, n_shards: int) -> None:
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        # top-level component -> shard id (memoized CRC-32)
        self._route: Dict[str, int] = {}

    def shard_id(self, path: PathT) -> int:
        if self.n_shards == 1:
            return 0
        top = path[0] if path else ""
        sid = self._route.get(top)
        if sid is None:
            sid = shard_index(path, self.n_shards)
            self._route[top] = sid
        return sid

    def bucket_by_shard(self, items: Sequence,
                        path_of=None) -> Dict[int, List[tuple]]:
        """Group indexed items by owning shard:
        ``{sid: [(original_index, item), ...]}`` — the one split-and-
        reassemble-in-order primitive every batched fan-out uses (both
        drivers' ``read_batch``, both executors' ``fetch_demand``), so
        ordering/empty-bucket edge cases cannot drift between copies.
        ``path_of`` extracts the routing path (default: ``item[0]``,
        the shape of read requests and range requests)."""
        buckets: Dict[int, List[tuple]] = {}
        if path_of is None:
            for i, item in enumerate(items):
                buckets.setdefault(self.shard_id(item[0]), []).append(
                    (i, item))
        else:
            for i, item in enumerate(items):
                buckets.setdefault(self.shard_id(path_of(item)), []).append(
                    (i, item))
        return buckets


@dataclass
class DemandSummary:
    """One CMU's demand estimate, serialized for the cross-shard
    allocation round.

    This is the wire format of the rebalance-summary protocol: worker
    processes ship these rows to the driver instead of live
    ``CacheManageUnit`` objects, and the in-process facade builds the
    same rows from its shards, so both drivers run the identical greedy
    rule (``GlobalRebalancer.plan_moves``).  ``demand_limit`` carries
    enough state to re-evaluate ``wants_more`` after a mid-round quota
    move (RANDOM streams stop wanting at ``dataset_bytes``); patterns
    whose demand does not depend on quota leave it ``None``.
    """

    shard: int                 # owning shard index
    key: PathT                 # CMU root path (unique within its shard)
    benefit: float             # marginal benefit B (quota-independent)
    wants_more: bool           # unmet demand at current quota
    can_take: bool             # workload CMU; shard defaults only donate
    quota: int
    headroom: int              # quota - min_share (donatable bytes)
    demand_limit: Optional[float] = None   # wants_more := quota < limit


class GlobalRebalancer(Rebalancer):
    """Cross-shard space allocation: the paper's greedy max-B ← min-B rule
    over the *merged* CMU population of all shards.

    Within a shard, the per-shard ``Rebalancer`` (inside each ``IGTCache``
    tick) already shifts quota between co-located CMUs; this layer handles
    the moves those rounds cannot see — donor and taker living in
    *different* shards.  A cross-shard move shifts both the CMU quota and
    the backing pool capacity (``UnifiedCache.adjust_capacity``), so total
    capacity is conserved and every shard keeps ``sum(quota) == capacity``.

    Ghost-window coherence: shard-local rounds fire on each shard's own
    read-triggered tick cadence and reset the per-round BufferWindow
    counters, so at global-round time the windows of different shards span
    different (phase-dependent) intervals.  SKEWED demand is therefore
    measured from the windows' *cumulative* counters as a delta over this
    layer's own round interval — every CMU is compared over the same span
    of simulated time regardless of local reset phase.  The other patterns'
    benefits don't read the per-round window, so ``marginal_benefit`` is
    used as-is.
    """

    def __init__(self, cfg: CacheConfig) -> None:
        super().__init__(cfg)
        self.tracker = ShardDemandTracker(cfg)

    def _estimate(self, cmu: CacheManageUnit, now: float) -> DemandEstimate:
        return self.tracker.estimate(cmu, now)

    def plan_moves(self, rows: Sequence[DemandSummary],
                   max_moves: Optional[int] = None
                   ) -> List[Tuple[DemandSummary, DemandSummary, int]]:
        """The paper's greedy max-B ← min-B rule over serialized demand
        rows — pure planning, no engine access.  Both drivers run this:
        the in-process facade applies the returned moves to live CMUs,
        the process driver ships them to workers as quota/capacity
        deltas.  Rows are mutated in place (quota, headroom,
        ``wants_more`` via ``demand_limit``) so successive moves see the
        post-move state, exactly like the live-object round did."""
        moves: List[Tuple[DemandSummary, DemandSummary, int]] = []
        if not rows or len({r.shard for r in rows}) < 2:
            return moves
        if max_moves is None:
            max_moves = len(rows)
        quantum = self.cfg.rebalance_quantum
        for _ in range(max_moves):
            takers = [r for r in rows if r.can_take and r.wants_more]
            if not takers:
                break
            taker = max(takers, key=lambda r: r.benefit)
            # donors restricted to OTHER shards: co-located pairs are the
            # shard-local rebalancer's job
            donors = [r for r in rows
                      if r.headroom >= quantum and r.shard != taker.shard]
            if not donors:
                break
            donor = min(donors, key=lambda r: r.benefit)
            if not self.clears_hysteresis(donor.benefit, taker.benefit):
                break
            amt = min(quantum, donor.headroom)
            if amt <= 0:
                break
            for row, delta in ((donor, -amt), (taker, amt)):
                row.quota += delta
                row.headroom += delta
                if row.demand_limit is not None:
                    row.wants_more = row.quota < row.demand_limit
            moves.append((donor, taker, amt))
        return moves

    def rebalance_shards(self, shards: Sequence[IGTCache], now: float,
                         max_moves: Optional[int] = None) -> List[tuple]:
        """In-process round: summarize each shard (the same rows a worker
        would ship), plan with the shared greedy rule, apply to the live
        engines.  A cross-shard move shifts CMU quota and backing pool
        capacity together, so total capacity is conserved and every
        shard keeps ``sum(quota) == capacity``."""
        self.last_round = now
        rows: List[DemandSummary] = []
        live: List[CacheManageUnit] = []     # rows[i] describes live[i]
        owner: List[IGTCache] = []
        for sid, eng in enumerate(shards):
            for row, cmu in self.tracker.summarize(eng, sid, now,
                                                   mark=False):
                rows.append(row)
                live.append(cmu)
                owner.append(eng)
        self.tracker.mark_all(live)
        index = {id(r): i for i, r in enumerate(rows)}
        moves: List[tuple] = []
        if len(shards) < 2:
            return moves
        for d_row, t_row, amt in self.plan_moves(rows, max_moves):
            donor, taker = live[index[id(d_row)]], live[index[id(t_row)]]
            d_eng, t_eng = owner[index[id(d_row)]], owner[index[id(t_row)]]
            donor.set_quota(donor.quota - amt)
            d_eng.cache.adjust_capacity(-amt)
            t_eng.cache.adjust_capacity(amt)
            taker.set_quota(taker.quota + amt)
            moves.append((donor, taker, amt))
        return moves


class ShardDemandTracker:
    """Per-shard demand summarization for the cross-shard round.

    Lives next to the engine it measures: in-process the facade's
    ``GlobalRebalancer`` holds one for all shards; under the process
    driver each worker holds its own and ships the rows over the pipe
    (the ``rebalance_summary`` command).  SKEWED demand is measured from
    the BufferWindows' *cumulative* counters as deltas over this
    tracker's own round interval — shard-local rounds reset the
    per-round counters on their own read-triggered phase, so the
    cumulative delta is the only phase-independent signal (see
    ``allocation.BufferWindow``)."""

    def __init__(self, cfg: CacheConfig) -> None:
        self.cfg = cfg
        # cmu -> (total_hits, total_probes) at the end of our last round
        self._ghost_mark: Dict[CacheManageUnit, Tuple[int, int]] = {}

    def estimate(self, cmu: CacheManageUnit, now: float) -> DemandEstimate:
        est = marginal_benefit(cmu, now, self.cfg)
        if cmu.effective_pattern() is Pattern.SKEWED:
            bw = cmu.buffer_window
            th, tp = self._ghost_mark.get(cmu, (0, 0))
            dh, dp = bw.total_hits - th, bw.total_probes - tp
            f = dh / dp if dp else 0.0
            est = DemandEstimate(cmu.arrival_rate(now) * f / bw.w,
                                 dh > 0, est.can_shrink)
        return est

    def _row(self, cmu: CacheManageUnit, sid: int, now: float,
             can_take: bool) -> DemandSummary:
        est = self.estimate(cmu, now)
        limit: Optional[float] = None
        pat = cmu.effective_pattern()
        if pat is Pattern.RANDOM:
            limit = float(cmu.dataset_bytes)
        elif pat is Pattern.UNKNOWN and can_take:
            # wants_more was `used >= 0.95 * quota` — express as a quota
            # threshold so mid-round moves re-evaluate it
            limit = cmu.used / 0.95 if cmu.used else 0.0
        return DemandSummary(
            shard=sid, key=cmu.root_path, benefit=est.benefit,
            wants_more=est.wants_more, can_take=can_take, quota=cmu.quota,
            headroom=cmu.quota - self.cfg.min_share, demand_limit=limit)

    def summarize(self, eng: IGTCache, sid: int, now: float,
                  mark: bool = True
                  ) -> List[Tuple[DemandSummary, CacheManageUnit]]:
        """Demand rows for one shard.

        The shard's *default* CMU is included as a donor-only row
        (``can_take=False``): a shard whose datasets happen to be
        all-sequential — or that drew no dataset at all — must not hold
        1/N of the cluster capacity hostage.  Mirrors the shard-local
        round, which also passes the default CMU as a donor.

        ``mark=True`` (the single-shard / worker-resident case) advances
        the ghost marks to now; a tracker measuring several shards must
        pass ``mark=False`` per shard and call :meth:`mark_all` once
        with every shard's CMUs — replacing the dict per shard would
        wipe the other shards' marks."""
        pairs: List[Tuple[DemandSummary, CacheManageUnit]] = []
        for c in eng.workload_cmus():
            pairs.append((self._row(c, sid, now, can_take=True), c))
        d = eng.cache.default_cmu
        pairs.append((self._row(d, sid, now, can_take=False), d))
        if mark:
            self.mark_all(c for _, c in pairs)
        return pairs

    def mark_all(self, cmus) -> None:
        """Start the next measurement interval at the current cumulative
        ghost counters (marks of TTL-removed CMUs are dropped)."""
        self._ghost_mark = {
            c: (c.buffer_window.total_hits, c.buffer_window.total_probes)
            for c in cmus}


def split_capacity(capacity: int, n_shards: int) -> List[int]:
    """Initial per-shard capacity partition (both drivers use this)."""
    base, rem = divmod(capacity, n_shards)
    return [base + (1 if i < rem else 0) for i in range(n_shards)]


class ShardedIGTCache(ShardRouting):
    """N path-hash ``IGTCache`` shards behind the engine's public API.

    Exactly the surface callers use — ``read``, ``read_batch``,
    ``read_serial``, ``complete_prefetch``, ``cancel_prefetch``, ``pin``,
    ``never_cache``, ``tick``, ``stats``, ``hit_ratio``, ``snapshot`` —
    so the cluster simulator, the training pipeline and the baselines run
    sharded without knowing it.
    """

    def __init__(self, meta: StoreMeta, capacity: int,
                 cfg: Optional[CacheConfig] = None,
                 options: Optional[EngineOptions] = None,
                 n_shards: int = 1) -> None:
        self._init_routing(n_shards)
        self.meta = meta
        self.cfg = cfg or CacheConfig()
        self.options = options or EngineOptions()
        self.capacity = capacity
        self.shards: List[IGTCache] = [
            IGTCache(meta, cap, cfg=self.cfg, options=self.options)
            for cap in split_capacity(capacity, n_shards)
        ]
        self.global_rebalancer = GlobalRebalancer(self.cfg)

    # ------------------------------------------------------------- routing
    def shard_for(self, path: PathT) -> IGTCache:
        return self.shards[self.shard_id(path)]

    # ------------------------------------------------------------ user API
    def pin(self, path: PathT) -> None:
        for s in self.shards:          # prefix may be shorter than the
            s.pin(path)                # routing key — broadcast is exact

    def never_cache(self, path: PathT) -> None:
        for s in self.shards:
            s.never_cache(path)

    def invalidate_meta_cache(self) -> None:
        for s in self.shards:
            s.invalidate_meta_cache()

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: float) -> ReadOutcome:
        return self.shard_for(file_path).read(file_path, offset, size, now)

    def read_serial(self, file_path: PathT, offset: int, size: int,
                    now: float) -> ReadOutcome:
        return self.shard_for(file_path).read_serial(file_path, offset,
                                                     size, now)

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: float) -> List[ReadOutcome]:
        """Split the batch by shard, serve each sub-batch on its shard
        (tick cadence amortized per shard, as in the unsharded engine),
        and reassemble outcomes in the original request order."""
        if self.n_shards == 1:
            return self.shards[0].read_batch(requests, now)
        buckets = self.bucket_by_shard(requests)
        outs: List[Optional[ReadOutcome]] = [None] * len(requests)
        for sid, items in buckets.items():
            got = self.shards[sid].read_batch([r for _, r in items], now)
            for (i, _), out in zip(items, got):
                outs[i] = out
        return outs  # type: ignore[return-value]

    # ------------------------------------------------------------- prefetch
    def complete_prefetch(self, path: PathT, size: int, now: float) -> bool:
        return self.shard_for(path).complete_prefetch(path, size, now)

    def cancel_prefetch(self, path: PathT) -> None:
        self.shard_for(path).cancel_prefetch(path)

    # ------------------------------------------------------------------ tick
    def tick(self, now: float) -> None:
        """Per-shard maintenance plus, when due, the cross-shard allocation
        round.  The global layer is phase-independent of the shards'
        read-triggered local rounds: SKEWED demand is measured from
        cumulative ghost counters over the global round's own interval
        (see GlobalRebalancer), so ordering here is not load-bearing."""
        if (self.n_shards > 1 and self.options.allocation == "adaptive"
                and self.global_rebalancer.due(now)):
            self.global_rebalancer.rebalance_shards(self.shards, now)
        for s in self.shards:
            s.tick(now)

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> CacheStats:
        """Point-in-time merge of the shards' counters.

        Unlike ``IGTCache.stats`` this is a *snapshot*, not the live
        counter object — re-read the property for fresh values.  The
        semantic is deliberately identical at every shard count (a live
        view would only be possible at ``n_shards == 1``)."""
        return CacheStats.merged(s.stats for s in self.shards)

    def hit_ratio(self) -> float:
        return self.stats.hit_ratio

    def used_bytes(self) -> int:
        return sum(s.cache.used_bytes() for s in self.shards)

    def node_count(self) -> int:
        return sum(s.tree.node_count() for s in self.shards)

    def workload_cmus(self) -> List[CacheManageUnit]:
        return [c for s in self.shards for c in s.workload_cmus()]

    def iter_workload_cmus(self):
        for s in self.shards:
            yield from s.iter_workload_cmus()

    def shard_capacities(self) -> List[int]:
        return [s.cache.capacity for s in self.shards]

    def snapshot(self) -> dict:
        s = self.stats.snapshot()
        s["nodes"] = self.node_count()
        s["cmus"] = sum(len(sh.cache.cmus) - 1 for sh in self.shards)
        s["used_bytes"] = self.used_bytes()
        return s


# Either engine satisfies the same public read/prefetch/tick/stats surface;
# callers (cluster sim, training pipeline, benchmarks) annotate with this.
Engine = Union[IGTCache, ShardedIGTCache]


def make_engine(meta: StoreMeta, capacity: int,
                cfg: Optional[CacheConfig] = None,
                options: Optional[EngineOptions] = None,
                n_shards: int = 1) -> Engine:
    """Engine constructor shared by sim/benchmarks/examples: the plain
    state machine for ``n_shards=1`` (zero facade overhead), the sharded
    facade otherwise."""
    if n_shards == 1:
        return IGTCache(meta, capacity, cfg=cfg, options=options)
    return ShardedIGTCache(meta, capacity, cfg=cfg, options=options,
                           n_shards=n_shards)

"""Core datatypes for IGTCache.

The vocabulary follows the paper (§3): an *access* is one block-granular read
observed at the cache; an *AccessStream* groups accesses sharing a path prefix;
a *pattern* is one of {sequential, random, skewed} (plus unknown before the
observation window fills).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Iterable, Optional, Tuple

# Path components are strings; a full block key is the file path plus a block
# ordinal, e.g. ("ImageNet", "train", "n01491361", "4716.JPEG", "#0").
PathT = Tuple[str, ...]

MB = 1024 * 1024
GB = 1024 * MB


def block_key(path: PathT, idx: int) -> PathT:
    """Block path for block ``idx`` of file ``path``.

    The one place the ``"#<n>"`` leaf convention is constructed — every
    layer (client fetch paths, token pipeline, store block enumeration)
    builds block paths through here so the convention cannot drift.
    """
    return path + (f"#{idx}",)


def split_block_key(path: PathT) -> Tuple[PathT, Optional[int]]:
    """Inverse of :func:`block_key`: ``(file_path, block_idx)``.

    A path without a ``"#<n>"`` leaf returns ``(path, None)`` — callers
    that accept both file and block paths branch on the second element.
    A leaf that merely *starts* with ``#`` (a real file can be named
    ``"#notes"``) is not a block key either.
    """
    if path and path[-1][:1] == "#":
        try:
            return path[:-1], int(path[-1][1:])
        except ValueError:
            return path, None
    return path, None


class Pattern(enum.Enum):
    UNKNOWN = "unknown"
    SEQUENTIAL = "sequential"
    RANDOM = "random"
    SKEWED = "skewed"


@dataclass(frozen=True)
class AccessRecord:
    """One observed access at a specific tree level.

    ``index`` is the data-item index of the touched child within its parent
    (block id for blocks; listing position for files/directories — the
    "sequential element number in the parent directory" of §3.2).
    ``total`` is the number of items at that level (c in the paper's PMF).
    """

    index: int
    total: int
    time: float
    child_key: str
    size: int = 0


@dataclass
class CacheConfig:
    """Hyper-parameters, defaults exactly as published (§4, §5.1)."""

    # §3.1 — observation window (accesses per stream kept for analysis).
    window: int = 100
    # §3.2 — K-S significance level.
    alpha: float = 0.01
    # Fraction of unit-stride gaps required to call a stream sequential.
    sequential_threshold: float = 0.8
    # z-score threshold for the distinct-count (frequency-skew) screen.
    distinct_z_threshold: float = 3.0
    # Adaptive readahead: depth starts at prefetch_depth and doubles while the
    # stream stays sequential, up to this many items per generation.
    max_readahead_items: int = 512
    # §3.3 — prefetch depth N for sequential streams.
    prefetch_depth: int = 4
    # §3.3 — hot-child probability threshold f_p for hierarchical prefetch.
    f_p: float = 0.8
    # §3.3 — statistical prefetching: prefetch whole dataset when the expected
    # hit ratio (= allocatable cache / dataset size) clears this threshold.
    statistical_prefetch_threshold: float = 0.8
    # §3.3 — BufferWindow (ghost cache) size in blocks, w.
    buffer_window: int = 100
    # Cap on bytes one sequential/hierarchical prefetch generation may cover
    # (admission may still evict stale blocks to make room; this only bounds
    # the readahead horizon so one stream cannot monopolize the link).
    prefetch_budget_bytes: int = 256 * MB
    # §3.3 — adaptive TTL: significance + base time (seconds).
    ttl_significance: float = 0.01
    ttl_base: float = 60.0
    # §4 — allocation rebalance cadence and quantum.
    rebalance_period: float = 60.0
    rebalance_quantum: int = 640 * MB
    min_share: int = 640 * MB
    # §4 — AccessStreamTree node cap (LRU beyond this).
    node_cap: int = 10_000
    # Block size used by the cache layer (JuiceFS default, §5.2).
    block_size: int = 4 * MB
    # Re-run pattern analysis every this many accesses after non-trivial.
    reanalyze_every: int = 50
    # Cross-shard demand sketches (core/sketch.py): CountMinSketch geometry
    # for the per-shard ghost-hit heat summary, and the SpaceSaving top-k
    # capacity (also caps exact per-CMU rows in a shard's wire summary).
    sketch_width: int = 512
    sketch_depth: int = 3
    topk: int = 64
    # Cross-shard move sizing: "adaptive" sizes each move by the taker's
    # measured unmet demand (sketch-derived) with gap/shard-count-scaled
    # caps on forced-eviction transfers; "fixed" is the legacy
    # one-quantum-per-move greedy loop.
    quantum_policy: str = "adaptive"


@dataclass
class CacheStats:
    """Counters maintained by the engine; CHR is block-level (§5.1)."""

    hits: int = 0
    misses: int = 0
    prefetch_hits: int = 0  # hits served by a block brought in via prefetch
    bytes_from_cache: int = 0
    bytes_from_remote: int = 0
    evictions: int = 0
    prefetch_issued: int = 0
    prefetch_wasted: int = 0  # prefetched blocks evicted before any hit

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_ratio(self) -> float:
        n = self.accesses
        return self.hits / n if n else 0.0

    def merge(self, other: "CacheStats") -> "CacheStats":
        """Accumulate another engine's counters (shard-mergeable stats: the
        ShardedIGTCache facade sums its shards' CacheStats into one view).
        Iterates the dataclass fields so counters added later merge too."""
        import dataclasses
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other,
                                                                  f.name))
        return self

    @classmethod
    def merged(cls, parts: "Iterable[CacheStats]") -> "CacheStats":
        out = cls()
        for p in parts:
            out.merge(p)
        return out

    def snapshot(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_ratio": self.hit_ratio,
            "prefetch_hits": self.prefetch_hits,
            "bytes_from_cache": self.bytes_from_cache,
            "bytes_from_remote": self.bytes_from_remote,
            "evictions": self.evictions,
            "prefetch_issued": self.prefetch_issued,
            "prefetch_wasted": self.prefetch_wasted,
        }

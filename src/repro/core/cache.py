"""Unified cache space management: UnifiedCache + CacheManageUnit (§3.3, §4).

A *CacheManageUnit* (CMU) enforces space isolation for one top-level
AccessStream (the shallowest non-trivial node — in practice the dataset/job
root).  Within a CMU, *SubStreams* — one per governing pattern node — carry
pattern-specific eviction policies (a multi-modal dataset like LLaVa holds a
sequential text sub-stream and a random image sub-stream under one quota).

Victim priority when a CMU must make room:
  1. consumed blocks of eager (sequential) sub-streams — free by definition;
  2. the requesting sub-stream's own policy;
  3. other evictable sub-streams (skewed LRU, default LRU);
  4. uniform (random-pattern) sub-streams refuse — the block is simply not
     admitted (uniform caching never thrashes), unless the eviction is forced
     by a quota shrink.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .allocation import BufferWindow
from .eviction import (ARC, EagerEviction, EvictionPolicy, LRU, UniformCache,
                       make_policy)
from .sketch import DemandSketch
from .types import CacheConfig, CacheStats, PathT, Pattern

BlockKey = str


def path_key(path: PathT) -> BlockKey:
    """String residency key for a block path (the ``UnifiedCache`` key
    space).  Block *paths* themselves are built with
    ``types.block_key(path, idx)``."""
    return "/".join(path)


PATTERN_POLICY = {
    Pattern.SEQUENTIAL: "eager",
    Pattern.RANDOM: "uniform",
    Pattern.SKEWED: "lru",
    Pattern.UNKNOWN: "lru",
}


class SubStream:
    """Blocks governed by one pattern node inside a CMU."""

    __slots__ = ("path", "pattern", "policy", "blocks")

    def __init__(self, path: PathT, pattern: Pattern, policy: EvictionPolicy) -> None:
        self.path = path
        self.pattern = pattern
        self.policy = policy
        self.blocks: Dict[BlockKey, int] = {}

    def switch_pattern(self, pattern: Pattern, capacity_blocks: int) -> None:
        """Re-instantiate the policy on a pattern change, keeping residents."""
        if pattern is self.pattern:
            return
        self.pattern = pattern
        new_policy = make_policy(PATTERN_POLICY[pattern], capacity_blocks)
        for k in self.blocks:
            new_policy.record_insert(k)
        if isinstance(new_policy, EagerEviction):
            # Carried-over residents were demand-read in the past — under a
            # sequential pattern they are behind the stream position.
            new_policy.mark_consumed(list(self.blocks))
        self.policy = new_policy


class CacheManageUnit:
    """Per-stream quota + policy enforcement (§4 'CacheManageUnit')."""

    def __init__(self, root_path: PathT, quota: int, cfg: CacheConfig,
                 on_evict: Callable[[BlockKey, int], None],
                 dataset_bytes: int = 0) -> None:
        self.root_path = root_path
        self.quota = quota
        self.cfg = cfg
        self.used = 0
        self.substreams: Dict[PathT, SubStream] = {}
        self.block_sub: Dict[BlockKey, SubStream] = {}
        self.buffer_window = BufferWindow(cfg.buffer_window)
        self.dataset_bytes = dataset_bytes
        self._on_evict = on_evict        # notifies the UnifiedCache
        self._recent_times: deque = deque(maxlen=256)
        self.ttl: Optional[float] = None
        self.last_access_time = 0.0
        self.stat_prefetch_done = False
        self.created_at = 0.0
        self.hits = 0
        self.misses = 0
        self.bytes_accessed = 0
        self.max_gap = 0.0  # largest inter-access gap seen (stall guard)
        # Dataset-granularity pattern analysis over the *flattened* global
        # block index (catches skew spread across few big files, which
        # per-level gap analysis fragments).  Ring buffer (plain list, made
        # an ndarray only at analysis): note_flat() runs once per block on
        # the hot path.
        self._flat_idx: List[int] = [0] * cfg.window
        self._flat_pos = 0
        self._flat_count = 0
        self.flat_pattern = Pattern.UNKNOWN
        self._flat_seen = 0
        self._flat_analyzed_at = 0
        self._flat_total = 0
        # _make_room is a pure function of the CMU's residency/policy state;
        # cache a failed verdict until that state changes (a full uniform
        # stream would otherwise walk the whole eviction ladder on every
        # miss).  Bumped by _evict, successful admits, quota changes and
        # substream creation/switches.
        self._mutations = 0
        self._no_room_at = -1
        self._no_room_sub: Optional[SubStream] = None

    # -- substream plumbing ---------------------------------------------------
    def substream(self, node_path: PathT, pattern: Pattern) -> SubStream:
        sub = self.substreams.get(node_path)
        if sub is None:
            cap_blocks = max(1, self.quota // self.cfg.block_size)
            sub = SubStream(node_path, pattern,
                            make_policy(PATTERN_POLICY[pattern], cap_blocks))
            self.substreams[node_path] = sub
            self._mutations += 1
        elif sub.pattern is not pattern:
            cap_blocks = max(1, self.quota // self.cfg.block_size)
            sub.switch_pattern(pattern, cap_blocks)
            self._mutations += 1
            if pattern is Pattern.RANDOM:
                self.stat_prefetch_done = False
        return sub

    # -- accounting -------------------------------------------------------------
    def note_access(self, now: float, nbytes: int = 0) -> None:
        if self.last_access_time and now > self.last_access_time:
            self.max_gap = max(self.max_gap, now - self.last_access_time)
        self._recent_times.append(now)
        self.last_access_time = now
        self.bytes_accessed += nbytes

    def mean_access_size(self) -> int:
        n = self.hits + self.misses
        return max(1, self.bytes_accessed // n) if n else self.cfg.block_size

    def note_flat(self, ordinal: int, total: int, now: float) -> Pattern:
        """Record the flattened block ordinal and (re)classify the stream at
        dataset granularity (vectorized over the ring-buffer window)."""
        pos = self._flat_pos
        w = self.cfg.window
        self._flat_idx[pos] = ordinal
        self._flat_pos = 0 if pos + 1 == w else pos + 1
        if self._flat_count < w:
            self._flat_count += 1
        self._flat_total = total
        self._flat_seen += 1
        if (self._flat_seen >= w
                and (self.flat_pattern is Pattern.UNKNOWN
                     or self._flat_seen - self._flat_analyzed_at
                     >= self.cfg.reanalyze_every)):
            from .pattern import classify_batch
            self._flat_analyzed_at = self._flat_seen
            res = classify_batch([(self.flat_window(), total)], self.cfg)[0]
            self.flat_pattern = res.pattern
        return self.flat_pattern

    def flat_window(self) -> np.ndarray:
        """Chronological flattened-index window (fresh ndarray)."""
        from .access_stream_tree import ring_chrono
        return np.array(ring_chrono(self._flat_idx, self._flat_pos,
                                    self._flat_count, self.cfg.window),
                        dtype=np.int64)

    def effective_ttl(self) -> Optional[float]:
        """Fitted TTL, guarded against recurring I/O stalls: a stream that
        once stalled for G seconds must be idle for at least 2G + base before
        being presumed finished."""
        if self.ttl is None:
            return None
        return max(self.ttl, 2.0 * self.max_gap + self.cfg.ttl_base)

    def arrival_rate(self, now: float) -> float:
        if len(self._recent_times) < 2:
            return 0.0
        first, last = self._recent_times[0], self._recent_times[-1]
        # decay: an idle stream's rate falls as `now` moves past its last
        # access (otherwise finished jobs keep a frozen high benefit)
        span = max(1e-9, last - first, now - first)
        return (len(self._recent_times) - 1) / span

    def mean_access_gap(self, now: float = 0.0) -> Optional[float]:
        rate = self.arrival_rate(now)
        return 1.0 / rate if rate > 0 else None

    def effective_pattern(self) -> Pattern:
        """Stream pattern at dataset granularity: the flattened-index
        classification when available, else the dominant sub-stream."""
        if self.flat_pattern is not Pattern.UNKNOWN:
            return self.flat_pattern
        if not self.substreams:
            return Pattern.UNKNOWN
        best, best_w = Pattern.UNKNOWN, -1.0
        for sub in self.substreams.values():
            w = float(sum(sub.blocks.values())) + len(sub.blocks) + 1.0
            if sub.pattern is not Pattern.UNKNOWN and w > best_w:
                best, best_w = sub.pattern, w
        return best

    # -- residency ----------------------------------------------------------------
    def resident(self, key: BlockKey) -> bool:
        return key in self.block_sub

    def on_hit(self, key: BlockKey) -> None:
        sub = self.block_sub.get(key)
        if sub is not None:
            sub.policy.record_access(key, hit=True)

    def after_read(self, key: BlockKey) -> None:
        """Eager eviction: a consumed sequential block leaves immediately."""
        sub = self.block_sub.get(key)
        if sub is not None and isinstance(sub.policy, EagerEviction):
            self._evict(key, sub, ghost=False)

    def on_miss(self, key: BlockKey, sub: SubStream) -> None:
        sub.policy.record_access(key, hit=False)
        self.buffer_window.probe(key)

    def admit(self, key: BlockKey, size: int, sub: SubStream) -> bool:
        """Try to admit a fetched block under the quota; False = not cached."""
        if key in self.block_sub:
            return True
        if size > self.quota:
            return False
        if not sub.policy.admit(key):
            return False
        if self.used + size > self.quota:
            if self._no_room_at == self._mutations and self._no_room_sub is sub:
                return False    # nothing changed since the last failed search
            while self.used + size > self.quota:
                if not self._make_room(sub):
                    self._no_room_at = self._mutations
                    self._no_room_sub = sub
                    return False
        sub.blocks[key] = size
        sub.policy.record_insert(key)
        self.block_sub[key] = sub
        self.used += size
        self._mutations += 1
        return True

    def _make_room(self, requester: SubStream) -> bool:
        # 1. consumed eager blocks anywhere
        for sub in self.substreams.values():
            if isinstance(sub.policy, EagerEviction):
                k = sub.policy.consumed_victim()
                if k is not None and k in sub.blocks:
                    self._evict(k, sub, ghost=False)
                    return True
        # 2. requester's own policy
        v = requester.policy.choose_victim()
        if v is not None and v in requester.blocks:
            self._evict(v, requester)
            return True
        # 3. other evictable substreams
        for sub in self.substreams.values():
            if sub is requester or isinstance(sub.policy, UniformCache):
                continue
            v = sub.policy.choose_victim()
            if v is not None and v in sub.blocks:
                self._evict(v, sub)
                return True
        return False

    def _evict(self, key: BlockKey, sub: SubStream, ghost: bool = True) -> None:
        size = sub.blocks.pop(key, 0)
        sub.policy.record_remove(key)
        self.block_sub.pop(key, None)
        self.used -= size
        self._mutations += 1
        if ghost:
            self.buffer_window.on_evict(key)
        self._on_evict(key, size)

    # -- quota management -------------------------------------------------------
    def set_quota(self, quota: int) -> None:
        grew = quota > self.quota
        self.quota = max(0, quota)
        self._mutations += 1
        if grew:
            # §4: on a size change, refresh pattern-derived decisions.
            self.stat_prefetch_done = False
            for sub in self.substreams.values():
                if isinstance(sub.policy, UniformCache):
                    sub.policy.mark_full(False)
        while self.used > self.quota:
            if not self._force_evict_one():
                break

    def _force_evict_one(self) -> bool:
        for sub in self.substreams.values():
            if isinstance(sub.policy, EagerEviction):
                v = sub.policy.choose_victim()
                if v is not None and v in sub.blocks:
                    self._evict(v, sub)
                    return True
        for sub in self.substreams.values():
            if isinstance(sub.policy, UniformCache):
                continue
            v = sub.policy.choose_victim()
            if v is not None and v in sub.blocks:
                self._evict(v, sub)
                return True
        for sub in self.substreams.values():
            v = sub.policy.force_victim()
            if v is not None and v in sub.blocks:
                self._evict(v, sub)
                return True
        return False

    def evict_all(self) -> int:
        """TTL expiry: drop the whole stream (the job is presumed finished)."""
        n = 0
        for sub in list(self.substreams.values()):
            for k in list(sub.blocks):
                self._evict(k, sub, ghost=False)
                n += 1
        return n


class UnifiedCache:
    """The shared cache pool: global residency map + CMU registry.

    Invariants (property-tested):
      * sum(cmu.used) == sum of sizes in the global map <= capacity;
      * sum(cmu.quota) == capacity (the default CMU absorbs slack);
      * each resident block belongs to exactly one CMU.
    """

    DEFAULT = ("<default>",)

    def __init__(self, capacity: int, cfg: Optional[CacheConfig] = None) -> None:
        self.capacity = capacity
        self.cfg = cfg or CacheConfig()
        self.stats = CacheStats()
        self.blocks: Dict[BlockKey, Tuple[int, CacheManageUnit]] = {}
        self.cmus: Dict[PathT, CacheManageUnit] = {}
        # bumped whenever the CMU registry changes; read-path caches of
        # path→CMU resolutions key their validity on it (§4 batched read)
        self.cmu_gen = 0
        # per-pool ghost-hit heat (core.sketch): every CMU's BufferWindow
        # sinks its ghost hits here so the cross-shard allocation round
        # can size unmet working sets from a bounded summary
        self.demand_sketch = DemandSketch(self.cfg)
        # optional eviction tap (key, size): a tiered backing store
        # registers its spill hook here (storage.tiers via IGTCache) —
        # observation only, never feeds back into kernel decisions
        self.evict_hook: Optional[Callable[[BlockKey, int], None]] = None
        self.default_cmu = CacheManageUnit(
            self.DEFAULT, capacity, self.cfg,
            on_evict=self._cmu_evicted, dataset_bytes=0)
        self.default_cmu.buffer_window.sink = self.demand_sketch.note
        self.cmus[self.DEFAULT] = self.default_cmu

    # -- bookkeeping hooks ------------------------------------------------------
    def _cmu_evicted(self, key: BlockKey, size: int) -> None:
        self.blocks.pop(key, None)
        self.stats.evictions += 1
        if self.evict_hook is not None:
            self.evict_hook(key, size)

    # -- queries ------------------------------------------------------------------
    def resident(self, key: BlockKey) -> bool:
        return key in self.blocks

    def used_bytes(self) -> int:
        return sum(c.used for c in self.cmus.values())

    def cmu_for_path(self, path: PathT) -> CacheManageUnit:
        """Deepest registered CMU whose root prefixes ``path`` (else default)."""
        for plen in range(len(path), 0, -1):
            cmu = self.cmus.get(path[:plen])
            if cmu is not None:
                return cmu
        return self.default_cmu

    # -- CMU lifecycle ----------------------------------------------------------
    def create_cmu(self, root_path: PathT, dataset_bytes: int,
                   now: float) -> CacheManageUnit:
        """Promote a newly non-trivial stream to its own CMU.

        Resident blocks under ``root_path`` migrate from the default CMU; the
        initial quota is the migrated footprint plus a fair slice of the
        default CMU's slack, never below ``min_share``.
        """
        existing = self.cmus.get(root_path)
        if existing is not None:
            return existing
        cmu = CacheManageUnit(root_path, 0, self.cfg,
                              on_evict=self._cmu_evicted,
                              dataset_bytes=dataset_bytes)
        cmu.buffer_window.sink = self.demand_sketch.note
        cmu.created_at = now
        prefix = path_key(root_path) + "/"
        moved_bytes = 0
        default = self.default_cmu
        for key in [k for k in default.block_sub if k.startswith(prefix)]:
            sub = default.block_sub[key]
            size = sub.blocks.pop(key)
            sub.policy.record_remove(key)
            default.block_sub.pop(key)
            default.used -= size
            dsub = cmu.substream(root_path, Pattern.UNKNOWN)
            dsub.blocks[key] = size
            dsub.policy.record_insert(key)
            cmu.block_sub[key] = dsub
            cmu.used += size
            self.blocks[key] = (size, cmu)
            moved_bytes += size
        if moved_bytes:
            default._mutations += 1
            cmu._mutations += 1
        slack = max(0, default.quota - default.used)  # post-move slack
        n_cmus = len(self.cmus)  # includes default
        desired = max(self.cfg.min_share, moved_bytes,
                      min(dataset_bytes, slack // max(1, n_cmus)))
        # default keeps a min-share floor (it adopts TTL-drained blocks and
        # serves unclassified traffic)
        grant = min(desired, max(0, default.quota - self.cfg.min_share))
        grant = max(grant, moved_bytes)       # must cover migrated residency
        default.set_quota(default.quota - grant)
        cmu.set_quota(grant)
        self.cmus[root_path] = cmu
        self.cmu_gen += 1
        return cmu

    def remove_cmu(self, root_path: PathT, transfer: bool = True) -> None:
        """TTL-expired job: release the stream back to the default pool.

        With ``transfer`` (default), resident blocks are *adopted* by the
        default CMU's LRU instead of being dropped eagerly: a genuinely
        finished job's data drains out as others claim space, while a
        misjudged-live job keeps hitting (and its blocks migrate back when
        its CMU is re-created).  Strictly dominates the paper's hard evict.
        """
        cmu = self.cmus.pop(root_path, None)
        if cmu is None or cmu is self.default_cmu:
            return
        self.cmu_gen += 1
        default = self.default_cmu
        default.set_quota(default.quota + cmu.quota)
        if transfer:
            for sub in list(cmu.substreams.values()):
                for key, size in list(sub.blocks.items()):
                    dsub = default.substream(root_path, Pattern.UNKNOWN)
                    dsub.blocks[key] = size
                    dsub.policy.record_insert(key)
                    default.block_sub[key] = dsub
                    default.used += size
                    self.blocks[key] = (size, default)
                sub.blocks.clear()
                default._mutations += 1
        else:
            cmu.evict_all()
        # default may now be over quota if capacity shrank elsewhere
        default.set_quota(default.quota)

    # -- cross-shard capacity (core.sharded) -------------------------------------
    def adjust_capacity(self, delta: int) -> None:
        """Grow or shrink this pool's capacity by ``delta`` bytes.

        Used by the cross-shard GlobalRebalancer: a quantum moving between
        shards shrinks the donor shard's pool and grows the taker's.  The
        caller is responsible for the paired CMU quota move (shrink the donor
        CMU before taking its capacity, grow the taker CMU after granting
        it), which keeps ``sum(quota) == capacity`` on both sides.
        """
        if self.capacity + delta < 0:
            raise ValueError(
                f"capacity adjustment {delta} would underflow pool "
                f"capacity {self.capacity}")
        self.capacity += delta

    # -- residency transitions -----------------------------------------------------
    def insert(self, path: PathT, size: int, cmu: CacheManageUnit,
               sub: SubStream) -> bool:
        return self.insert_key(path_key(path), size, cmu, sub)

    def insert_key(self, key: BlockKey, size: int, cmu: CacheManageUnit,
                   sub: SubStream) -> bool:
        """Hot-path insert for callers that already hold the block key."""
        ok = cmu.admit(key, size, sub)
        if ok:
            self.blocks[key] = (size, cmu)
        return ok

    def quota_invariant_ok(self) -> bool:
        return sum(c.quota for c in self.cmus.values()) <= self.capacity

"""Metadata protocol the cache core needs from the storage layer.

The core never imports ``repro.storage`` — any object satisfying this
protocol (the simulated S3 store, a real filesystem walker, the training-data
shard store) can back IGTCache.
"""
from __future__ import annotations

from typing import Iterator, List, Protocol, Tuple

from .types import PathT


class StoreMeta(Protocol):
    """Listing/geometry metadata (what a FUSE layer sees from readdir/stat)."""

    def listing(self, path: PathT) -> List[str]:
        """Ordered child names under ``path`` (traversal order — the index
        space of §3.2)."""
        ...

    def listing_size(self, path: PathT) -> int:
        """len(listing(path)) without materializing it."""
        ...

    def child_index(self, path: PathT, name: str) -> int:
        """Position of ``name`` in ``listing(path)``."""
        ...

    def is_file(self, path: PathT) -> bool:
        ...

    def file_size(self, path: PathT) -> int:
        ...

    def subtree_bytes(self, path: PathT) -> int:
        """Total bytes stored under ``path`` (dataset size for §3.3)."""
        ...

    def iter_block_keys(self, path: PathT) -> Iterator[Tuple[PathT, int]]:
        """All (block_path, size) under ``path`` in traversal order."""
        ...

    def flat_block_index(self, file_path: PathT, block: int) -> Tuple[int, int]:
        """(global block ordinal, total blocks) within the file's top-level
        dataset, in traversal order — the flattened index space used for
        dataset-granularity pattern analysis."""
        ...


class LevelCache:
    """Memoized root→leaf level resolution over a StoreMeta (§4).

    The seed engine re-asked the store for ``listing_size``/``child_index``
    at every directory level of every block access.  Listings are static for
    the lifetime of a run (datasets are immutable once registered), so the
    (name, index, listing-size) decomposition of a path is a pure function of
    the path — memoize it per directory, sharing the common prefix across
    all files in that directory.  Call :meth:`invalidate` if the backing
    store ever re-registers datasets mid-run.
    """

    # Bound on memoized paths: entries are tiny (one tuple-of-tuples per
    # path) but a process streaming over millions of distinct files must not
    # grow without limit; on overflow the cache simply resets (a rebuild
    # costs a handful of dict lookups per path).
    MAX_ENTRIES = 1 << 20

    def __init__(self, meta: StoreMeta, max_entries: int = MAX_ENTRIES) -> None:
        self._meta = meta
        self._max = max_entries
        self._dirs: dict = {(): ()}

    def dir_levels(self, path: PathT) -> Tuple[Tuple[str, int, int], ...]:
        """(name, child-index, parent-listing-size) for each component."""
        got = self._dirs.get(path)
        if got is None:
            parent, name = path[:-1], path[-1]
            got = self.dir_levels(parent) + (
                (name, self._meta.child_index(parent, name),
                 self._meta.listing_size(parent)),)
            if len(self._dirs) >= self._max:
                self._dirs = {(): ()}
            self._dirs[path] = got
        return got

    def invalidate(self) -> None:
        self._dirs = {(): ()}

"""Metadata protocol the cache core needs from the storage layer.

The core never imports ``repro.storage`` — any object satisfying this
protocol (the simulated S3 store, a real filesystem walker, the training-data
shard store) can back IGTCache.
"""
from __future__ import annotations

from typing import Iterator, List, Protocol, Tuple

from .types import PathT


class StoreMeta(Protocol):
    """Listing/geometry metadata (what a FUSE layer sees from readdir/stat)."""

    def listing(self, path: PathT) -> List[str]:
        """Ordered child names under ``path`` (traversal order — the index
        space of §3.2)."""
        ...

    def listing_size(self, path: PathT) -> int:
        """len(listing(path)) without materializing it."""
        ...

    def child_index(self, path: PathT, name: str) -> int:
        """Position of ``name`` in ``listing(path)``."""
        ...

    def is_file(self, path: PathT) -> bool:
        ...

    def file_size(self, path: PathT) -> int:
        ...

    def subtree_bytes(self, path: PathT) -> int:
        """Total bytes stored under ``path`` (dataset size for §3.3)."""
        ...

    def iter_block_keys(self, path: PathT) -> Iterator[Tuple[PathT, int]]:
        """All (block_path, size) under ``path`` in traversal order."""
        ...

    def flat_block_index(self, file_path: PathT, block: int) -> Tuple[int, int]:
        """(global block ordinal, total blocks) within the file's top-level
        dataset, in traversal order — the flattened index space used for
        dataset-granularity pattern analysis."""
        ...

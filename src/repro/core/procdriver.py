"""Multi-process shard driver: process-owned kernels + shared-memory arena.

The ``ShardedIGTCache`` facade made the engine N independent state
machines, and the ``ThreadedExecutor`` gave each shard its own worker —
but every shard still executes in one GIL-bound process, so 4 shards are
*slower* per access than 1 (BENCH_overhead.json ``sharded``).  This
module is the scaling lever the ROADMAP names: each shard kernel lives in
its **own worker process** (owning its AccessStreamTree, chain/ctx
caches, ``UnifiedCache`` partition — and its own store instance,
re-opened per process via ``storage.api.store_spec``), behind the same
engine API and the same ``CacheClient``.  Hoard (arXiv:1812.00669) uses
the same shape for distributed DL caches: per-worker cache daemons with a
thin client library in front.

Three pieces:

* :class:`ProcessShardedCache` — the driver/facade.  Routing and the
  cross-shard allocation rule are shared with the in-process facade
  (``sharded.ShardRouting`` / ``GlobalRebalancer.plan_moves``): commands
  travel as small batched tuples over one pipe per worker — **one
  round-trip per** ``read_batch`` **per shard** — and each rebalance
  round aggregates the workers' serialized per-CMU ``DemandSummary``
  rows, plans centrally with the same greedy max-B ← min-B rule, and
  ships quota/capacity deltas back (``adjust_capacity`` worker-side), so
  space allocation stays cluster-wide.
* :class:`ShmArena` — a ``multiprocessing.shared_memory`` block split
  into per-worker regions.  Workers write fetched bytes into arena slots
  and reply with ``(offset, length)`` descriptors; the client maps them
  as read-only ``memoryview``-backed arrays — **payload bytes never ride
  pickle**.  Slot lifecycle is refcounted on the client: when the last
  array view is garbage-collected, the slot offset is queued and
  piggybacked on the next command to that worker, which returns it to
  the region's free list.  If a region is exhausted the worker falls
  back to an inline reply (counted as a *spill* — visible in
  ``arena_spills()`` so benchmarks/tests can assert the zero-copy path).
* :class:`ProcessExecutor` — the ``PrefetchExecutor`` for this driver.
  Same contract as the ``ThreadedExecutor`` (tests/test_client.py
  semantics): bounded per-shard background queues with in-queue dedup,
  demand fetches as a strict-priority class, cancel-on-overflow /
  dedup / shutdown via ``cancel_prefetch`` **on the worker's kernel**
  (never a silent drop), so ``submitted == completed + cancelled +
  deduped`` holds at close and the worker-side pending tables never
  leak — even under a failing backend (worker-side retries on
  ``TransientStoreError``; permanent failures cancel the candidate).

Client-side, each worker pipe is **pipelined**: callers send commands
directly under a per-channel send lock (a ``read_batch`` has every
shard's sub-batch in flight before the first reply is awaited — that
concurrency is the speedup), and one receiver thread per channel
matches FIFO replies to in-flight commands.  Background candidates
coalesce into at most one in-flight ``prefetch_batch`` per channel
(bounded priority inversion for demand commands); read replies are
key-free compact tuples decoded lazily (:class:`WireOutcome`).  A dead
worker breaks its pipe, which fails that channel's pending commands
instead of hanging the caller.
"""
from __future__ import annotations

import bisect
import gc
import multiprocessing
import os
import threading
import time
import weakref
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from ..train.fault import Heartbeat
from .client import PrefetchExecutor, _sync_block_size
from .faults import (RestartBudget, SHARD_DOWN, SHARD_RESTARTING, SHARD_UP,
                     ShardUnavailableError)
from .igtcache import EngineOptions, IGTCache, ReadOutcome
from .meta import StoreMeta
from .sharded import (DemandSummary, GlobalRebalancer, ShardDemandTracker,
                      ShardRouting, ShardSummary, split_capacity)
from .types import CacheConfig, CacheStats, MB, PathT, Pattern
# the compact reply codec is shared with the network cache daemon
# (repro.daemon speaks the same frames) — core/wire.py is the one
# definition; the old procdriver names stay importable from here
from .wire import WireOutcome, encode_outcome as _encode_out

__all__ = ["ProcessExecutor", "ProcessShardedCache", "ShmArena",
           "WireOutcome"]

_UNSET = object()          # sentinel: "use the driver's default rpc timeout"

DEFAULT_ARENA_BYTES = 64 * MB
# background candidates coalesced into one prefetch_batch command
PREFETCH_COALESCE = 64


# ---------------------------------------------------------------------------
# shared-memory byte arena
# ---------------------------------------------------------------------------

class _RegionAllocator:
    """First-fit free-list allocator over one worker's arena region
    (worker-side; offsets are absolute within the shared block).  Frees
    arrive as piggybacked ``(offset, length)`` pairs on later commands
    and coalesce with adjacent free intervals."""

    def __init__(self, offset: int, length: int,
                 reserved: Sequence[Tuple[int, int]] = ()) -> None:
        self._free: List[Tuple[int, int]] = ([(offset, length)]
                                             if length > 0 else [])
        # respawn path: slots the *previous* worker generation handed to
        # the client as live arena views are carved out up front, so the
        # fresh allocator can never hand them to new fetches while the
        # client still reads them; the client's piggybacked frees return
        # them to the pool as the old views are collected.
        for off, n in sorted(reserved):
            self.reserve(off, n)

    def reserve(self, offset: int, n: int) -> bool:
        """Remove ``[offset, offset+n)`` from the free list (must lie
        inside one free interval — true for slots the previous
        generation allocated from the same region)."""
        if n <= 0:
            return True
        for i, (off, length) in enumerate(self._free):
            if off <= offset and offset + n <= off + length:
                pieces = []
                if offset > off:
                    pieces.append((off, offset - off))
                tail = (off + length) - (offset + n)
                if tail > 0:
                    pieces.append((offset + n, tail))
                self._free[i:i + 1] = pieces
                return True
        return False

    def alloc(self, n: int) -> int:
        """Absolute offset of an ``n``-byte slot, or -1 when exhausted."""
        if n <= 0:
            return -1
        for i, (off, length) in enumerate(self._free):
            if length >= n:
                if length == n:
                    self._free.pop(i)
                else:
                    self._free[i] = (off + n, length - n)
                return off
        return -1

    def free(self, offset: int, n: int) -> None:
        if n <= 0:
            return
        i = bisect.bisect_left(self._free, (offset, n))
        self._free.insert(i, (offset, n))
        # coalesce with right then left neighbour
        if i + 1 < len(self._free):
            off, length = self._free[i]
            noff, nlen = self._free[i + 1]
            if off + length == noff:
                self._free[i] = (off, length + nlen)
                self._free.pop(i + 1)
        if i > 0:
            poff, plen = self._free[i - 1]
            off, length = self._free[i]
            if poff + plen == off:
                self._free[i - 1] = (poff, plen + length)
                self._free.pop(i)

    def free_bytes(self) -> int:
        return sum(length for _, length in self._free)


class ShmArena:
    """One ``multiprocessing.shared_memory`` block, split into equal
    per-worker regions so workers allocate without any cross-process
    locking (each region has exactly one writer: its worker).  The
    client (creator) maps reply descriptors as read-only numpy views;
    ``view()`` attaches a finalizer that queues the slot for reuse when
    the last reference dies."""

    def __init__(self, total_bytes: int, n_regions: int) -> None:
        from multiprocessing import shared_memory
        region = max(0, total_bytes) // max(1, n_regions)
        self.region_bytes = region
        self.shm = (shared_memory.SharedMemory(create=True,
                                               size=region * n_regions)
                    if region > 0 else None)
        self.name = self.shm.name if self.shm is not None else None
        self._closed = False

    def region(self, i: int) -> Tuple[int, int]:
        return i * self.region_bytes, self.region_bytes

    def view(self, offset: int, length: int,
             on_release: Optional[Callable[[int, int], None]] = None
             ) -> np.ndarray:
        """Read-only zero-copy array over ``[offset, offset+length)``.
        ``on_release(offset, length)`` fires when the array (and
        everything sharing its buffer) is garbage-collected."""
        if length == 0 or self.shm is None:
            return np.empty(0, dtype=np.uint8)
        arr = np.frombuffer(self.shm.buf, dtype=np.uint8, count=length,
                            offset=offset)
        arr.flags.writeable = False
        if on_release is not None:
            weakref.finalize(arr, on_release, offset, length)
        return arr

    def close(self) -> None:
        if self._closed or self.shm is None:
            return
        self._closed = True
        try:
            self.shm.close()
        except BufferError:
            # client still holds live views into the block: the mapping
            # can only drop when they are collected.  Silence the
            # destructor's doomed re-close (it would print an ignored
            # BufferError at interpreter exit) — the OS reclaims the
            # mapping with the process either way.
            self.shm.close = lambda: None
        try:
            self.shm.unlink()
        except FileNotFoundError:  # pragma: no cover - double unlink
            pass


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

class _WorkerState:
    """Everything one shard worker owns: its kernel, its store, its
    arena region, its demand tracker."""

    def __init__(self, sid, kernel, store, backing, retry, shm, alloc):
        self.sid = sid
        self.kernel = kernel
        self.store = store
        self.backing = backing
        self.retry = retry
        self.shm = shm
        self.alloc = alloc
        self.tracker = ShardDemandTracker(kernel.cfg)
        self.spills = 0
        self.retries = 0
        # unpickled path tuples are fresh objects every command: no
        # cached hashes, no identity fast-path in the kernel's many
        # per-access dict hops.  Canonicalize to the first-seen tuple —
        # one lookup here buys identity-hit lookups everywhere below.
        # Bounded like the kernel's own memo caches: a worker streaming
        # over millions of distinct blocks must not retain every tuple
        # forever; on overflow the map simply resets (correctness is
        # unaffected — canonicalization is a pure perf identity map).
        self._canon: Dict[PathT, PathT] = {}

    _CANON_MAX = 1 << 20

    def canon(self, path: PathT) -> PathT:
        got = self._canon.get(path)
        if got is None:
            if len(self._canon) >= self._CANON_MAX:
                self._canon.clear()
            self._canon[path] = path
            got = path
        return got

    def note_retry(self, attempt, exc) -> None:
        self.retries += 1


def _worker_main(conn, shm_name: Optional[str], region: Tuple[int, int],
                 spec, backing_spec, capacity: int,
                 cfg: Optional[CacheConfig],
                 options: Optional[EngineOptions], sid: int,
                 retry, pause_gc: bool,
                 reserved: Sequence[Tuple[int, int]] = ()) -> None:
    """Shard worker entry point: build the kernel + per-process store,
    then serve commands until ``stop``/EOF.  Every inbound message is
    ``(op, frees, payload)`` — ``frees`` returns arena slots the client
    released; every reply is ``("ok", result)`` or ``("err", exc)``."""
    from ..storage.api import RetryPolicy, as_backing_store, resolve_store_spec
    store = resolve_store_spec(spec)
    cfg = cfg or CacheConfig()
    _sync_block_size(store, cfg)     # worker instance must agree on geometry
    kernel = IGTCache(store, capacity, cfg=cfg, options=options)
    # byte fetches may come from a different store than the metadata
    # (the client's `backing` override travels as its own spec)
    if backing_spec is None:
        backing_store = store
    else:
        backing_store = resolve_store_spec(backing_spec)
        _sync_block_size(backing_store, cfg)
    shm = None
    if shm_name is not None and region[1] > 0:
        from multiprocessing import shared_memory
        shm = shared_memory.SharedMemory(name=shm_name)
    state = _WorkerState(sid, kernel, store, as_backing_store(backing_store),
                         retry if retry is not None else RetryPolicy(),
                         shm, _RegionAllocator(*region, reserved=reserved))
    if pause_gc:
        gc.disable()
    try:
        _serve(conn, state)
    finally:
        if shm is not None:
            try:
                shm.close()
            except BufferError:  # pragma: no cover
                pass
        conn.close()


def _serve(conn, state: _WorkerState) -> None:
    kernel = state.kernel
    while True:
        try:
            op, frees, payload = conn.recv()
        except (EOFError, OSError):
            return
        for off, n in frees:
            state.alloc.free(off, n)
        try:
            result = _dispatch(state, kernel, op, payload)
        except BaseException as e:
            try:
                conn.send(("err", e))
            except Exception:    # unpicklable exception: degrade to repr
                conn.send(("err", RuntimeError(repr(e))))
            continue
        conn.send(("ok", result))
        if op == "stop":
            return


def _dispatch(state: _WorkerState, kernel: IGTCache, op: str, payload):
    bs = kernel.cfg.block_size
    if op == "read_batch":
        reqs, now, inline = payload
        canon = state.canon
        outs = kernel.read_batch([(canon(fp), off, sz)
                                  for fp, off, sz in reqs], now)
        done = _inline_complete(kernel, outs, now) if inline else 0
        return [_encode_out(o, req[1] // bs)
                for o, req in zip(outs, reqs)], done
    if op == "read":
        fp, off, size, now, inline = payload
        out = kernel.read(state.canon(fp), off, size, now)
        done = _inline_complete(kernel, [out], now) if inline else 0
        return _encode_out(out, off // bs), done
    if op == "read_serial":
        fp, off, size, now, inline = payload
        out = kernel.read_serial(state.canon(fp), off, size, now)
        done = _inline_complete(kernel, [out], now) if inline else 0
        return _encode_out(out, off // bs), done
    if op == "fetch":
        return _op_fetch(state, payload)
    if op == "prefetch_batch":
        return _op_prefetch_batch(state, *payload)
    if op == "cancel_many":
        for path in payload:
            kernel.cancel_prefetch(state.canon(path))
        return len(payload)
    if op == "complete":
        path, size, now = payload
        return kernel.complete_prefetch(state.canon(path), size, now)
    if op == "cancel":
        kernel.cancel_prefetch(state.canon(payload))
        return None
    if op == "tick":
        kernel.tick(payload)
        return None
    if op == "rebalance_summary":
        # summarize() builds the bounded wire ShardSummary (exact rows
        # for the default + top-k CMUs, sketch payloads for the block
        # heat) as a side effect — ship that, not the raw row list
        state.tracker.summarize(kernel, state.sid, payload)
        return state.tracker.summaries[state.sid]
    if op == "rebalance_apply":
        return _op_apply_alloc(kernel, *payload)
    if op == "stats":
        return {"stats": kernel.stats,
                "nodes": kernel.tree.node_count(),
                "used": kernel.cache.used_bytes(),
                "capacity": kernel.cache.capacity,
                "cmus": len(kernel.cache.cmus) - 1,
                "pending": len(kernel._pending_prefetch),
                "spills": state.spills,
                "arena_free": state.alloc.free_bytes()}
    if op == "snapshot":
        return kernel.snapshot()
    if op == "cmus":
        return [(path, c.effective_pattern().value, c.quota, c.used,
                 c.hits, c.misses)
                for path, c in kernel.iter_workload_cmus()]
    if op == "pin":
        kernel.pin(payload)
        return None
    if op == "never_cache":
        kernel.never_cache(payload)
        return None
    if op == "invalidate_meta":
        # the documented mid-run refresh workflow (storage/local_fs.py):
        # each worker owns its store instance, so the re-walk must
        # happen HERE — a client-side store.refresh() never reaches the
        # workers' snapshots
        for obj in {id(state.store): state.store,
                    id(state.backing): state.backing}.values():
            refresh = getattr(obj, "refresh", None)
            if callable(refresh):
                refresh()
        kernel.invalidate_meta_cache()
        return None
    if op == "debug_pending":
        return set(kernel._pending_prefetch)
    if op == "hello":
        caps = getattr(state.backing, "capabilities", None)
        return {"pid": os.getpid(),
                "capabilities": caps().snapshot() if caps else None}
    if op == "stop":
        return None
    raise ValueError(f"unknown worker op {op!r}")


def _inline_complete(kernel: IGTCache, outs: Sequence[ReadOutcome],
                     now: float) -> int:
    """Worker-side inline prefetch completion (``prefetch="inline"``):
    the exact protocol of the caller-driven kernel loop — every candidate
    completes at the read's own ``now``, kernel-side, no byte movement.
    Completed candidates are stripped from the outcome so the client
    cannot double-dispatch them."""
    done = 0
    for out in outs:
        if out.prefetches:
            for p, s in out.prefetches:
                kernel.complete_prefetch(p, s, now)
            done += len(out.prefetches)
            out.prefetches = []
    return done


def _op_fetch(state: _WorkerState, requests):
    """Demand fetch into the arena: one ``fetch_many`` against this
    worker's own store, results written into region slots, descriptors
    (not bytes) back over the pipe.  Transient errors retried here (the
    retry count travels in the reply); a permanent error fails the batch
    like a real multi-range response with a failed part."""
    before = state.retries
    datas = state.retry.call(state.backing.fetch_many, list(requests),
                             on_retry=state.note_retry)
    entries: List[tuple] = []
    for d in datas:
        d = np.asarray(d, dtype=np.uint8)
        n = int(d.size)
        off = state.alloc.alloc(n) if state.shm is not None else -1
        if n == 0:
            entries.append(("shm", 0, 0))
        elif off < 0:
            state.spills += 1          # region exhausted: inline fallback
            entries.append(("raw", d))
        else:
            dst = np.frombuffer(state.shm.buf, dtype=np.uint8, count=n,
                                offset=off)
            dst[:] = d
            entries.append(("shm", off, n))
    return entries, state.retries - before


def _op_prefetch_batch(state: _WorkerState, cands, now: float,
                       max_fetch_bytes: int):
    """One coalesced batch of background candidates: capped byte fetch
    (retry-guarded) + ``complete_prefetch`` on this worker's kernel; a
    fetch that fails past the retry bound cancels the candidate instead
    — the executor identity survives a failing backend."""
    kernel = state.kernel
    completed = cancelled = errors = 0
    before = state.retries
    for path, size in cands:
        path = state.canon(path)
        try:
            if state.backing is not None and max_fetch_bytes > 0:
                state.retry.call(state.backing.fetch_range, path, 0,
                                 min(size, max_fetch_bytes),
                                 on_retry=state.note_retry)
            kernel.complete_prefetch(path, size, now)
            completed += 1
        except Exception:
            errors += 1
            kernel.cancel_prefetch(path)
            cancelled += 1
    return completed, cancelled, state.retries - before, errors


def _op_apply_alloc(kernel: IGTCache, shrinks, cap_delta: int, grows):
    """Apply one rebalance round's deltas: quota shrinks first (forced
    eviction happens while the capacity is still here), then the pool
    capacity delta, then quota grows — ``sum(quota) == capacity`` holds
    when the command completes.  A CMU removed (TTL) between summary and
    apply falls back to the default CMU so the invariant survives."""
    cache = kernel.cache

    def adj(key, delta):
        cmu = cache.cmus.get(tuple(key))
        if cmu is None:
            cmu = cache.default_cmu
        cmu.set_quota(cmu.quota + delta)

    for key, amt in shrinks:
        adj(key, -amt)
    if cap_delta:
        cache.adjust_capacity(cap_delta)
    for key, amt in grows:
        adj(key, amt)
    return None


# ---------------------------------------------------------------------------
# client side: per-shard channel + dispatcher
# ---------------------------------------------------------------------------

class _RPC:
    """One demand-class command awaiting its reply."""

    __slots__ = ("op", "payload", "event", "reply", "error")

    def __init__(self, op: str, payload) -> None:
        self.op = op
        self.payload = payload
        self.event = threading.Event()
        self.reply = None
        self.error: Optional[BaseException] = None

    def wait(self, timeout: Optional[float] = None):
        if not self.event.wait(timeout):
            raise TimeoutError(f"worker RPC {self.op!r} timed out")
        if self.error is not None:
            raise self.error
        return self.reply


class _PrefetchBatch:
    """Marker for one in-flight coalesced ``prefetch_batch`` command."""

    __slots__ = ("items",)

    def __init__(self, items) -> None:
        self.items = items


class _ShardChannel:
    """Client-side endpoint for one worker pipe, **pipelined**: callers
    send commands directly (no dispatcher hop) under ``send_lock``,
    appending an :class:`_RPC` to the FIFO ``pending`` deque; the
    worker serves strictly in order, so the channel's single receiver
    thread matches each reply to ``pending.popleft()``.  Multiple
    commands can be in flight at once — a ``read_batch`` fans out to
    every shard before the first reply is awaited, which is what makes
    the workers compute in parallel.

    Background prefetch candidates queue separately (bounded, deduped)
    and at most **one** coalesced ``prefetch_batch`` command is in
    flight per channel, so a demand command never waits behind more
    than one bounded batch of capped background fetches — the process
    driver's version of the ThreadedExecutor's demand>prefetch
    priority.  Pending arena frees piggyback on the next outbound
    command."""

    def __init__(self, sid: int, conn, proc, capacity: int = 0,
                 budget: Optional[RestartBudget] = None) -> None:
        self.sid = sid
        self.conn = conn
        self.proc = proc
        self.send_lock = threading.Lock()
        self.pending: Deque[object] = deque()     # _RPC | _PrefetchBatch
        self.cv = threading.Condition()           # background bookkeeping
        # (path, size, key, now)
        self.background: Deque[Tuple[PathT, int, str, float]] = deque()
        self.keys: Set[str] = set()        # queued + in-flight candidates
        self.outstanding = 0               # background items not yet done
        self.batch_inflight = False
        self.pending_frees: List[Tuple[int, int]] = []
        self.closed = False                # no new sends accepted
        # -- fault-tolerance state (supervisor-owned transitions) -----------
        self.state = SHARD_UP              # up | restarting | down
        self.generation = 0                # bumped on every respawn
        self.capacity = capacity           # client-tracked (frozen on death)
        self.budget = budget or RestartBudget()
        self.live: Dict[int, int] = {}     # arena slots with client views
        self.last_stats: Optional[dict] = None   # last good "stats" reply
        self.stats_carry = CacheStats()    # counters from dead generations
        self.recv_thread: Optional[threading.Thread] = None
        self.died_at = 0.0                 # monotonic time of last death

    # -- outbound ------------------------------------------------------------
    def send_rpc(self, rpc: _RPC) -> bool:
        with self.send_lock:
            if self.closed:
                return False
            self.pending.append(rpc)
            try:
                self.conn.send((rpc.op, self.take_frees(), rpc.payload))
            except (OSError, ValueError, BrokenPipeError):
                self.pending.pop()         # ours: nothing was sent
                return False
            return True

    def send_batch(self, batch: _PrefetchBatch, payload) -> bool:
        with self.send_lock:
            if self.closed:
                return False
            self.pending.append(batch)
            try:
                self.conn.send(("prefetch_batch", self.take_frees(),
                                payload))
            except (OSError, ValueError, BrokenPipeError):
                self.pending.pop()
                return False
            return True

    # -- background queue ----------------------------------------------------
    def offer_background(self, path: PathT, size: int, key: str,
                         now: float, depth: int) -> str:
        """'queued' | 'dup' | 'full' | 'closed' (same verdicts as the
        ThreadedExecutor's shard queue)."""
        with self.cv:
            if self.closed:
                return "closed"
            if key in self.keys:
                return "dup"
            if len(self.background) >= depth:
                return "full"
            self.keys.add(key)
            self.background.append((path, size, key, now))
            self.outstanding += 1
            return "queued"

    def pop_batch(self) -> Optional[List[Tuple[PathT, int, str, float]]]:
        """Claim the next coalesced batch (None if one is already in
        flight or nothing is queued).  The claimer must send it and, on
        send failure, call :meth:`batch_done`."""
        with self.cv:
            if self.batch_inflight or not self.background:
                return None
            self.batch_inflight = True
            items = []
            while self.background and len(items) < PREFETCH_COALESCE:
                items.append(self.background.popleft())
            return items

    def batch_done(self, items) -> None:
        with self.cv:
            self.batch_inflight = False
            for _, _, key, _ in items:
                self.keys.discard(key)
            self.outstanding -= len(items)
            self.cv.notify_all()

    def drain_background(self) -> List[Tuple[PathT, int, str, float]]:
        with self.cv:
            items = list(self.background)
            self.background.clear()
            for _, _, key, _ in items:
                self.keys.discard(key)
            self.outstanding -= len(items)
            self.cv.notify_all()
            return items

    # -- arena frees ---------------------------------------------------------
    def note_live(self, offset: int, length: int) -> None:
        """An arena slot descriptor reached the client: until its views
        are collected, a respawned worker must treat it as reserved."""
        if length > 0:
            with self.cv:
                self.live[offset] = length

    def queue_free(self, offset: int, length: int) -> None:
        """Arena slot released client-side (last view collected): queue
        it for the worker's allocator, shipped with the next command.
        While the shard is RESTARTING the free still queues — the next
        generation's allocator has the slot carved out as reserved and
        this free is what eventually returns it to the pool."""
        with self.cv:
            self.live.pop(offset, None)
            if not self.closed or self.state == SHARD_RESTARTING:
                self.pending_frees.append((offset, length))

    def take_frees(self) -> List[Tuple[int, int]]:
        with self.cv:
            frees = self.pending_frees
            self.pending_frees = []
            return frees

    def begin_respawn(self) -> List[Tuple[int, int]]:
        """Atomic hand-off point for a respawn: returns the live-slot
        snapshot the new worker must reserve and drops frees queued for
        the *old* allocator (their slots are not in the snapshot, so the
        new allocator already considers them free — shipping them would
        double-free).  Frees queued after this call are for reserved
        slots and ship normally."""
        with self.cv:
            self.pending_frees = []
            return list(self.live.items())

    def wait_idle(self, timeout: Optional[float]) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self.cv:
            while self.outstanding > 0:
                if self.closed:
                    # dead channel: its queued work has been drained /
                    # failed — report the truth promptly instead of
                    # sleeping out the caller's full timeout
                    return False
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self.cv.wait(rem if rem is not None else 0.1)
        return True


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

class ProcessShardedCache(ShardRouting):
    """Process-backed shard driver behind the engine's public API.

    Same surface as ``ShardedIGTCache`` — ``read`` / ``read_batch`` /
    ``read_serial`` / ``complete_prefetch`` / ``cancel_prefetch`` /
    ``pin`` / ``never_cache`` / ``tick`` / ``stats`` / ``hit_ratio`` /
    ``snapshot`` — with each shard kernel running in its own worker
    process.  ``read_batch`` splits the batch by shard, sends every
    sub-batch before waiting (one round-trip per shard, the sub-batches
    execute **in parallel** across workers), and reassembles outcomes in
    request order.

    ``prefetch`` selects the candidate protocol: ``"client"`` (default)
    returns candidates in the outcomes for a ``PrefetchExecutor`` to
    run; ``"inline"`` completes them worker-side at the read's own
    ``now`` — the exact kernel-loop protocol benchmarks compare against.

    ``store`` may be a URI (each worker re-opens it — per-process file
    handles and capability negotiation) or a store instance (shipped via
    ``storage.api.store_spec``; under the default ``fork`` start method
    the child inherits it, under ``spawn`` it must pickle).
    """

    def __init__(self, store, capacity: int, *,
                 cfg: Optional[CacheConfig] = None,
                 options: Optional[EngineOptions] = None,
                 n_procs: int = 2,
                 arena_bytes: int = DEFAULT_ARENA_BYTES,
                 prefetch: str = "client",
                 backing=None,
                 start_method: Optional[str] = None,
                 retry=None,
                 pause_worker_gc: bool = False,
                 supervise: bool = True,
                 restart_budget: int = 3,
                 restart_window_s: float = 60.0,
                 heartbeat_s: Optional[float] = None,
                 rpc_timeout_s: Optional[float] = 30.0) -> None:
        if prefetch not in ("client", "inline"):
            raise ValueError(f"prefetch must be 'client' or 'inline', "
                             f"got {prefetch!r}")
        self._init_routing(n_procs)
        from ..storage.api import store_spec
        if isinstance(store, str):
            from ..storage.api import open_store
            spec = ("uri", store)
            store = open_store(store)
        else:
            spec = store_spec(store)
        # `backing` overrides where the workers fetch *bytes* from (the
        # store stays the kernel's metadata source) — mirrors the
        # CacheClient knob so a process-driver client fetches hits and
        # misses from the same source
        backing_spec = (None if backing is None or backing is store
                        else store_spec(backing))
        self.meta: StoreMeta = store
        self.cfg = cfg or CacheConfig()
        _sync_block_size(store, self.cfg)
        self.options = options or EngineOptions()
        self.capacity = capacity
        self.prefetch_mode = prefetch
        self.global_rebalancer = GlobalRebalancer(self.cfg)
        self._inline = prefetch == "inline"
        self._executor: Optional["ProcessExecutor"] = None
        self._executor_lock = threading.Lock()
        self._closed = False
        self._lock = threading.Lock()
        self.supervise = supervise
        self.rpc_timeout_s = rpc_timeout_s
        self.heartbeat_s = heartbeat_s
        self._hb = Heartbeat(deadline_s=heartbeat_s or 0.0)
        # replayed to a respawned worker (its kernel comes back cold)
        self._pin_log: List[PathT] = []
        self._never_log: List[PathT] = []
        self.fault_events: List[dict] = []
        self._respawn_q: Deque[_ShardChannel] = deque()
        self._respawn_cv = threading.Condition()

        if start_method is None:
            start_method = ("fork" if "fork"
                            in multiprocessing.get_all_start_methods()
                            else "spawn")
        ctx = multiprocessing.get_context(start_method)
        # everything a respawn needs to rebuild a worker cold
        self._spawn = dict(ctx=ctx, spec=spec, backing_spec=backing_spec,
                           retry=retry, pause_gc=pause_worker_gc)
        self.arena = ShmArena(arena_bytes, n_procs)
        self._channels: List[_ShardChannel] = []
        caps = split_capacity(capacity, n_procs)
        # spawn every worker BEFORE starting any dispatcher thread (a
        # fork of a multi-threaded parent is where fork goes wrong).
        # Each child end is closed IMMEDIATELY after its start: a later
        # fork must not inherit an earlier pipe's child end, or killing
        # that earlier worker never EOFs its pipe (the dup keeps the
        # write side open) and the death goes undetected.
        for sid in range(n_procs):
            parent, child = ctx.Pipe()
            proc = ctx.Process(
                target=_worker_main,
                args=(child, self.arena.name, self.arena.region(sid), spec,
                      backing_spec, caps[sid], self.cfg, self.options, sid,
                      retry, pause_worker_gc),
                name=f"igt-shard-{sid}", daemon=True)
            proc.start()
            child.close()                 # parent keeps only its end
            self._channels.append(_ShardChannel(
                sid, parent, proc, capacity=caps[sid],
                budget=RestartBudget(restart_budget, restart_window_s)))
        self._threads = []
        for ch in self._channels:
            t = threading.Thread(target=self._receive, args=(ch,),
                                 name=f"igt-chan-{ch.sid}", daemon=True)
            ch.recv_thread = t
            t.start()
            self._threads.append(t)
        self._supervisor: Optional[threading.Thread] = None
        if supervise:
            self._supervisor = threading.Thread(
                target=self._supervise_loop, name="igt-supervisor",
                daemon=True)
            self._supervisor.start()
        # pass the channel list (not a proc snapshot): respawns swap
        # ch.proc, and the safety net must kill the *current* generation
        self._finalizer = weakref.finalize(self, _cleanup_leftovers,
                                           self.arena, self._channels)
        # capability re-negotiation: each worker reports what *its* store
        # instance can do (a URI re-open may differ from the client's)
        self.worker_info = [self._rpc(sid, "hello", None)
                            for sid in range(n_procs)]

    # -------------------------------------------------------------- receiver
    def _receive(self, ch: _ShardChannel) -> None:
        """The channel's single reply consumer: blocks in ``recv`` (no
        polling, no notify ping-pong), matches each reply to the FIFO
        of in-flight commands.  One thread per channel so the byte
        reads and reply unpickling of different shards overlap (recv
        releases the GIL while reading the pipe).  A worker death (or a
        deliberate close) breaks the pipe, which wakes this thread to
        fail everything still pending instead of letting callers
        hang."""
        stopped = False
        beat = self.heartbeat_s is not None
        try:
            while True:
                try:
                    status, result = ch.conn.recv()
                except (EOFError, OSError):
                    break
                if beat:
                    self._hb.beat(ch.sid, time.monotonic())
                item = ch.pending.popleft()
                if isinstance(item, _PrefetchBatch):
                    self._on_batch_reply(ch, item, status, result)
                    self._pump_prefetch(ch)
                    continue
                if status == "ok" and item.op == "fetch":
                    # register live arena slots HERE, before the caller
                    # can even see the descriptors: if the worker dies
                    # and respawns, the new allocator must already treat
                    # them as reserved
                    for entry in result[0]:
                        if entry[0] == "shm":
                            ch.note_live(entry[1], entry[2])
                if status == "err":
                    item.error = result
                else:
                    item.reply = result
                item.event.set()
                if item.op == "stop":
                    stopped = True
                    break
        finally:
            # even on an unexpected receiver error (protocol bug,
            # unpicklable reply), no caller may be left hanging
            self._fail_channel(ch, graceful=stopped)

    def _fail_channel(self, ch: _ShardChannel, graceful: bool) -> None:
        with ch.send_lock:
            ch.closed = True
            if not graceful and ch.state == SHARD_UP:
                ch.state = SHARD_RESTARTING
        ch.died_at = time.monotonic()
        err = None if graceful else ShardUnavailableError(
            f"shard worker {ch.sid} died (exit code {ch.proc.exitcode}) "
            f"with commands in flight", sid=ch.sid, state=ch.state)
        while ch.pending:
            item = ch.pending.popleft()
            if isinstance(item, _PrefetchBatch):
                self._on_batch_reply(ch, item, "err", None)
                continue
            item.error = err or RuntimeError(
                "ProcessShardedCache channel closed with the RPC in flight")
            item.event.set()
        # queued-but-never-sent candidates: account as cancelled so the
        # executor identity still balances (the kernel died with its
        # pending table, there is nothing left to leak).  The executor
        # pointer is read under the registration lock so a concurrent
        # ProcessExecutor.close cannot detach between the read and the
        # accounting (the death-during-close stats race).
        drained = ch.drain_background()
        with self._executor_lock:
            sink = self._executor
            if drained and sink is not None:
                with sink._stats_lock:
                    sink.stats.cancelled += len(drained)
        if not graceful:
            # the dead kernel's counters survive as carried history so
            # the merged driver stats stay (approximately) monotone
            # across respawns — the delta since the last stats RPC is
            # lost with the process
            if ch.last_stats is not None:
                ch.stats_carry = CacheStats.merged(
                    [ch.stats_carry, ch.last_stats["stats"]])
                ch.last_stats = None
            if self.supervise and not self._closed \
                    and ch.state == SHARD_RESTARTING:
                with self._respawn_cv:
                    self._respawn_q.append(ch)
                    self._respawn_cv.notify_all()

    def _on_batch_reply(self, ch: _ShardChannel, batch: _PrefetchBatch,
                        status: str, result) -> None:
        if status == "ok":
            completed, cancelled, retries, errors = result
        else:
            # worker unreachable / errored: its kernel is gone with its
            # pending table — account the batch as cancelled so the
            # executor identity still balances
            completed, retries = 0, 0
            cancelled = errors = len(batch.items)
        with self._executor_lock:
            sink = self._executor
            if sink is not None:
                with sink._stats_lock:
                    sink.stats.completed += completed
                    sink.stats.cancelled += cancelled
                    sink.stats.retries += retries
                    sink.stats.fetch_errors += errors
        ch.batch_done(batch.items)

    def _pump_prefetch(self, ch: _ShardChannel) -> None:
        """Launch the next coalesced prefetch batch if none is in
        flight.  Called after an offer (kick-start) and after each batch
        reply (drain)."""
        items = ch.pop_batch()
        if not items:
            return
        with self._executor_lock:
            sink = self._executor
        cap = sink.max_fetch_bytes if sink is not None else 0
        batch = _PrefetchBatch(items)
        payload = ([(p, s) for p, s, _, _ in items], items[-1][3], cap)
        if not ch.send_batch(batch, payload):
            self._on_batch_reply(ch, batch, "err", None)

    # ------------------------------------------------------------------ RPC
    def _rpc_async(self, sid: int, op: str, payload) -> _RPC:
        ch = self._channels[sid]
        rpc = _RPC(op, payload)
        if not ch.send_rpc(rpc):
            if self._closed:
                rpc.error = RuntimeError(
                    f"{op!r} on a closed ProcessShardedCache")
            else:
                rpc.error = ShardUnavailableError(
                    f"shard {sid} is {ch.state} ({op!r} rejected)",
                    sid=sid, state=ch.state)
            rpc.event.set()
        elif self.heartbeat_s is not None:
            self._hb.beat(sid, time.monotonic())
        return rpc

    def _wait_rpc(self, sid: int, rpc: _RPC, timeout=_UNSET):
        """Bounded wait: a worker that neither replies nor dies within
        the RPC timeout is treated as hung — it is killed (SIGKILL works
        on a SIGSTOPped process too), which breaks the pipe and routes
        it through the normal death → supervision path — and the caller
        gets a typed ``ShardUnavailableError`` instead of blocking
        forever."""
        t = self.rpc_timeout_s if timeout is _UNSET else timeout
        try:
            return rpc.wait(t)
        except TimeoutError:
            self._kill_worker(sid, f"RPC {rpc.op!r} exceeded {t}s")
            raise ShardUnavailableError(
                f"shard {sid} RPC {rpc.op!r} timed out after {t}s",
                sid=sid, state=self._channels[sid].state) from None

    def _kill_worker(self, sid: int, reason: str) -> None:
        ch = self._channels[sid]
        proc = ch.proc
        if proc.is_alive():
            kill = getattr(proc, "kill", proc.terminate)
            kill()
        self.fault_events.append({"sid": sid, "kind": "kill",
                                  "reason": reason,
                                  "at": time.monotonic(),
                                  "generation": ch.generation})

    def _rpc(self, sid: int, op: str, payload, timeout=_UNSET):
        return self._wait_rpc(sid, self._rpc_async(sid, op, payload),
                              timeout)

    def _broadcast(self, op: str, payload, timeout=_UNSET,
                   tolerant: bool = False) -> list:
        """Fan an RPC to all shards.  ``tolerant`` skips shards that are
        not UP and swallows per-shard unavailability (used for controls
        and maintenance, which a down shard must not poison)."""
        sids = [sid for sid in range(self.n_shards)
                if not tolerant or self._channels[sid].state == SHARD_UP]
        rpcs = [(sid, self._rpc_async(sid, op, payload)) for sid in sids]
        out = []
        for sid, r in rpcs:
            try:
                out.append(self._wait_rpc(sid, r, timeout))
            except ShardUnavailableError:
                if not tolerant:
                    raise
        return out

    # ------------------------------------------------------------ supervisor
    def _supervise_loop(self) -> None:
        """One supervision thread per driver: respawns dead workers
        (queued by the receiver threads' ``_fail_channel``) and, when
        ``heartbeat_s`` is set, kills workers that have in-flight
        commands but no pipe activity within the deadline (a hung/
        suspended worker never breaks its own pipe — this turns a stall
        into a detectable death)."""
        poll = (min(self.heartbeat_s / 2, 0.2)
                if self.heartbeat_s else 0.5)
        while True:
            with self._respawn_cv:
                if not self._respawn_q and not self._closed:
                    self._respawn_cv.wait(poll)
                if self._closed:
                    return
                ch = self._respawn_q.popleft() if self._respawn_q else None
            if ch is not None:
                self._respawn(ch)
                continue
            if self.heartbeat_s is not None:
                self._check_stalls()

    def _check_stalls(self) -> None:
        now = time.monotonic()
        for sid in self._hb.dead_workers(now):
            ch = self._channels[sid]
            # only a worker with commands in flight can be "stalled" —
            # an idle worker legitimately sends nothing
            if ch.state == SHARD_UP and ch.pending and ch.proc.is_alive():
                self._kill_worker(sid, f"heartbeat missed "
                                       f"({self.heartbeat_s}s)")
            self._hb.beat(sid, now)    # one kill per stall detection

    def _respawn(self, ch: _ShardChannel) -> None:
        """Bring a dead shard back: fresh process, same region and
        capacity, store re-opened from its spec, kernel rebuilt cold.
        Slots with live client views are pre-reserved so stale reads
        stay valid; the restart budget turns a crash loop into a
        permanent, stable DOWN."""
        now = time.monotonic()
        if self._closed or self.arena._closed \
                or ch.state != SHARD_RESTARTING:
            return
        if not ch.budget.allow(now):
            ch.state = SHARD_DOWN
            self.fault_events.append({
                "sid": ch.sid, "kind": "down", "died_at": ch.died_at,
                "at": now, "generation": ch.generation,
                "restarts_used": ch.budget.used})
            return
        sp = self._spawn
        ctx = sp["ctx"]
        reserved = ch.begin_respawn()
        parent, child = ctx.Pipe()
        proc = ctx.Process(
            target=_worker_main,
            args=(child, self.arena.name, self.arena.region(ch.sid),
                  sp["spec"], sp["backing_spec"], ch.capacity, self.cfg,
                  self.options, ch.sid, sp["retry"], sp["pause_gc"],
                  reserved),
            name=f"igt-shard-{ch.sid}g{ch.generation + 1}", daemon=True)
        try:
            proc.start()
        except Exception:                   # pragma: no cover - fork failed
            ch.state = SHARD_DOWN
            self.fault_events.append({
                "sid": ch.sid, "kind": "down", "died_at": ch.died_at,
                "at": now, "generation": ch.generation,
                "restarts_used": ch.budget.used})
            return
        child.close()
        with ch.send_lock:
            ch.conn = parent
            ch.proc = proc
            ch.generation += 1
            ch.closed = False
            ch.state = SHARD_UP
        t = threading.Thread(target=self._receive, args=(ch,),
                             name=f"igt-chan-{ch.sid}g{ch.generation}",
                             daemon=True)
        ch.recv_thread = t
        t.start()
        self._threads.append(t)
        if self.heartbeat_s is not None:
            self._hb.beat(ch.sid, time.monotonic())
        # the kernel came back cold: replay the sticky controls and
        # refresh the capability info (best-effort — if it dies again
        # mid-replay the new receiver routes it back through here)
        try:
            for path in self._pin_log:
                self._rpc(ch.sid, "pin", path)
            for path in self._never_log:
                self._rpc(ch.sid, "never_cache", path)
            self.worker_info[ch.sid] = self._rpc(ch.sid, "hello", None)
        except (ShardUnavailableError, RuntimeError, TimeoutError):
            pass
        up_at = time.monotonic()
        self.fault_events.append({
            "sid": ch.sid, "kind": "respawn", "died_at": ch.died_at,
            "respawned_at": up_at, "recovery_s": up_at - ch.died_at,
            "generation": ch.generation,
            "restarts_used": ch.budget.used})

    def fault_stats(self) -> dict:
        """Supervision observability: per-shard state/generation/budget
        plus the chronological event log (kills, respawns with recovery
        time, permanent downs)."""
        return {
            "restarts": sum(ch.generation for ch in self._channels),
            "shards": {ch.sid: {"state": ch.state,
                                "generation": ch.generation,
                                "restarts_used": ch.budget.used,
                                "capacity": ch.capacity}
                       for ch in self._channels},
            "events": list(self.fault_events),
        }

    def shard_states(self) -> List[str]:
        return [ch.state for ch in self._channels]

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: float) -> WireOutcome:
        enc, _ = self._rpc(self.shard_id(file_path), "read",
                           (file_path, offset, size, now, self._inline))
        return WireOutcome(enc, file_path)

    def read_serial(self, file_path: PathT, offset: int, size: int,
                    now: float) -> WireOutcome:
        enc, _ = self._rpc(self.shard_id(file_path), "read_serial",
                           (file_path, offset, size, now, self._inline))
        return WireOutcome(enc, file_path)

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: float) -> List[WireOutcome]:
        """One round-trip per shard: all sub-batches are in flight
        before the first reply is awaited, so shard kernels execute the
        batch in parallel across processes."""
        requests = list(requests)
        if self.n_shards == 1:
            try:
                encs, _ = self._rpc(0, "read_batch",
                                    (requests, now, self._inline))
            except ShardUnavailableError as e:
                raise ShardUnavailableError(
                    str(e), sid=e.sid, state=e.state,
                    partial=[None] * len(requests),
                    indices=list(range(len(requests)))) from None
            return [WireOutcome(e, req[0])
                    for e, req in zip(encs, requests)]
        buckets = self.bucket_by_shard(requests)
        pending = [(sid, items, self._rpc_async(
                        sid, "read_batch",
                        ([r for _, r in items], now, self._inline)))
                   for sid, items in buckets.items()]
        outs: List[Optional[WireOutcome]] = [None] * len(requests)
        failed: List[int] = []
        first: Optional[ShardUnavailableError] = None
        for sid, items, rpc in pending:
            try:
                encs, _ = self._wait_rpc(sid, rpc)
            except ShardUnavailableError as e:
                # keep collecting the healthy shards' outcomes — the
                # error carries them so the client degrades only the
                # failed sub-batch instead of re-reading (and thereby
                # double-observing) the survivors
                if first is None:
                    first = e
                failed.extend(i for i, _ in items)
                continue
            for (i, req), enc in zip(items, encs):
                outs[i] = WireOutcome(enc, req[0])
        if first is not None:
            raise ShardUnavailableError(
                str(first), sid=first.sid, state=first.state,
                partial=outs, indices=sorted(failed)) from None
        return outs  # type: ignore[return-value]

    # ------------------------------------------------------------- prefetch
    def complete_prefetch(self, path: PathT, size: int, now: float) -> bool:
        try:
            return self._rpc(self.shard_id(path), "complete",
                             (path, size, now))
        except ShardUnavailableError:
            # the kernel died with its pending table — nothing to admit
            return False

    def cancel_prefetch(self, path: PathT) -> None:
        try:
            self._rpc(self.shard_id(path), "cancel", path)
        except ShardUnavailableError:
            pass                 # dead kernel: nothing left to leak

    # ------------------------------------------------------------------ tick
    def tick(self, now: float) -> None:
        """Per-shard maintenance plus, when due, the cross-shard round
        over the workers' serialized demand summaries.  Down/restarting
        shards are skipped — maintenance must not poison the callers.
        Unlike the in-process facade there is no starvation-triggered
        early round (spotting a sub-min-share CMU would cost an RPC
        sweep per tick); the retrying floor top-up inside the planner
        still repairs starvation on the next periodic round."""
        if (self.n_shards > 1 and self.options.allocation == "adaptive"
                and self.global_rebalancer.due(now)):
            self.rebalance_now(now)
        self._broadcast("tick", now, tolerant=True)

    def rebalance_now(self, now: float) -> int:
        """One cross-shard allocation round: gather ``DemandSummary``
        rows from the *reachable* workers, plan with the same greedy
        rule as the in-process facade, ship the deltas back.  A down
        shard contributes no rows, so its capacity is frozen exactly
        where it died — moves conserve capacity among the survivors and
        the cluster total stays intact for when it returns.  Returns the
        number of quantum moves applied."""
        reb = self.global_rebalancer
        reb.last_round = now
        summaries: List[ShardSummary] = [
            got for got in self._broadcast("rebalance_summary", now,
                                           tolerant=True)
            if got is not None]
        rows: List[DemandSummary] = [r for s in summaries for r in s.rows]
        moves = reb.plan_moves(rows)
        reb.note_round(now, summaries, moves)
        if not moves:
            return 0
        shrinks: Dict[int, List[Tuple[PathT, int]]] = {}
        grows: Dict[int, List[Tuple[PathT, int]]] = {}
        cap_delta: Dict[int, int] = {}
        for donor, taker, amt in moves:
            shrinks.setdefault(donor.shard, []).append((donor.key, amt))
            cap_delta[donor.shard] = cap_delta.get(donor.shard, 0) - amt
            cap_delta[taker.shard] = cap_delta.get(taker.shard, 0) + amt
            grows.setdefault(taker.shard, []).append((taker.key, amt))
        # client-tracked capacities move FIRST: they are what a respawn
        # hands the replacement worker, so even a death mid-apply keeps
        # sum(shard capacities) == cluster capacity
        for sid, delta in cap_delta.items():
            self._channels[sid].capacity += delta
        pending = [(sid, self._rpc_async(sid, "rebalance_apply",
                                         (shrinks.get(sid, []),
                                          cap_delta.get(sid, 0),
                                          grows.get(sid, []))))
                   for sid in cap_delta]
        for sid, rpc in pending:
            try:
                self._wait_rpc(sid, rpc)
            except ShardUnavailableError:
                pass   # respawn re-applies via ch.capacity
        return len(moves)

    # ------------------------------------------------------------- controls
    def pin(self, path: PathT) -> None:
        self._pin_log.append(path)    # replayed to respawned (cold) workers
        self._broadcast("pin", path, tolerant=True)

    def never_cache(self, path: PathT) -> None:
        self._never_log.append(path)
        self._broadcast("never_cache", path, tolerant=True)

    def invalidate_meta_cache(self) -> None:
        """Mid-run dataset change (the ``LocalFSStore.refresh``
        workflow): every worker re-walks its own store instance (the
        client-side store's ``refresh()`` cannot reach worker
        snapshots) and drops its kernel's memoized metadata; the
        client-side store is refreshed here too so planning
        (``_plan_ranges``) agrees with the workers."""
        refresh = getattr(self.meta, "refresh", None)
        if callable(refresh):
            refresh()
        self._broadcast("invalidate_meta", None, tolerant=True)

    # ----------------------------------------------------------------- stats
    def _channel_stats(self, ch: _ShardChannel) -> dict:
        """One shard's stats dict — live from the worker when it is UP,
        else the last reply seen before it died (capacity overridden
        with the client-tracked value, which stays authoritative across
        rebalances and respawns)."""
        if ch.state == SHARD_UP:
            try:
                got = self._rpc(ch.sid, "stats", None)
                ch.last_stats = got
                return got
            except ShardUnavailableError:
                pass
        got = dict(ch.last_stats) if ch.last_stats is not None else {
            "stats": CacheStats(), "nodes": 0, "used": 0, "cmus": 0,
            "pending": 0, "spills": 0, "arena_free": 0}
        got["capacity"] = ch.capacity
        return got

    def _gather_stats(self) -> List[dict]:
        out = []
        for ch in self._channels:
            g = self._channel_stats(ch)
            if ch.generation > 0:
                # fold in the counters carried over from generations
                # that died (a respawned kernel restarts from zero)
                g = dict(g)
                g["stats"] = CacheStats.merged([ch.stats_carry,
                                                g["stats"]])
            out.append(g)
        return out

    @property
    def stats(self) -> CacheStats:
        """Point-in-time merge of the worker kernels' counters (same
        snapshot semantic as ``ShardedIGTCache.stats``)."""
        return CacheStats.merged(g["stats"] for g in self._gather_stats())

    def hit_ratio(self) -> float:
        return self.stats.hit_ratio

    def used_bytes(self) -> int:
        return sum(g["used"] for g in self._gather_stats())

    def node_count(self) -> int:
        return sum(g["nodes"] for g in self._gather_stats())

    def shard_capacities(self) -> List[int]:
        return [g["capacity"] for g in self._gather_stats()]

    def arena_spills(self) -> int:
        """Fetch results that could not get an arena slot and fell back
        to an inline (pickled) reply — 0 means every payload byte
        crossed through shared memory."""
        return sum(g["spills"] for g in self._gather_stats())

    def pending_prefetch_count(self) -> int:
        """Total candidates pending in the worker kernels (leak probe
        for the executor-contract tests)."""
        return sum(g["pending"] for g in self._gather_stats())

    def snapshot(self) -> dict:
        gathered = self._gather_stats()
        s = CacheStats.merged(g["stats"] for g in gathered).snapshot()
        s["nodes"] = sum(g["nodes"] for g in gathered)
        s["cmus"] = sum(g["cmus"] for g in gathered)
        s["used_bytes"] = sum(g["used"] for g in gathered)
        s["arena_spills"] = sum(g["spills"] for g in gathered)
        return s

    def workload_cmus(self) -> list:
        return [c for _, c in self.iter_workload_cmus()]

    def iter_workload_cmus(self):
        """(root_path, summary) pairs.  The CMUs live in the worker
        processes; what crosses back is a read-only :class:`CmuView`
        (quota/used/hits/misses/pattern), not the live object."""
        for sid in range(self.n_shards):
            if self._channels[sid].state != SHARD_UP:
                continue
            try:
                rows = self._rpc(sid, "cmus", None)
            except ShardUnavailableError:
                continue
            for path, pat, quota, used, hits, misses in rows:
                yield tuple(path), CmuView(tuple(path), Pattern(pat),
                                           quota, used, hits, misses)

    # ------------------------------------------------------------- executor
    def _register_executor(self,
                           executor: Optional["ProcessExecutor"]) -> None:
        # under the lock so a receiver thread mid-death-accounting can
        # never race an executor attaching/detaching (satellite: the
        # death-during-close stats race)
        with self._executor_lock:
            self._executor = executor

    def flush(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for ch in self._channels:
            rem = None if deadline is None else deadline - time.monotonic()
            if not ch.wait_idle(rem):
                return False
        return True

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: float = 5.0) -> None:
        """Stop the workers and release the arena.  Queued background
        candidates are dropped (close the attached executor *first* if
        its accounting must balance — ``CacheClient.close`` does)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # stop the supervisor first: no respawns may race the shutdown
        with self._respawn_cv:
            self._respawn_q.clear()
            self._respawn_cv.notify_all()
        if self._supervisor is not None:
            self._supervisor.join(timeout=timeout)
        for ch in self._channels:
            ch.drain_background()
        # the stop command rides the normal FIFO, so every in-flight
        # command drains first; the receiver exits on the stop reply
        stops = [self._rpc_async(ch.sid, "stop", None)
                 for ch in self._channels]
        for rpc in stops:
            try:
                rpc.wait(timeout)
            except Exception:
                pass
        for ch in self._channels:
            with ch.send_lock:
                ch.closed = True
        for t in self._threads:
            t.join(timeout=timeout)
        for ch in self._channels:
            ch.proc.join(timeout=timeout)
            if ch.proc.is_alive():          # pragma: no cover - stuck worker
                ch.proc.terminate()         # breaks the pipe → receiver
                ch.proc.join(timeout=1.0)   # wakes and fails its pending
        for t in self._threads:             # pragma: no cover - stuck worker
            if t.is_alive():
                t.join(timeout=1.0)
        for ch in self._channels:
            try:
                ch.conn.close()
            except OSError:                 # pragma: no cover
                pass
        self.arena.close()
        self._finalizer.detach()

    def __enter__(self) -> "ProcessShardedCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _cleanup_leftovers(arena: ShmArena, channels) -> None:
    """GC / interpreter-exit safety net: never leak worker processes or
    the shared-memory block when a driver is dropped without close()."""
    for ch in channels:
        if ch.proc.is_alive():
            ch.proc.terminate()
    arena.close()


class CmuView:
    """Read-only CMU summary shipped from a worker (the process driver's
    ``iter_workload_cmus`` payload — live CMUs cannot cross the pipe)."""

    __slots__ = ("root_path", "pattern", "quota", "used", "hits", "misses",
                 "substreams")

    def __init__(self, root_path, pattern, quota, used, hits, misses):
        self.root_path = root_path
        self.pattern = pattern
        self.quota = quota
        self.used = used
        self.hits = hits
        self.misses = misses
        self.substreams: dict = {}

    def effective_pattern(self) -> Pattern:
        return self.pattern


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

class ProcessExecutor(PrefetchExecutor):
    """`PrefetchExecutor` over a :class:`ProcessShardedCache`.

    Candidates route to their shard's background queue (bounded,
    deduped); the shard's dispatcher coalesces them into
    ``prefetch_batch`` commands that fetch + complete **inside the
    worker process** — the client never touches prefetch bytes.  Demand
    fetches are demand-class RPCs served via the shared-memory arena.
    Dedup/overflow/shutdown cancellations reach the worker kernel as
    batched ``cancel_many`` commands, so the pending tables never leak
    and ``submitted == completed + cancelled + deduped`` holds at close.
    """

    def __init__(self, queue_depth: int = 4096,
                 max_fetch_bytes: int = 4096) -> None:
        super().__init__()
        self.queue_depth = queue_depth
        self.max_fetch_bytes = max_fetch_bytes
        self.driver: Optional[ProcessShardedCache] = None
        self._closed = False

    def attach(self, engine, backing, guard, clock, retry=None) -> None:
        if not isinstance(engine, ProcessShardedCache):
            raise TypeError(
                "ProcessExecutor needs a ProcessShardedCache engine "
                f"(driver='process'), got {type(engine).__name__}")
        super().attach(engine, backing, guard, clock, retry)
        self.driver = engine
        engine._register_executor(self)

    # -- candidate path -----------------------------------------------------
    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        from .cache import path_key
        d = self.driver
        if self._closed:
            # release the kernel's pending entries, then fail loudly —
            # same close-vs-submit semantics as the ThreadedExecutor
            self._cancel_candidates(candidates)
            raise RuntimeError("submit() on a closed ProcessExecutor")
        with self._stats_lock:
            self.stats.submitted += len(candidates)
        cancels: Dict[int, List[PathT]] = {}
        touched: Set[int] = set()
        for path, size in candidates:
            sid = d.shard_id(path)
            got = d._channels[sid].offer_background(
                path, size, path_key(path), now, self.queue_depth)
            if got == "queued":
                touched.add(sid)
                continue
            with self._stats_lock:
                if got == "dup":
                    self.stats.deduped += 1
                else:                       # full / closed
                    self.stats.cancelled += 1
            cancels.setdefault(sid, []).append(path)
        for sid, paths in cancels.items():
            d._rpc_async(sid, "cancel_many", paths)   # fire-and-forget
        for sid in touched:                 # kick the coalescing pump
            d._pump_prefetch(d._channels[sid])

    def _cancel_candidates(self, candidates) -> None:
        d = self.driver
        with self._stats_lock:
            self.stats.submitted += len(candidates)
            self.stats.cancelled += len(candidates)
        by: Dict[int, List[PathT]] = {}
        for path, _size in candidates:
            by.setdefault(d.shard_id(path), []).append(path)
        for rpc in [d._rpc_async(sid, "cancel_many", paths)
                    for sid, paths in by.items()]:
            try:
                rpc.wait(5.0)
            except Exception:
                pass

    # -- demand path --------------------------------------------------------
    def fetch_demand(self, requests) -> List[np.ndarray]:
        """Split the demand ranges by shard, one ``fetch`` RPC each (all
        in flight before the first wait → shard-parallel ``fetch_many``
        against per-process stores), bytes back through the arena."""
        d = self.driver
        with self._stats_lock:
            self.stats.demand_fetches += len(requests)
        pending = [(sid, items,
                    d._rpc_async(sid, "fetch", [req for _, req in items]))
                   for sid, items in d.bucket_by_shard(requests).items()]
        out: List[Optional[np.ndarray]] = [None] * len(requests)
        error: Optional[BaseException] = None
        for sid, items, rpc in pending:
            try:
                entries, retries = d._wait_rpc(sid, rpc)
            except BaseException as e:
                with self._stats_lock:
                    self.stats.fetch_errors += 1
                if error is None:
                    error = e
                continue
            with self._stats_lock:
                self.stats.retries += retries
            ch = d._channels[sid]
            for (i, _), entry in zip(items, entries):
                if entry[0] == "raw":
                    out[i] = np.asarray(entry[1], dtype=np.uint8)
                else:
                    out[i] = d.arena.view(entry[1], entry[2],
                                          on_release=ch.queue_free)
        if error is not None:
            raise error                     # re-raise in the reader's thread
        return out  # type: ignore[return-value]

    # -- lifecycle ----------------------------------------------------------
    def flush(self, timeout: Optional[float] = None) -> bool:
        return self.driver.flush(timeout) if self.driver else True

    def close(self, cancel_pending: bool = True) -> None:
        if self._closed or self.driver is None:
            return
        if not cancel_pending:
            self.flush()
        self._closed = True
        d = self.driver
        pending = []
        for ch in d._channels:
            drained = ch.drain_background()
            if not drained:
                continue
            with self._stats_lock:
                self.stats.cancelled += len(drained)
            pending.append(d._rpc_async(ch.sid, "cancel_many",
                                        [p for p, _, _, _ in drained]))
        for rpc in pending:
            try:
                rpc.wait(5.0)
            except Exception:
                pass
        # in-flight prefetch batches finish on their own; wait so the
        # stats identity holds the moment close() returns
        self.flush(timeout=10.0)
        d._register_executor(None)

"""Pluggable eviction policies (§3.3 + §5.3 baselines).

Adaptive selection per stream: sequential → eager, random → uniform caching,
skewed → LRU.  The classical policies (LRU/FIFO/LFU/ARC/SIEVE) are also
implemented both as baselines (§5.3) and as building blocks.

All policies speak a narrow interface driven by the CacheManageUnit:

    record_insert(key)      a block belonging to this stream entered the cache
    record_access(key, hit) a read was served (hit) or missed (miss)
    record_remove(key)      the block left the cache (any reason)
    admit(key) -> bool      may this new block enter at all? (uniform: no when full)
    choose_victim()         pick a block to evict to make room (None = refuse)
    force_victim()          pick a block when eviction is mandatory (quota shrink)

Policies track *keys only*; sizes/quotas live in the CacheManageUnit.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Dict, Optional


class EvictionPolicy:
    name = "base"

    def __init__(self) -> None:
        self.resident: set[str] = set()

    # -- bookkeeping -------------------------------------------------------
    def record_insert(self, key: str) -> None:
        self.resident.add(key)

    def record_access(self, key: str, hit: bool) -> None:  # pragma: no cover
        pass

    def record_remove(self, key: str) -> None:
        self.resident.discard(key)

    # -- decisions ----------------------------------------------------------
    def admit(self, key: str) -> bool:
        return True

    def choose_victim(self) -> Optional[str]:  # pragma: no cover
        raise NotImplementedError

    def force_victim(self) -> Optional[str]:
        return self.choose_victim()

    def __len__(self) -> int:
        return len(self.resident)


class LRU(EvictionPolicy):
    name = "lru"

    def __init__(self) -> None:
        super().__init__()
        self._order: "OrderedDict[str, None]" = OrderedDict()

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        self._order[key] = None
        self._order.move_to_end(key)

    def record_access(self, key: str, hit: bool) -> None:
        if hit and key in self._order:
            self._order.move_to_end(key)

    def record_remove(self, key: str) -> None:
        super().record_remove(key)
        self._order.pop(key, None)

    def choose_victim(self) -> Optional[str]:
        if not self._order:
            return None
        return next(iter(self._order))


class FIFO(EvictionPolicy):
    name = "fifo"

    def __init__(self) -> None:
        super().__init__()
        self._queue: deque[str] = deque()

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        self._queue.append(key)

    def record_remove(self, key: str) -> None:
        super().record_remove(key)
        # lazy removal; choose_victim skips non-resident entries

    def choose_victim(self) -> Optional[str]:
        while self._queue:
            k = self._queue[0]
            if k in self.resident:
                return k
            self._queue.popleft()
        return None


class LFU(EvictionPolicy):
    """Frequency-ordered with LRU tie-break (O(1) bucket implementation)."""

    name = "lfu"

    def __init__(self) -> None:
        super().__init__()
        self._freq: Dict[str, int] = {}
        self._buckets: Dict[int, "OrderedDict[str, None]"] = {}
        self._min_freq = 0

    def _bucket(self, f: int) -> "OrderedDict[str, None]":
        return self._buckets.setdefault(f, OrderedDict())

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        self._freq[key] = 1
        self._bucket(1)[key] = None
        self._min_freq = 1

    def record_access(self, key: str, hit: bool) -> None:
        if not hit or key not in self._freq:
            return
        f = self._freq[key]
        bucket = self._buckets.get(f)
        if bucket is not None:
            bucket.pop(key, None)
            if not bucket and self._min_freq == f:
                self._min_freq = f + 1
        self._freq[key] = f + 1
        self._bucket(f + 1)[key] = None

    def record_remove(self, key: str) -> None:
        super().record_remove(key)
        f = self._freq.pop(key, None)
        if f is not None:
            bucket = self._buckets.get(f)
            if bucket is not None:
                bucket.pop(key, None)

    def choose_victim(self) -> Optional[str]:
        if not self._freq:
            return None
        f = self._min_freq
        while f <= max(self._buckets, default=0):
            bucket = self._buckets.get(f)
            if bucket:
                self._min_freq = f
                return next(iter(bucket))
            f += 1
        # fallback: scan
        for f, bucket in sorted(self._buckets.items()):
            if bucket:
                return next(iter(bucket))
        return None


class ARC(EvictionPolicy):
    """Adaptive Replacement Cache (Megiddo & Modha, FAST'03).

    Entry-count based: ``capacity`` is the number of (roughly fixed-size)
    blocks the stream's quota admits.  T1 = recent-once, T2 = frequent,
    B1/B2 = ghost lists; p adapts toward whichever ghost list hits.
    """

    name = "arc"

    def __init__(self, capacity: int = 1024) -> None:
        super().__init__()
        self.capacity = max(1, capacity)
        self.p = 0.0
        self.t1: "OrderedDict[str, None]" = OrderedDict()
        self.t2: "OrderedDict[str, None]" = OrderedDict()
        self.b1: "OrderedDict[str, None]" = OrderedDict()
        self.b2: "OrderedDict[str, None]" = OrderedDict()

    def set_capacity(self, capacity: int) -> None:
        self.capacity = max(1, capacity)

    def record_access(self, key: str, hit: bool) -> None:
        if hit:
            if key in self.t1:
                del self.t1[key]
                self.t2[key] = None
            elif key in self.t2:
                self.t2.move_to_end(key)
            return
        # Miss path: ghost hits adapt p (the actual insert follows).
        if key in self.b1:
            self.p = min(float(self.capacity),
                         self.p + max(1.0, len(self.b2) / max(1, len(self.b1))))
            del self.b1[key]
            self._pending_t2 = key
        elif key in self.b2:
            self.p = max(0.0, self.p - max(1.0, len(self.b1) / max(1, len(self.b2))))
            del self.b2[key]
            self._pending_t2 = key

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        if getattr(self, "_pending_t2", None) == key:
            self.t2[key] = None
            self._pending_t2 = None
        else:
            self.t1[key] = None
        # bound ghost lists
        while len(self.b1) > self.capacity:
            self.b1.popitem(last=False)
        while len(self.b2) > self.capacity:
            self.b2.popitem(last=False)

    def record_remove(self, key: str) -> None:
        super().record_remove(key)
        self.t1.pop(key, None)
        self.t2.pop(key, None)

    def choose_victim(self) -> Optional[str]:
        if self.t1 and (len(self.t1) > self.p or not self.t2):
            k = next(iter(self.t1))
            self.b1[k] = None
            return k
        if self.t2:
            k = next(iter(self.t2))
            self.b2[k] = None
            return k
        if self.t1:
            k = next(iter(self.t1))
            self.b1[k] = None
            return k
        return None


class SIEVE(EvictionPolicy):
    """SIEVE (NSDI'24): FIFO queue + visited bit + moving hand."""

    name = "sieve"

    def __init__(self) -> None:
        super().__init__()
        self._order: "OrderedDict[str, bool]" = OrderedDict()  # key -> visited
        self._hand: Optional[str] = None

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        self._order[key] = False

    def record_access(self, key: str, hit: bool) -> None:
        if hit and key in self._order:
            self._order[key] = True

    def record_remove(self, key: str) -> None:
        super().record_remove(key)
        if self._hand == key:
            self._hand = self._prev_key(key)
        self._order.pop(key, None)

    def _prev_key(self, key: str) -> Optional[str]:
        prev = None
        for k in self._order:
            if k == key:
                return prev
            prev = k
        return None

    def choose_victim(self) -> Optional[str]:
        if not self._order:
            return None
        keys = list(self._order.keys())
        # hand starts at oldest (head) if unset
        try:
            idx = keys.index(self._hand) if self._hand in self._order else 0
        except ValueError:
            idx = 0
        n = len(keys)
        for step in range(2 * n):
            k = keys[idx % n]
            if self._order.get(k):
                self._order[k] = False
                idx += 1
            else:
                self._hand = keys[(idx + 1) % n] if n > 1 else None
                return k
        return keys[0]


class UniformCache(EvictionPolicy):
    """Uniform caching (§2.2, [58, 87]): pin-until-full, never thrash.

    Under a *random* access pattern every cached block has identical hit
    probability, so churn buys nothing; blocks are admitted until the quota is
    reached and never evicted thereafter (except mandatory quota shrink).
    """

    name = "uniform"

    def __init__(self) -> None:
        super().__init__()
        self._stack: list[str] = []
        self.full = False

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        self._stack.append(key)

    def record_remove(self, key: str) -> None:
        super().record_remove(key)

    def mark_full(self, full: bool) -> None:
        self.full = full

    def admit(self, key: str) -> bool:
        return not self.full

    def choose_victim(self) -> Optional[str]:
        return None  # never evict to admit

    def force_victim(self) -> Optional[str]:
        while self._stack:
            k = self._stack.pop()
            if k in self.resident:
                return k
        return None


class EagerEviction(EvictionPolicy):
    """Eager eviction for sequential streams (§3.3): evict right after use.

    The CacheManageUnit consults ``consumed()`` after each hit and evicts the
    block immediately — a sequentially-read block will not be read again.
    Prefetched-but-not-yet-read blocks are retained (they are the readahead
    window); victim order is FIFO if space is still needed.
    """

    name = "eager"

    def __init__(self) -> None:
        super().__init__()
        self._fifo: deque[str] = deque()
        self._used: set[str] = set()

    def record_insert(self, key: str) -> None:
        super().record_insert(key)
        self._fifo.append(key)

    def record_access(self, key: str, hit: bool) -> None:
        if hit:
            self._used.add(key)

    def record_remove(self, key: str) -> None:
        super().record_remove(key)
        self._used.discard(key)

    def mark_consumed(self, keys) -> None:
        """Blocks known to be behind the stream position (e.g. residents
        carried over from before the pattern switch)."""
        self._used.update(k for k in keys if k in self.resident)

    def consumed_victim(self) -> Optional[str]:
        for k in self._used:
            if k in self.resident:
                return k
        return None

    def evict_after_use(self, key: str) -> bool:
        return True

    def choose_victim(self) -> Optional[str]:
        # Prefer already-consumed blocks; otherwise sacrifice the *newest*
        # unread block (the far end of the readahead window) — the oldest
        # unread block is the very next one the stream will consume.
        for k in list(self._used):
            if k in self.resident:
                return k
        while self._fifo:
            k = self._fifo[-1]
            if k in self.resident:
                return k
            self._fifo.pop()
        return None


POLICIES = {
    "lru": LRU,
    "fifo": FIFO,
    "lfu": LFU,
    "arc": ARC,
    "sieve": SIEVE,
    "uniform": UniformCache,
    "eager": EagerEviction,
}


def make_policy(name: str, capacity_blocks: int = 1024) -> EvictionPolicy:
    cls = POLICIES[name]
    if cls is ARC:
        return ARC(capacity_blocks)
    return cls()

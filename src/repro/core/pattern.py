"""Online pattern recognition for one AccessStream (§3.2) + adaptive TTL (§3.3).

Decision procedure, purely from cache-side information:

1. sequential — the signed spatial gaps of consecutive accesses are
   overwhelmingly a small constant positive stride (unit stride for block
   scans and listing-order traversals).  Existing-practice detector.
2. otherwise run the K-S test of the |gap| samples against the triangular
   permutation law over [1, c]:  accept → random, reject → skewed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

from .ks import ks_test_random, normal_quantile
from .types import AccessRecord, CacheConfig, Pattern


@dataclass
class PatternResult:
    pattern: Pattern
    d_stat: float = 0.0
    d_critical: float = 0.0
    stride: int = 1  # detected stride when sequential
    seq_fraction: float = 0.0


def spatial_gaps(records: Sequence[AccessRecord]) -> list[int]:
    return [records[i].index - records[i - 1].index for i in range(1, len(records))]


MAX_STRIDE = 16


def detect_sequential(gaps: Sequence[int], threshold: float) -> tuple[bool, int, float]:
    """Return (is_sequential, stride, fraction-in-order).

    A stream is sequential when consecutive accesses move monotonically
    forward in small steps: at least ``threshold`` of the gaps lie in
    [0, MAX_STRIDE], backwards seeks are rare (<= 1 - threshold), and there is
    net forward drift.  Gap 0 counts as in-order — a coarse (directory) level
    sees long runs of 0 while a child is being traversed, punctuated by +1 on
    child switches.  Random streams fail on the backwards-seek test
    (~half their gaps are negative); skewed streams fail on both.
    """
    if not gaps:
        return False, 1, 0.0
    n = len(gaps)
    in_order = sum(1 for g in gaps if 0 <= g <= MAX_STRIDE) / n
    backwards = sum(1 for g in gaps if g < 0) / n
    drift = sum(gaps)
    pos = [g for g in gaps if 0 < g <= MAX_STRIDE]
    counts: dict[int, int] = {}
    for g in pos:
        counts[g] = counts.get(g, 0) + 1
    stride = max(counts.items(), key=lambda kv: kv[1])[0] if counts else 1
    is_seq = in_order >= threshold and backwards <= (1.0 - threshold) and drift > 0
    return is_seq, stride, in_order


def distinct_deficit(indices: Sequence[int], c: int) -> float:
    """z-score of the distinct-count against the uniform null.

    Under uniform(-with-replacement) sampling of w items from [1, c]:
        E[D]   = c (1 - (1-1/c)^w)
        Var[D] = c (1-1/c)^w + c(c-1)(1-2/c)^w - c^2 (1-1/c)^{2w}
    Permutation epochs (the random pattern) give >= E[D] distinct items; a
    frequency-skewed stream revisits hot items and lands far BELOW.  Returns
    (E[D] - observed) / sd — large positive = skew.  This screen catches hot
    sets that are scattered in index space, which the spatial-gap K-S test is
    blind to (the skew is in access *frequency*, not position).
    """
    w = len(indices)
    if w < 4 or c < 4:
        return 0.0
    d_obs = len(set(indices))
    p1 = (1.0 - 1.0 / c) ** w
    p2 = (1.0 - 2.0 / c) ** w
    e_d = c * (1.0 - p1)
    var = c * p1 + c * (c - 1) * p2 - c * c * p1 * p1
    sd = math.sqrt(max(var, 1e-9))
    return (e_d - d_obs) / max(sd, 1.0)


def classify(records: Sequence[AccessRecord], total: int, cfg: CacheConfig) -> PatternResult:
    """Classify one observation window of accesses (§3.2).

    Order: sequential gap screen → distinct-count z-test (frequency skew) →
    K-S against the triangular permutation law (positional randomness).
    """
    if len(records) < 2:
        return PatternResult(Pattern.UNKNOWN)
    gaps = spatial_gaps(records)

    is_seq, stride, frac = detect_sequential(gaps, cfg.sequential_threshold)
    if is_seq:
        return PatternResult(Pattern.SEQUENTIAL, stride=stride, seq_fraction=frac)

    c = max(total, max(r.index for r in records) + 1)
    # Degenerate index space (single-item listing / one hot child): nothing to
    # infer at this level — defer to an ancestor/descendant stream.
    if c <= 2 or len({r.index for r in records}) <= 1:
        return PatternResult(Pattern.UNKNOWN)

    z = distinct_deficit([r.index for r in records], c)
    if z > cfg.distinct_z_threshold:
        return PatternResult(Pattern.SKEWED)
    abs_gaps = [abs(g) for g in gaps]
    accept, d, d_alpha = ks_test_random(abs_gaps, c, cfg.alpha)
    pattern = Pattern.RANDOM if accept else Pattern.SKEWED
    return PatternResult(pattern, d_stat=d, d_critical=d_alpha, seq_fraction=frac)


# ---------------------------------------------------------------------------
# Adaptive TTL (§3.3): temporal gaps ~ Normal(mu, sigma); TTL is the
# (1 - significance) quantile plus a base time guarding against small
# disturbances.  A stream idle longer than its TTL is presumed finished and
# its resident data is evicted wholesale.
# ---------------------------------------------------------------------------

def fit_adaptive_ttl(times: Sequence[float], cfg: CacheConfig) -> Optional[float]:
    """Fit TTL from the access timestamps of one observation window."""
    if len(times) < 3:
        return None
    gaps = [times[i] - times[i - 1] for i in range(1, len(times)) if times[i] >= times[i - 1]]
    if len(gaps) < 2:
        return None
    n = len(gaps)
    mu = sum(gaps) / n
    var = sum((g - mu) ** 2 for g in gaps) / max(1, n - 1)
    sigma = math.sqrt(var)
    z = normal_quantile(1.0 - cfg.ttl_significance)
    return mu + z * sigma + cfg.ttl_base

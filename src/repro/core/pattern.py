"""Online pattern recognition for one AccessStream (§3.2) + adaptive TTL (§3.3).

Decision procedure, purely from cache-side information:

1. sequential — the signed spatial gaps of consecutive accesses are
   overwhelmingly a small constant positive stride (unit stride for block
   scans and listing-order traversals).  Existing-practice detector.
2. otherwise run the K-S test of the |gap| samples against the triangular
   permutation law over [1, c]:  accept → random, reject → skewed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .ks import (ks_critical, ks_test_random, ks_test_random_matrix,
                 normal_quantile)
from .types import AccessRecord, CacheConfig, Pattern


@dataclass
class PatternResult:
    pattern: Pattern
    d_stat: float = 0.0
    d_critical: float = 0.0
    stride: int = 1  # detected stride when sequential
    seq_fraction: float = 0.0


def spatial_gaps(records: Sequence[AccessRecord]) -> list[int]:
    return [records[i].index - records[i - 1].index for i in range(1, len(records))]


MAX_STRIDE = 16


def detect_sequential(gaps: Sequence[int], threshold: float) -> tuple[bool, int, float]:
    """Return (is_sequential, stride, fraction-in-order).

    A stream is sequential when consecutive accesses move monotonically
    forward in small steps: at least ``threshold`` of the gaps lie in
    [0, MAX_STRIDE], backwards seeks are rare (<= 1 - threshold), and there is
    net forward drift.  Gap 0 counts as in-order — a coarse (directory) level
    sees long runs of 0 while a child is being traversed, punctuated by +1 on
    child switches.  Random streams fail on the backwards-seek test
    (~half their gaps are negative); skewed streams fail on both.
    """
    if not gaps:
        return False, 1, 0.0
    n = len(gaps)
    in_order = sum(1 for g in gaps if 0 <= g <= MAX_STRIDE) / n
    backwards = sum(1 for g in gaps if g < 0) / n
    drift = sum(gaps)
    pos = [g for g in gaps if 0 < g <= MAX_STRIDE]
    counts: dict[int, int] = {}
    for g in pos:
        counts[g] = counts.get(g, 0) + 1
    stride = max(counts.items(), key=lambda kv: kv[1])[0] if counts else 1
    is_seq = in_order >= threshold and backwards <= (1.0 - threshold) and drift > 0
    return is_seq, stride, in_order


def distinct_deficit(indices: Sequence[int], c: int) -> float:
    """z-score of the distinct-count against the uniform null.

    Under uniform(-with-replacement) sampling of w items from [1, c]:
        E[D]   = c (1 - (1-1/c)^w)
        Var[D] = c (1-1/c)^w + c(c-1)(1-2/c)^w - c^2 (1-1/c)^{2w}
    Permutation epochs (the random pattern) give >= E[D] distinct items; a
    frequency-skewed stream revisits hot items and lands far BELOW.  Returns
    (E[D] - observed) / sd — large positive = skew.  This screen catches hot
    sets that are scattered in index space, which the spatial-gap K-S test is
    blind to (the skew is in access *frequency*, not position).
    """
    w = len(indices)
    if w < 4 or c < 4:
        return 0.0
    d_obs = len(set(indices))
    p1 = (1.0 - 1.0 / c) ** w
    p2 = (1.0 - 2.0 / c) ** w
    e_d = c * (1.0 - p1)
    var = c * p1 + c * (c - 1) * p2 - c * c * p1 * p1
    sd = math.sqrt(max(var, 1e-9))
    return (e_d - d_obs) / max(sd, 1.0)


def classify(records: Sequence[AccessRecord], total: int, cfg: CacheConfig) -> PatternResult:
    """Classify one observation window of accesses (§3.2).

    Order: sequential gap screen → distinct-count z-test (frequency skew) →
    K-S against the triangular permutation law (positional randomness).
    """
    if len(records) < 2:
        return PatternResult(Pattern.UNKNOWN)
    gaps = spatial_gaps(records)

    is_seq, stride, frac = detect_sequential(gaps, cfg.sequential_threshold)
    if is_seq:
        return PatternResult(Pattern.SEQUENTIAL, stride=stride, seq_fraction=frac)

    c = max(total, max(r.index for r in records) + 1)
    # Degenerate index space (single-item listing / one hot child): nothing to
    # infer at this level — defer to an ancestor/descendant stream.
    if c <= 2 or len({r.index for r in records}) <= 1:
        return PatternResult(Pattern.UNKNOWN)

    z = distinct_deficit([r.index for r in records], c)
    if z > cfg.distinct_z_threshold:
        return PatternResult(Pattern.SKEWED)
    abs_gaps = [abs(g) for g in gaps]
    accept, d, d_alpha = ks_test_random(abs_gaps, c, cfg.alpha)
    pattern = Pattern.RANDOM if accept else Pattern.SKEWED
    return PatternResult(pattern, d_stat=d, d_critical=d_alpha, seq_fraction=frac)


# ---------------------------------------------------------------------------
# Vectorized classification (§4 overhead optimization): all windows due for
# (re)analysis are classified in one matrix pass.  The scalar classify()
# above stays as the cross-checked reference; per-row results are designed to
# be independent of batching (integer counts, elementwise ops, masked maxes —
# no cross-column float accumulation), so classify_batch([w]) == the result
# of w inside any larger batch.
# ---------------------------------------------------------------------------

# One analysis window: (chronological item indices, listing size c).
Window = Tuple[np.ndarray, int]


def _mode_stride(gaps: np.ndarray) -> int:
    """First-occurrence-wins mode of the in-range positive gaps (matches the
    dict-insertion-order tie-break of detect_sequential)."""
    pos = gaps[(gaps > 0) & (gaps <= MAX_STRIDE)]
    if pos.size == 0:
        return 1
    vals, counts = np.unique(pos, return_counts=True)
    best = counts.max()
    cands = vals[counts == best]
    if cands.size == 1:
        return int(cands[0])
    first_occ = [int(np.argmax(pos == v)) for v in cands]
    return int(cands[int(np.argmin(first_occ))])


def _classify_one(a: np.ndarray, total: int,
                  cfg: CacheConfig) -> PatternResult:
    """Single-window fast path of :func:`classify_batch`.

    Same decision procedure and the same float expressions (in the same
    evaluation order) as the matrix path below, on 1-D arrays — a window
    classifies identically whether it rides alone or in a batch.
    """
    n = int(a.size)
    if n < 2:
        return PatternResult(Pattern.UNKNOWN)
    gaps = np.diff(a)
    m = n - 1
    in_cnt = int(np.count_nonzero((gaps >= 0) & (gaps <= MAX_STRIDE)))
    back_cnt = int(np.count_nonzero(gaps < 0))
    drift = int(gaps.sum())
    frac = in_cnt / m
    thr = cfg.sequential_threshold
    if frac >= thr and back_cnt / m <= 1.0 - thr and drift > 0:
        return PatternResult(Pattern.SEQUENTIAL, stride=_mode_stride(gaps),
                             seq_fraction=float(frac))
    srt_idx = np.sort(a)
    c = max(int(total), int(srt_idx[-1]) + 1)
    distinct = 1 + int(np.count_nonzero(srt_idx[1:] != srt_idx[:-1]))
    if c <= 2 or distinct <= 1:
        return PatternResult(Pattern.UNKNOWN)
    if n >= 4 and c >= 4:
        cf = float(c)
        wf = float(n)
        p1 = (1.0 - 1.0 / cf) ** wf
        p2 = (1.0 - 2.0 / cf) ** wf
        e_d = cf * (1.0 - p1)
        var = cf * p1 + cf * (cf - 1.0) * p2 - cf * cf * p1 * p1
        sd = math.sqrt(max(var, 1e-9))
        z = (e_d - distinct) / max(sd, 1.0)
        if z > cfg.distinct_z_threshold:
            return PatternResult(Pattern.SKEWED)
    cf = float(c)
    # kf = floor(min(|gap|, c-1)) — exact on int64 without the float round
    # trip (values < 2^53); identical to the matrix path's floor/minimum
    kf = np.minimum(np.sort(np.abs(gaps)), c - 1)
    f = 2.0 * kf / (cf - 1.0) - kf * (kf + 1.0) / (cf * (cf - 1.0))
    f[kf < 1] = 0.0
    pos, pos_prev = _ecdf_positions(m)
    dev = np.maximum(pos - f, f - pos_prev)
    d = float(dev.max())
    d_alpha = ks_critical(m, cfg.alpha)
    pat = Pattern.RANDOM if d < d_alpha else Pattern.SKEWED
    return PatternResult(pat, d_stat=d, d_critical=d_alpha,
                         seq_fraction=float(frac))


_ECDF_CACHE: dict = {}


def _ecdf_positions(m: int) -> Tuple[np.ndarray, np.ndarray]:
    """(i/m, (i-1)/m) for i=1..m — cached; windows recur at the same size."""
    got = _ECDF_CACHE.get(m)
    if got is None:
        pos = np.arange(1, m + 1, dtype=np.float64)
        got = (pos / m, (pos - 1.0) / m)
        if len(_ECDF_CACHE) < 1024:
            _ECDF_CACHE[m] = got
    return got


def classify_batch(windows: Sequence[Window],
                   cfg: CacheConfig) -> List[PatternResult]:
    """Classify many observation windows in one vectorized pass.

    Each window is (indices, total): the chronological item indices of one
    AccessStream's observation window plus its listing size.  Implements the
    same decision procedure as :func:`classify` — sequential screen →
    distinct-deficit z-test → K-S against the triangular law — with every
    stage computed over the padded (R, W) matrix at once.
    """
    R = len(windows)
    if R == 0:
        return []
    if R == 1:
        a, total = windows[0]
        return [_classify_one(np.asarray(a, dtype=np.int64), int(total), cfg)]
    lens = np.fromiter((len(w[0]) for w in windows), np.int64, R)
    totals = np.fromiter((w[1] for w in windows), np.int64, R)
    W = max(int(lens.max()), 2)
    idx = np.zeros((R, W), np.int64)
    for r, (a, _) in enumerate(windows):
        idx[r, : len(a)] = a

    cols = np.arange(W, dtype=np.int64)[None, :]
    imask = cols < lens[:, None]
    m = np.maximum(lens - 1, 0)                    # gap count per row
    gaps = idx[:, 1:] - idx[:, :-1]
    gmask = cols[:, : W - 1] < m[:, None]

    # -- sequential screen (exact integer counts) ---------------------------
    in_cnt = ((gaps >= 0) & (gaps <= MAX_STRIDE) & gmask).sum(axis=1)
    back_cnt = ((gaps < 0) & gmask).sum(axis=1)
    drift = np.where(gmask, gaps, 0).sum(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        frac = np.where(m > 0, in_cnt / np.maximum(m, 1), 0.0)
        backfrac = np.where(m > 0, back_cnt / np.maximum(m, 1), 0.0)
    thr = cfg.sequential_threshold
    is_seq = (m > 0) & (frac >= thr) & (backfrac <= 1.0 - thr) & (drift > 0)

    # -- index-space geometry ----------------------------------------------
    row_max = np.where(imask, idx, np.iinfo(np.int64).min).max(axis=1)
    c = np.maximum(totals, row_max + 1)
    srt = np.sort(np.where(imask, idx, np.iinfo(np.int64).max), axis=1)
    changed = (srt[:, 1:] != srt[:, :-1]) & gmask
    distinct = np.where(lens > 0, changed.sum(axis=1) + 1, 0)

    # -- distinct-count z (frequency skew), same formula as distinct_deficit
    w_f = lens.astype(np.float64)
    c_f = c.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        p1 = (1.0 - 1.0 / c_f) ** w_f
        p2 = (1.0 - 2.0 / c_f) ** w_f
        e_d = c_f * (1.0 - p1)
        var = c_f * p1 + c_f * (c_f - 1.0) * p2 - c_f * c_f * p1 * p1
        sd = np.sqrt(np.maximum(var, 1e-9))
        z = (e_d - distinct) / np.maximum(sd, 1.0)
    z = np.where((lens >= 4) & (c >= 4), z, 0.0)

    # -- K-S against the triangular permutation law ------------------------
    abs_gaps = np.where(gmask, np.abs(gaps), np.iinfo(np.int64).max
                        ).astype(np.float64)
    accept, d, d_alpha = ks_test_random_matrix(abs_gaps, m, c, cfg.alpha)

    out: List[PatternResult] = []
    for r in range(R):
        if lens[r] < 2:
            out.append(PatternResult(Pattern.UNKNOWN))
        elif is_seq[r]:
            stride = _mode_stride(gaps[r, : m[r]])
            out.append(PatternResult(Pattern.SEQUENTIAL, stride=stride,
                                     seq_fraction=float(frac[r])))
        elif c[r] <= 2 or distinct[r] <= 1:
            out.append(PatternResult(Pattern.UNKNOWN))
        elif z[r] > cfg.distinct_z_threshold:
            out.append(PatternResult(Pattern.SKEWED))
        else:
            pat = Pattern.RANDOM if accept[r] else Pattern.SKEWED
            out.append(PatternResult(pat, d_stat=float(d[r]),
                                     d_critical=float(d_alpha[r]),
                                     seq_fraction=float(frac[r])))
    return out


def fit_adaptive_ttl_arr(times: np.ndarray,
                         cfg: CacheConfig) -> Optional[float]:
    """Array form of :func:`fit_adaptive_ttl` over a chronological window."""
    if times.size < 3:
        return None
    diffs = times[1:] - times[:-1]
    gaps = diffs[diffs >= 0.0]
    n = gaps.size
    if n < 2:
        return None
    mu = float(gaps.sum()) / n
    var = float(((gaps - mu) ** 2).sum()) / max(1, n - 1)
    sigma = math.sqrt(var)
    z = normal_quantile(1.0 - cfg.ttl_significance)
    return mu + z * sigma + cfg.ttl_base


def fit_adaptive_ttl_batch(windows: Sequence[np.ndarray],
                           cfg: CacheConfig) -> List[Optional[float]]:
    """Vectorized :func:`fit_adaptive_ttl_arr` over many chronological
    windows in one padded-matrix pass (§4 overhead lever).

    The classify pass hands every node that just (re)classified RANDOM to
    this in one call (``access_stream_tree.analyze_streams``) instead of
    fitting per node.  Per-row decision logic (>= 3 samples, >= 2
    non-negative gaps, the N(mu, sigma) quantile) matches the scalar form;
    masked/padded entries contribute exact zeros to the row reductions.
    """
    R = len(windows)
    if R == 0:
        return []
    if R == 1:
        return [fit_adaptive_ttl_arr(
            np.asarray(windows[0], dtype=np.float64), cfg)]
    lens = np.fromiter((len(w) for w in windows), np.int64, R)
    W = max(int(lens.max()), 2)
    mat = np.zeros((R, W), np.float64)
    for r, w in enumerate(windows):
        mat[r, : len(w)] = w
    diffs = mat[:, 1:] - mat[:, :-1]
    cols = np.arange(W - 1, dtype=np.int64)[None, :]
    valid = (cols < (lens - 1)[:, None]) & (diffs >= 0.0)
    n = valid.sum(axis=1)
    gaps = np.where(valid, diffs, 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        mu = gaps.sum(axis=1) / np.maximum(n, 1)
        dev = np.where(valid, diffs - mu[:, None], 0.0)
        var = (dev * dev).sum(axis=1) / np.maximum(n - 1, 1)
    sigma = np.sqrt(var)
    z = normal_quantile(1.0 - cfg.ttl_significance)
    ttl = mu + z * sigma + cfg.ttl_base
    ok = (lens >= 3) & (n >= 2)
    return [float(ttl[r]) if ok[r] else None for r in range(R)]


# ---------------------------------------------------------------------------
# Adaptive TTL (§3.3): temporal gaps ~ Normal(mu, sigma); TTL is the
# (1 - significance) quantile plus a base time guarding against small
# disturbances.  A stream idle longer than its TTL is presumed finished and
# its resident data is evicted wholesale.
# ---------------------------------------------------------------------------

def fit_adaptive_ttl(times: Sequence[float], cfg: CacheConfig) -> Optional[float]:
    """Fit TTL from the access timestamps of one observation window."""
    if len(times) < 3:
        return None
    gaps = [times[i] - times[i - 1] for i in range(1, len(times)) if times[i] >= times[i - 1]]
    if len(gaps) < 2:
        return None
    n = len(gaps)
    mu = sum(gaps) / n
    var = sum((g - mu) ** 2 for g in gaps) / max(1, n - 1)
    sigma = math.sqrt(var)
    z = normal_quantile(1.0 - cfg.ttl_significance)
    return mu + z * sigma + cfg.ttl_base

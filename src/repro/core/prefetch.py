"""Prefetching strategies (§3.3).

Pattern adaptivity:
  * sequential  → next-N items at the level where the sequential pattern was
                  detected (N = ``prefetch_depth``), in listing order;
  * random      → *statistical prefetching*: bulk-prefetch the dataset when
                  the expected hit ratio (allocatable quota / dataset size)
                  clears ``statistical_prefetch_threshold``;
  * skewed      → no prefetching.

Granularity adaptivity — *hierarchical prefetching*: horizontal candidates at
the detected level; vertical selection below it keeps only descendants that
were hot (frequency >= f_p) in previously-visited sibling subtrees (Fig. 7),
falling back to "everything" when siblings were read in full.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from .access_stream_tree import AccessStream
from .meta import StoreMeta
from .types import CacheConfig, PathT, Pattern, block_key

# A prefetch candidate is (block_path, size).
Candidate = Tuple[PathT, int]


def _sibling_child_profile(node: AccessStream, f_p: float) -> Optional[set]:
    """Relative-child keys hot across the *visited* children of ``node``.

    f(k) = (#visited children whose subtree touched k) / (#visited children).
    Returns None when the profile says "everything" (all visited siblings were
    read in full, or nothing informative yet).
    """
    visited = [c for c in node.children.values() if c.child_hits]
    if not visited:
        return None
    counts: dict = {}
    for v in visited:
        for k in v.child_hits:
            counts[k] = counts.get(k, 0) + 1
    n = len(visited)
    hot = {k for k, x in counts.items() if x / n >= f_p}
    if not hot:
        return None
    # If siblings were read ~in full, selection buys nothing — prefetch all.
    avg_children = sum(len(v.child_hits) for v in visited) / n
    if visited[0].total and avg_children >= 0.9 * visited[0].total:
        return None
    return hot


def _expand_candidate(meta: StoreMeta, path: PathT, node: Optional[AccessStream],
                      hot_filter: Optional[set], cfg: CacheConfig,
                      budget: int, depth: int = 0) -> List[Candidate]:
    """Vertically expand one horizontal candidate into block keys."""
    if budget <= 0 or depth > 4:
        return []
    out: List[Candidate] = []
    if meta.is_file(path):
        size = meta.file_size(path)
        nblocks = max(1, -(-size // cfg.block_size))
        block_filter = hot_filter  # hot blocks of sibling files, if any
        for b in range(nblocks):
            bkey = f"#{b}"
            if block_filter is not None and bkey not in block_filter:
                continue
            bsize = min(cfg.block_size, size - b * cfg.block_size)
            out.append((block_key(path, b), bsize))
            budget -= bsize
            if budget <= 0:
                break
        return out
    children = meta.listing(path)
    for name in children:
        if hot_filter is not None and name not in hot_filter:
            continue
        # The next level's hot filter is the profile of the *visited siblings*
        # at this level (which relative grand-children they touched).
        child_node = node.children.get(name) if node is not None else None
        got = _expand_candidate(meta, path + (name,), child_node,
                                _grandchild_profile(node, cfg.f_p),
                                cfg, budget, depth + 1)
        out.extend(got)
        budget -= sum(s for _, s in got)
        if budget <= 0:
            break
    return out


def _grandchild_profile(node: Optional[AccessStream], f_p: float) -> Optional[set]:
    if node is None:
        return None
    return _sibling_child_profile(node, f_p)


def sequential_candidates(meta: StoreMeta, node: AccessStream,
                          cfg: CacheConfig, budget: int,
                          depth: int = 0) -> List[Candidate]:
    """Next-N prefetch at ``node``'s level after its latest access (§3.3).

    ``node`` is the AccessStream where the sequential pattern was detected;
    its last record names the child just accessed.  Candidates are the next
    N siblings (stride-aware), each vertically narrowed by the hot profile of
    previously visited siblings (hierarchical prefetching).  ``depth``
    overrides the base N (the engine grows it while the stream keeps
    consuming readahead — footnote-7 policy extension).
    """
    if node.count == 0:
        return []
    depth = depth or cfg.prefetch_depth
    last_index = node.last_index
    stride = max(1, node.pattern.stride)
    listing = meta.listing(node.path)
    if not listing:
        return []
    hot = _sibling_child_profile(node, cfg.f_p)
    out: List[Candidate] = []
    for step in range(1, depth + 1):
        idx = last_index + step * stride
        if idx >= len(listing):
            break
        name = listing[idx]
        child_node = node.children.get(name)
        got = _expand_candidate(meta, node.path + (name,), child_node, hot,
                                cfg, budget)
        out.extend(got)
        budget -= sum(s for _, s in got)
        if budget <= 0:
            break
    return out


def block_sequential_candidates(meta: StoreMeta, file_node: AccessStream,
                                cfg: CacheConfig, budget: int,
                                depth: int = 0) -> List[Candidate]:
    """Next-N blocks inside one file (the classic readahead case)."""
    if file_node.count == 0:
        return []
    depth = depth or cfg.prefetch_depth
    last_index = file_node.last_index
    stride = max(1, file_node.pattern.stride)
    size = meta.file_size(file_node.path)
    nblocks = max(1, -(-size // cfg.block_size))
    out: List[Candidate] = []
    for step in range(1, depth + 1):
        b = last_index + step * stride
        if b >= nblocks:
            break
        bsize = min(cfg.block_size, size - b * cfg.block_size)
        out.append((block_key(file_node.path, b), bsize))
        budget -= bsize
        if budget <= 0:
            break
    return out


def statistical_candidates(meta: StoreMeta, root_path: PathT, quota: int,
                           dataset_bytes: int, cfg: CacheConfig,
                           resident) -> List[Candidate]:
    """Whole-dataset prefetch for random streams (§3.3).

    Fires when expected hit ratio = quota / dataset_bytes >= threshold;
    fills at most the quota, skipping already-resident blocks.
    """
    if dataset_bytes <= 0:
        return []
    expected_hit = min(1.0, quota / dataset_bytes)
    if expected_hit < cfg.statistical_prefetch_threshold:
        return []
    out: List[Candidate] = []
    budget = quota
    for bpath, bsize in meta.iter_block_keys(root_path):
        if budget - bsize < 0:
            break
        if resident(bpath):
            budget -= bsize  # counts against quota but no fetch needed
            continue
        out.append((bpath, bsize))
        budget -= bsize
    return out

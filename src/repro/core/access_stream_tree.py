"""AccessStreamTree (§3.1): hierarchical organization of recent accesses.

Each node is an *AccessStream*: the set of accesses sharing the node's path
prefix.  A node records, in a bounded observation window, which of its
children each passing access descended into (``AccessRecord.index`` = the
child's listing position, ``total`` = the listing size c).  Once a node has
observed ``window`` accesses it becomes *non-trivial* and pattern analysis
(§3.2) runs at that level; it re-runs every ``reanalyze_every`` accesses so a
stream that changes behaviour (e.g. warm-up scan then random epochs) is
re-classified promptly.

Overhead controls (§4):
  * layer compression — callers collapse single-child chain levels before
    calling :meth:`observe` (see ``igtcache.compress_levels``); interior
    levels with a one-entry listing store no records;
  * child pruning — a non-trivial node keeps at most ``window`` child nodes,
    discarding the least-recently-touched;
  * node cap — a global LRU bound (default 10 000) on tree nodes; childless
    nodes are detached first.

Per-access update cost is O(depth + log W); the tree never exceeds
``node_cap`` nodes (property-tested).
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from .pattern import PatternResult, classify, fit_adaptive_ttl
from .types import AccessRecord, CacheConfig, PathT, Pattern


class AccessStream:
    """One node of the AccessStreamTree."""

    __slots__ = (
        "key", "path", "parent", "children", "records", "times", "total",
        "accesses", "pattern", "last_analyzed_at", "last_access_time",
        "ttl", "child_hits", "distinct_children", "depth",
    )

    def __init__(self, key: str, path: PathT, parent: Optional["AccessStream"],
                 window: int) -> None:
        self.key = key
        self.path = path
        self.parent = parent
        self.children: "OrderedDict[str, AccessStream]" = OrderedDict()
        # Observation window of (index, total, child_key) + timestamps.
        self.records: Deque[AccessRecord] = deque(maxlen=window)
        self.times: Deque[float] = deque(maxlen=window)
        self.total = 0              # listing size c at this level
        self.accesses = 0
        self.pattern = PatternResult(Pattern.UNKNOWN)
        self.last_analyzed_at = 0
        self.last_access_time = 0.0
        self.ttl: Optional[float] = None
        # child_key -> number of window accesses that touched it (for the
        # vertical/hot-child statistics of hierarchical prefetching, §3.3).
        self.child_hits: Dict[str, int] = {}
        self.distinct_children = 0
        self.depth = len(path)

    # -- classification ------------------------------------------------------
    def non_trivial(self, cfg: CacheConfig) -> bool:
        return self.accesses >= cfg.window

    def record(self, rec: AccessRecord) -> None:
        if len(self.records) == self.records.maxlen:
            old = self.records[0]
            # keep child_hits consistent with the sliding window
            h = self.child_hits.get(old.child_key)
            if h is not None:
                if h <= 1:
                    del self.child_hits[old.child_key]
                else:
                    self.child_hits[old.child_key] = h - 1
        self.records.append(rec)
        self.times.append(rec.time)
        self.child_hits[rec.child_key] = self.child_hits.get(rec.child_key, 0) + 1
        self.accesses += 1
        self.last_access_time = rec.time

    def analyze(self, cfg: CacheConfig) -> PatternResult:
        self.pattern = classify(list(self.records), self.total, cfg)
        self.last_analyzed_at = self.accesses
        if self.pattern.pattern is Pattern.RANDOM:
            self.ttl = fit_adaptive_ttl(list(self.times), cfg)
        return self.pattern

    def maybe_analyze(self, cfg: CacheConfig) -> Optional[PatternResult]:
        if not self.non_trivial(cfg):
            return None
        if (self.pattern.pattern is Pattern.UNKNOWN
                or self.accesses - self.last_analyzed_at >= cfg.reanalyze_every):
            return self.analyze(cfg)
        return None

    def hot_children(self, f_p: float) -> List[str]:
        """Children whose in-window access frequency f = x/n >= f_p (§3.3)."""
        n = len(self.records)
        if n == 0:
            return []
        return [k for k, x in self.child_hits.items() if x / n >= f_p]

    def __repr__(self) -> str:  # pragma: no cover
        return (f"AccessStream({'/'.join(self.path) or '<root>'}, "
                f"acc={self.accesses}, pat={self.pattern.pattern.value})")


class AccessStreamTree:
    """The tree + global node accounting (§3.1, §4)."""

    def __init__(self, cfg: Optional[CacheConfig] = None) -> None:
        self.cfg = cfg or CacheConfig()
        self.root = AccessStream("", (), None, self.cfg.window)
        # LRU over all non-root nodes for the hard node cap.
        self._lru: "OrderedDict[PathT, AccessStream]" = OrderedDict()

    # -- observation ---------------------------------------------------------
    def observe(self, levels: Iterable[Tuple[str, int, int]], time: float,
                size: int = 0) -> List[AccessStream]:
        """Insert one leaf access.

        ``levels`` is the root-to-leaf decomposition of the access:
        ``(child_key, child_index, level_total)`` per level — e.g. for
        ``ImageNet/train/n014/4716.JPEG`` block 0:
        ``[("ImageNet", 3, 10), ("train", 0, 1), ("n014", 17, 1000),
        ("4716.JPEG", 561, 1300), ("#0", 0, 1)]``.

        Layer compression (§4), generalized: a level with a single-entry
        listing (total <= 1) carries no pattern information, so it is not
        recorded; nodes are only materialized down to the deepest level that
        still has informative structure below it.  A 1-block file in a flat
        directory therefore costs ZERO nodes beyond its parent directory —
        the directory node's observation window carries the file-level
        pattern.

        Returns the list of nodes (root-side first) that recorded the access.
        """
        levels = list(levels)
        # deepest level with an informative (>1 entry) listing
        last_informative = -1
        for d, (_, _, total) in enumerate(levels):
            if total > 1:
                last_informative = d
        node = self.root
        touched: List[AccessStream] = []
        for d, (child_key, index, total) in enumerate(levels):
            if total > 1:
                node.total = max(node.total, total)
                node.record(AccessRecord(index=index, total=total, time=time,
                                         child_key=child_key, size=size))
                node.maybe_analyze(self.cfg)
                touched.append(node)
            else:
                node.last_access_time = time
            if d >= last_informative:
                break  # nothing informative below — stop materializing
            child = node.children.get(child_key)
            if child is None:
                child = AccessStream(child_key, node.path + (child_key,), node,
                                     self.cfg.window)
                node.children[child_key] = child
                self._lru[child.path] = child
                self._prune_children(node)
                self._enforce_node_cap()
            else:
                node.children.move_to_end(child_key)
                self._lru.move_to_end(child.path)
            node = child
        node.last_access_time = time
        return touched

    # -- overhead control ----------------------------------------------------
    def _prune_children(self, node: AccessStream) -> None:
        """Child pruning (§4): bound children of a non-trivial node."""
        limit = self.cfg.window
        while len(node.children) > limit:
            old_key, old_child = node.children.popitem(last=False)
            self._detach_subtree(old_child)

    def _detach_subtree(self, node: AccessStream) -> None:
        self._lru.pop(node.path, None)
        for child in node.children.values():
            self._detach_subtree(child)
        node.children.clear()
        node.parent = None

    def _enforce_node_cap(self) -> None:
        while len(self._lru) > self.cfg.node_cap:
            victim = None
            for path, node in self._lru.items():
                if not node.children:  # only detach leaves of the tree
                    victim = node
                    break
            if victim is None:
                path, victim = next(iter(self._lru.items()))
            self._lru.pop(victim.path, None)
            if victim.parent is not None:
                victim.parent.children.pop(victim.key, None)
                victim.parent = None

    # -- queries --------------------------------------------------------------
    def node_count(self) -> int:
        return len(self._lru)

    def find(self, path: PathT) -> Optional[AccessStream]:
        node = self.root
        for comp in path:
            node = node.children.get(comp)
            if node is None:
                return None
        return node

    def iter_nodes(self):
        yield from self._lru.values()

    def shallowest_non_trivial(self, path: PathT) -> Optional[AccessStream]:
        """First non-trivial node on the root→path walk (the CMU anchor)."""
        node = self.root
        for comp in path:
            child = node.children.get(comp)
            if child is None:
                break
            if child.non_trivial(self.cfg):
                return child
            node = child
        return None

    def deepest_informative(self, path: PathT) -> Optional[AccessStream]:
        """Deepest non-trivial node with a classified pattern along the path.

        This is the level whose pattern governs policy for accesses under it
        (e.g. block level inside a large file, file level inside a dataset
        directory) — 'depending on where a non-trivial data access pattern
        exists' (§3.3).
        """
        node = self.root
        best: Optional[AccessStream] = None
        for comp in path:
            child = node.children.get(comp)
            if child is None:
                break
            if (child.non_trivial(self.cfg)
                    and child.pattern.pattern is not Pattern.UNKNOWN):
                best = child
            node = child
        return best

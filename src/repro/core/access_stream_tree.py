"""AccessStreamTree (§3.1): hierarchical organization of recent accesses.

Each node is an *AccessStream*: the set of accesses sharing the node's path
prefix.  A node records, in a bounded observation window, which of its
children each passing access descended into (``index`` = the child's listing
position, ``total`` = the listing size c).  Once a node has observed
``window`` accesses it becomes *non-trivial* and pattern analysis (§3.2) runs
at that level; it re-runs every ``reanalyze_every`` accesses so a stream that
changes behaviour (e.g. warm-up scan then random epochs) is re-classified
promptly.

Overhead controls (§4):
  * layer compression — interior levels with a one-entry listing store no
    records; nodes materialize only down to the deepest informative level;
  * child pruning — a non-trivial node keeps at most ``window`` child nodes,
    discarding the least-recently-touched;
  * node cap — a global LRU bound (default 10 000) on tree nodes; childless
    nodes are detached first, found in O(1) via a dedicated leaf LRU;
  * observation windows are NumPy ring buffers (no per-access allocation),
    and analysis is vectorized (``pattern.classify_batch``) over every due
    window in one matrix pass;
  * repeated walks down an unchanged path are replayed from an
    ``ObservedChain`` (built once per file by the engine) without any
    dict-walk of the tree — the batched read path of §4.

Per-access update cost is O(depth); the tree never exceeds ``node_cap``
nodes (property-tested).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .pattern import (PatternResult, classify_batch, fit_adaptive_ttl_arr,
                      fit_adaptive_ttl_batch)
from .types import AccessRecord, CacheConfig, PathT, Pattern

_INT64 = np.int64
_F64 = np.float64


def ring_chrono(buf: list, pos: int, count: int, cap: int) -> list:
    """Chronological view of a ring buffer backed by a plain list.

    Invariant shared by every ring in this codebase (AccessStream windows,
    CacheManageUnit.flat ring): the buffer only wraps once full, so
    ``count < cap`` implies the data is the contiguous prefix ``buf[:count]``
    and ``count == cap`` implies the oldest entry sits at ``pos``.
    """
    if count < cap:
        return buf[:count]
    if pos == 0:
        return buf
    return buf[pos:] + buf[:pos]


class AccessStream:
    """One node of the AccessStreamTree.

    The observation window is a fixed-size ring: ``_idx``/``_tim`` hold the
    last ``window`` (item-index, timestamp) pairs in arrival order starting
    at ``_pos`` (once wrapped); ``_keys`` carries the child keys for the
    sliding ``child_hits`` profile used by hierarchical prefetching.
    """

    __slots__ = (
        "key", "path", "parent", "children", "total",
        "accesses", "pattern", "last_analyzed_at", "last_access_time",
        "ttl", "child_hits", "distinct_children", "depth", "detached",
        "_win", "_cap", "_idx", "_tim", "_keys", "_pos", "count",
        "last_index",
    )

    def __init__(self, key: str, path: PathT, parent: Optional["AccessStream"],
                 window: int) -> None:
        self.key = key
        self.path = path
        self.parent = parent
        self.children: "OrderedDict[str, AccessStream]" = OrderedDict()
        self.total = 0              # listing size c at this level
        self.accesses = 0
        self.pattern = PatternResult(Pattern.UNKNOWN)
        self.last_analyzed_at = 0
        self.last_access_time = 0.0
        self.ttl: Optional[float] = None
        # child_key -> number of window accesses that touched it (for the
        # vertical/hot-child statistics of hierarchical prefetching, §3.3).
        self.child_hits: dict = {}
        self.distinct_children = 0
        self.depth = len(path)
        self.detached = False
        # Observation-window ring buffers.  Stored as plain Python lists —
        # a scalar store into a list is ~10× cheaper than into an ndarray,
        # and the window only becomes an ndarray at analysis time
        # (window_indices/window_times), amortized over reanalyze_every
        # accesses.  The ring starts small and doubles up to ``window``:
        # most tree nodes (leaf-side file nodes) see only a handful of
        # accesses before being pruned, so pre-allocating the full window
        # per node would waste both the allocation and the memory.
        self._win = window
        cap = 8 if window > 8 else window
        self._cap = cap
        self._idx: List[int] = [0] * cap
        self._tim: List[float] = [0.0] * cap
        self._keys: List[Optional[str]] = [None] * cap
        self._pos = 0               # next write slot
        self.count = 0              # live entries (<= window)
        self.last_index = -1

    # -- observation window --------------------------------------------------
    def record_raw(self, index: int, total: int, time: float,
                   child_key: str) -> None:
        """Append one access to the ring (the hot-path form of record())."""
        if total > self.total:
            self.total = total
        pos = self._pos
        ch = self.child_hits
        if self.count == self._cap:
            if self._cap < self._win:
                pos = self._grow()
            else:
                old = self._keys[pos]
                h = ch.get(old)
                if h is not None:
                    if h <= 1:
                        del ch[old]
                    else:
                        ch[old] = h - 1
                self.count -= 1
        self.count += 1
        self._idx[pos] = index
        self._tim[pos] = time
        self._keys[pos] = child_key
        self._pos = 0 if pos + 1 == self._cap else pos + 1
        ch[child_key] = ch.get(child_key, 0) + 1
        self.accesses += 1
        self.last_access_time = time
        self.last_index = index

    def _grow(self) -> int:
        """Double the ring capacity (called with the ring exactly full, so
        the buffer is already in chronological order with _pos == 0)."""
        ncap = self._cap * 2
        if ncap > self._win:
            ncap = self._win
        extra = ncap - self._cap
        self._idx.extend([0] * extra)
        self._tim.extend([0.0] * extra)
        self._keys.extend([None] * extra)
        self._pos = self._cap
        self._cap = ncap
        return self._pos

    def record(self, rec: AccessRecord) -> None:
        """Compatibility wrapper over :meth:`record_raw`."""
        self.record_raw(rec.index, rec.total, rec.time, rec.child_key)

    def ring_memory_bytes(self) -> int:
        """Approximate heap bytes held by this node's observation window."""
        import sys
        return (sys.getsizeof(self._idx) + sys.getsizeof(self._tim)
                + sys.getsizeof(self._keys) + 56 * self.count)

    def window_indices(self) -> np.ndarray:
        """Window item indices in chronological order (fresh ndarray)."""
        return np.array(ring_chrono(self._idx, self._pos, self.count,
                                    self._cap), dtype=_INT64)

    def window_times(self) -> np.ndarray:
        """Window timestamps in chronological order (fresh ndarray)."""
        return np.array(ring_chrono(self._tim, self._pos, self.count,
                                    self._cap), dtype=_F64)

    def window_records(self) -> List[AccessRecord]:
        """Materialize the window as AccessRecords (reference/debug path)."""
        idx, tim = self.window_indices(), self.window_times()
        keys = ring_chrono(self._keys, self._pos, self.count, self._cap)
        return [AccessRecord(index=int(i), total=self.total, time=float(t),
                             child_key=k or "")
                for i, t, k in zip(idx, tim, keys)]

    # -- classification ------------------------------------------------------
    def non_trivial(self, cfg: CacheConfig) -> bool:
        return self.accesses >= cfg.window

    def analysis_due(self, cfg: CacheConfig) -> bool:
        return (self.accesses >= cfg.window
                and (self.pattern.pattern is Pattern.UNKNOWN
                     or self.accesses - self.last_analyzed_at
                     >= cfg.reanalyze_every))

    _TTL_UNSET = object()      # sentinel: fit here (solo path) vs batched

    def apply_analysis(self, result: PatternResult, cfg: CacheConfig,
                       ttl=_TTL_UNSET) -> None:
        """Install a (re)classification result.  RANDOM streams get an
        adaptive TTL — fitted here on the solo path, or passed in by
        :func:`analyze_streams`, which fits every random node of the batch
        in one ``fit_adaptive_ttl_batch`` matrix pass (§4)."""
        self.pattern = result
        self.last_analyzed_at = self.accesses
        if result.pattern is Pattern.RANDOM:
            if ttl is self._TTL_UNSET:
                ttl = fit_adaptive_ttl_arr(self.window_times(), cfg)
            self.ttl = ttl

    def analyze(self, cfg: CacheConfig) -> PatternResult:
        res = classify_batch([(self.window_indices(), self.total)], cfg)[0]
        self.apply_analysis(res, cfg)
        return self.pattern

    def maybe_analyze(self, cfg: CacheConfig) -> Optional[PatternResult]:
        if self.analysis_due(cfg):
            return self.analyze(cfg)
        return None

    def hot_children(self, f_p: float) -> List[str]:
        """Children whose in-window access frequency f = x/n >= f_p (§3.3)."""
        n = self.count
        if n == 0:
            return []
        return [k for k, x in self.child_hits.items() if x / n >= f_p]

    def __repr__(self) -> str:  # pragma: no cover
        return (f"AccessStream({'/'.join(self.path) or '<root>'}, "
                f"acc={self.accesses}, pat={self.pattern.pattern.value})")


def analyze_streams(nodes: List[AccessStream], cfg: CacheConfig) -> None:
    """Vectorized (re)analysis of every due node in one matrix pass (§4):
    one ``classify_batch`` call for the labels, then one
    ``fit_adaptive_ttl_batch`` call fitting the adaptive TTL of every node
    that classified RANDOM (previously a per-node fit)."""
    if not nodes:
        return
    results = classify_batch([(n.window_indices(), n.total) for n in nodes],
                             cfg)
    rand_nodes = [n for n, res in zip(nodes, results)
                  if res.pattern is Pattern.RANDOM]
    ttls = iter(fit_adaptive_ttl_batch(
        [n.window_times() for n in rand_nodes], cfg)) if rand_nodes else None
    for n, res in zip(nodes, results):
        if res.pattern is Pattern.RANDOM:
            n.apply_analysis(res, cfg, ttl=next(ttls))
        else:
            n.apply_analysis(res, cfg)


class ObservedChain:
    """A replayable root→file walk for one file path (§4 batched read path).

    Built once by :meth:`AccessStreamTree.build_chain`; every later access to
    any block of the file replays it without touching the children dicts:
    record at the informative nodes, refresh the LRU positions, done.  The
    chain is invalidated (``valid()`` False) as soon as any involved node is
    detached by child pruning or the node cap.

    ``steps`` is one flattened entry per walked level:
    ``(node, index, total, child_key, mchildren, mkey)`` — ``index >= 0``
    means the level is informative and ``node`` records (index, total,
    child_key); ``index < 0`` means trivial (touch only).  ``mchildren``
    is the parent's children OrderedDict to refresh (``mkey`` moved to MRU),
    or None at the level the walk stops on.
    """

    __slots__ = ("steps", "cnodes", "leaf_node", "leaf_total", "final_node",
                 "tail_path", "check_nodes")

    def __init__(self) -> None:
        self.steps: List[Tuple] = []
        self.cnodes: List[AccessStream] = []        # child chain, root-side first
        self.leaf_node: Optional[AccessStream] = None  # records block level
        self.leaf_total = 1
        self.final_node: Optional[AccessStream] = None
        self.tail_path: Optional[PathT] = None      # deepest child's path
        self.check_nodes: List[AccessStream] = []

    def valid(self) -> bool:
        for n in self.check_nodes:
            if n.detached:
                return False
        return True


class AccessStreamTree:
    """The tree + global node accounting (§3.1, §4)."""

    def __init__(self, cfg: Optional[CacheConfig] = None) -> None:
        self.cfg = cfg or CacheConfig()
        self.root = AccessStream("", (), None, self.cfg.window)
        # Registry of all non-root nodes (plain dict — insertion order only)
        # plus an LRU of *childless* nodes so cap enforcement finds its
        # least-recently-touched leaf victim in O(1) instead of scanning the
        # whole registry (the seed's accidental quadratic).  Interior nodes
        # need no recency order: they are never victims while they have
        # children, so only the leaf LRU is refreshed per access.
        self._lru: Dict[PathT, AccessStream] = {}
        self._leaf_lru: "OrderedDict[PathT, AccessStream]" = OrderedDict()

    # -- observation ---------------------------------------------------------
    def observe(self, levels: Iterable[Tuple[str, int, int]], time: float,
                size: int = 0) -> List[AccessStream]:
        """Insert one leaf access (reference per-access path).

        ``levels`` is the root-to-leaf decomposition of the access:
        ``(child_key, child_index, level_total)`` per level — e.g. for
        ``ImageNet/train/n014/4716.JPEG`` block 0:
        ``[("ImageNet", 3, 10), ("train", 0, 1), ("n014", 17, 1000),
        ("4716.JPEG", 561, 1300), ("#0", 0, 1)]``.

        Layer compression (§4), generalized: a level with a single-entry
        listing (total <= 1) carries no pattern information, so it is not
        recorded; nodes are only materialized down to the deepest level that
        still has informative structure below it.  A 1-block file in a flat
        directory therefore costs ZERO nodes beyond its parent directory —
        the directory node's observation window carries the file-level
        pattern.

        Returns the list of nodes (root-side first) that recorded the access.
        """
        levels = list(levels)
        # deepest level with an informative (>1 entry) listing
        last_informative = -1
        for d, (_, _, total) in enumerate(levels):
            if total > 1:
                last_informative = d
        node = self.root
        touched: List[AccessStream] = []
        due: List[AccessStream] = []
        for d, (child_key, index, total) in enumerate(levels):
            if total > 1:
                node.record_raw(index, total, time, child_key)
                if node.analysis_due(self.cfg):
                    due.append(node)
                touched.append(node)
            else:
                node.last_access_time = time
            if d >= last_informative:
                break  # nothing informative below — stop materializing
            child = node.children.get(child_key)
            if child is None:
                child = self._create_child(node, child_key)
            else:
                node.children.move_to_end(child_key)
                if child.path in self._leaf_lru:
                    self._leaf_lru.move_to_end(child.path)
            node = child
        node.last_access_time = time
        analyze_streams(due, self.cfg)
        return touched

    def _create_child(self, node: AccessStream, child_key: str) -> AccessStream:
        child = AccessStream(child_key, node.path + (child_key,), node,
                             self.cfg.window)
        if not node.children and node.parent is not None:
            self._leaf_lru.pop(node.path, None)   # parent is a leaf no more
        node.children[child_key] = child
        self._lru[child.path] = child
        self._leaf_lru[child.path] = child
        self._prune_children(node)
        self._enforce_node_cap()
        return child

    # -- batched read path (§4) ----------------------------------------------
    def build_chain(self, dir_levels: Tuple[Tuple[str, int, int], ...],
                    nblocks: int) -> ObservedChain:
        """Walk (and materialize) the path once, returning a replayable chain.

        ``dir_levels`` is the (name, index, total) decomposition of the FILE
        path; the block level (total = ``nblocks``) is handled separately so
        one chain serves every block of the file.  The walk itself records
        nothing — the caller replays the chain for each observed block.
        """
        L = len(dir_levels)
        last_informative = -1
        for d, (_, _, total) in enumerate(dir_levels):
            if total > 1:
                last_informative = d
        if nblocks > 1:
            last_informative = L
        chain = ObservedChain()
        node = self.root
        for d in range(L + 1):
            if d == L:
                # block level: recorded at the deepest materialized node
                if nblocks > 1:
                    chain.leaf_node = node
                    chain.leaf_total = nblocks
                break
            child_key, index, total = dir_levels[d]
            if d >= last_informative:
                chain.steps.append((node, index if total > 1 else -1, total,
                                    child_key, None, None))
                break
            child = node.children.get(child_key)
            if child is None:
                child = self._create_child(node, child_key)
            chain.steps.append((node, index if total > 1 else -1, total,
                                child_key, node.children, child_key))
            chain.cnodes.append(child)
            node = child
        chain.final_node = node
        if chain.cnodes:
            chain.tail_path = chain.cnodes[-1].path
        # every non-root node the chain touches IS a chain child (rec/touch
        # nodes at depth d are the root or cnodes[d-1]), so validity reduces
        # to the child chain
        chain.check_nodes = chain.cnodes
        return chain

    def replay_chain(self, chain: ObservedChain, block: int, time: float,
                     due_out: List[AccessStream]) -> None:
        """One access through a valid chain: records + LRU refresh only.

        Mutation-for-mutation identical to :meth:`observe` on the same
        (existing) path; appends any node whose analysis is now due to
        ``due_out`` (the caller batch-classifies them via analyze_streams).
        """
        cfg = self.cfg
        window, reanalyze = cfg.window, cfg.reanalyze_every
        unknown = Pattern.UNKNOWN
        for node, index, total, child_key, mchildren, mkey in chain.steps:
            if index >= 0:
                node.record_raw(index, total, time, child_key)
                acc = node.accesses
                if acc >= window and (node.pattern.pattern is unknown
                                      or acc - node.last_analyzed_at
                                      >= reanalyze):
                    due_out.append(node)
            else:
                node.last_access_time = time
            if mchildren is not None:
                mchildren.move_to_end(mkey)
        leaf = chain.leaf_node
        if leaf is not None:
            leaf.record_raw(block, chain.leaf_total, time, f"#{block}")
            acc = leaf.accesses
            if acc >= window and (leaf.pattern.pattern is unknown
                                  or acc - leaf.last_analyzed_at >= reanalyze):
                due_out.append(leaf)
        tail = chain.tail_path
        if tail is not None:
            # only the deepest chain node can be childless (interior chain
            # nodes hold the next chain node as a child while the chain is
            # valid), so a single leaf-LRU refresh suffices
            leaf_lru = self._leaf_lru
            if tail in leaf_lru:
                leaf_lru.move_to_end(tail)
        chain.final_node.last_access_time = time

    # -- overhead control ----------------------------------------------------
    def _prune_children(self, node: AccessStream) -> None:
        """Child pruning (§4): bound children of a non-trivial node."""
        limit = self.cfg.window
        while len(node.children) > limit:
            old_key, old_child = node.children.popitem(last=False)
            self._detach_subtree(old_child)

    def _detach_subtree(self, node: AccessStream) -> None:
        self._lru.pop(node.path, None)
        self._leaf_lru.pop(node.path, None)
        node.detached = True
        for child in node.children.values():
            self._detach_subtree(child)
        node.children.clear()
        node.parent = None

    def _enforce_node_cap(self) -> None:
        while len(self._lru) > self.cfg.node_cap:
            if self._leaf_lru:
                _, victim = next(iter(self._leaf_lru.items()))
            else:  # degenerate: no childless node tracked — evict oldest
                _, victim = next(iter(self._lru.items()))
            self._lru.pop(victim.path, None)
            self._leaf_lru.pop(victim.path, None)
            victim.detached = True
            parent = victim.parent
            if parent is not None:
                parent.children.pop(victim.key, None)
                victim.parent = None
                if not parent.children and parent.parent is not None \
                        and parent.path in self._lru:
                    self._leaf_lru[parent.path] = parent

    # -- queries --------------------------------------------------------------
    def node_count(self) -> int:
        return len(self._lru)

    def find(self, path: PathT) -> Optional[AccessStream]:
        node = self.root
        for comp in path:
            node = node.children.get(comp)
            if node is None:
                return None
        return node

    def iter_nodes(self):
        yield from self._lru.values()

    def shallowest_non_trivial(self, path: PathT) -> Optional[AccessStream]:
        """First non-trivial node on the root→path walk (the CMU anchor)."""
        node = self.root
        for comp in path:
            child = node.children.get(comp)
            if child is None:
                break
            if child.non_trivial(self.cfg):
                return child
            node = child
        return None

    def deepest_informative(self, path: PathT) -> Optional[AccessStream]:
        """Deepest non-trivial node with a classified pattern along the path.

        This is the level whose pattern governs policy for accesses under it
        (e.g. block level inside a large file, file level inside a dataset
        directory) — 'depending on where a non-trivial data access pattern
        exists' (§3.3).
        """
        node = self.root
        best: Optional[AccessStream] = None
        for comp in path:
            child = node.children.get(comp)
            if child is None:
                break
            if (child.non_trivial(self.cfg)
                    and child.pattern.pattern is not Pattern.UNKNOWN):
                best = child
            node = child
        return best

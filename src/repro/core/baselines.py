"""Named policy bundles used across the evaluation (§5).

Each bundle is an ``EngineOptions`` preset; "the baseline" in EXPERIMENTS.md
always means ``juicefs`` (enhanced-stride block readahead + one shared LRU
pool + fixed 600 s TTL — the vanilla-JuiceFS behaviour the paper compares
against; Alluxio ships effectively the same policies, §5.1).
"""
from __future__ import annotations

from typing import Optional

from .igtcache import EngineOptions
from .types import CacheConfig

BUNDLES = {
    # the paper's system
    "igtcache": EngineOptions(name="igtcache"),
    # production frameworks (≈ JuiceFS defaults / Alluxio)
    "juicefs": EngineOptions(prefetch="enhanced_stride", eviction="lru",
                             allocation="shared", fixed_ttl=600.0,
                             name="juicefs"),
    # §5.2 prefetch micro-benchmarks (everything else like juicefs-shared)
    "prefetch_stride": EngineOptions(prefetch="stride", eviction="lru",
                                     allocation="shared", name="prefetch_stride"),
    "prefetch_enhanced": EngineOptions(prefetch="enhanced_stride", eviction="lru",
                                       allocation="shared",
                                       name="prefetch_enhanced"),
    "prefetch_sfp": EngineOptions(prefetch="sfp", eviction="lru",
                                  allocation="shared", name="prefetch_sfp"),
    "prefetch_none": EngineOptions(prefetch="none", eviction="lru",
                                   allocation="shared", name="prefetch_none"),
    "prefetch_igt": EngineOptions(prefetch="adaptive", eviction="lru",
                                  allocation="shared", name="prefetch_igt"),
    # §5.3 eviction micro-benchmarks (no prefetch; per-job static 50 % quota)
    "evict_lru": EngineOptions(prefetch="none", eviction="lru",
                               allocation="static", name="evict_lru"),
    "evict_fifo": EngineOptions(prefetch="none", eviction="fifo",
                                allocation="static", name="evict_fifo"),
    "evict_arc": EngineOptions(prefetch="none", eviction="arc",
                               allocation="static", name="evict_arc"),
    "evict_uniform": EngineOptions(prefetch="none", eviction="uniform",
                                   allocation="static", name="evict_uniform"),
    "evict_sieve": EngineOptions(prefetch="none", eviction="sieve",
                                 allocation="static", name="evict_sieve"),
    "evict_lfu": EngineOptions(prefetch="none", eviction="lfu",
                               allocation="static", name="evict_lfu"),
    "evict_igt": EngineOptions(prefetch="none", eviction="adaptive",
                               allocation="static", name="evict_igt"),
    # §5.4 allocation micro-benchmarks (no prefetch; adaptive eviction)
    "alloc_shared": EngineOptions(prefetch="none", eviction="lru",
                                  allocation="shared", name="alloc_shared"),
    "alloc_quiver": EngineOptions(prefetch="none", eviction="adaptive",
                                  allocation="quiver", name="alloc_quiver"),
    "alloc_fluid": EngineOptions(prefetch="none", eviction="adaptive",
                                 allocation="fluid", name="alloc_fluid"),
    "alloc_igt": EngineOptions(prefetch="none", eviction="adaptive",
                               allocation="adaptive", name="alloc_igt"),
}


def bundle(name: str) -> EngineOptions:
    return BUNDLES[name]


def bundle_engine(name: str, meta, capacity: int,
                  cfg: Optional[CacheConfig] = None, n_shards: int = 1):
    """Construct a bare kernel running the named bundle, sharded when asked.

    Baselines ride the same sharded facade as IGTCache proper — the
    comparison in the evaluation stays apples-to-apples at any shard count
    (the global cross-shard rebalancer only activates for the adaptive
    allocation, exactly as the shard-local one does).
    """
    from .sharded import make_engine
    return make_engine(meta, capacity, cfg=cfg, options=bundle(name),
                       n_shards=n_shards)


def bundle_client(name: str, store, capacity: int,
                  cfg: Optional[CacheConfig] = None, n_shards: int = 1,
                  **client_kw):
    """``open_cache`` with a named policy bundle: the one constructor path
    (sim, benchmarks, examples) for baseline CacheClients."""
    from .client import open_cache
    return open_cache(store, capacity, cfg=cfg, options=bundle(name),
                      n_shards=n_shards, **client_kw)

"""Cache space allocation (§3.3 "Cache Allocation Optimization" + §4).

The marginal benefit metric B quantifies the remote-transmission reduction per
unit time obtained by granting one more unit of cache to a stream:

  * sequential: B = 0                       (never re-read)
  * random:     B = 1 / (q * n)             (q = inter-access gap, n = blocks;
                                             each block re-read once per epoch
                                             of length q*n — multiple jobs on
                                             the same dataset shrink q)
  * skewed:     B = lambda * f_bufferhit/w  (ghost "BufferWindow" of the last
                                             w evicted blocks; hits there are
                                             the misses one more w-block grant
                                             would have saved)

The rebalancer runs in rounds (60 s): one ``rebalance_quantum`` (640 MB) moves
from the minimum-B donor to the maximum-B recipient with unmet demand; every
stream keeps ``min_share``.  Quiver- and Fluid-style allocators are provided
as §5.4 baselines.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, TYPE_CHECKING

from .types import CacheConfig, Pattern

if TYPE_CHECKING:  # pragma: no cover
    from .cache import CacheManageUnit


class BufferWindow:
    """Ghost cache of recently-evicted blocks (§3.3), LRU, max w entries.

    ``hits``/``probes`` are per-round counters reset by the owning pool's
    rebalance round; ``total_hits``/``total_probes`` accumulate for the
    pool's lifetime so an *outside* observer (the cross-shard
    GlobalRebalancer, whose round phase is independent of each shard's
    read-triggered local rounds) can measure hit frequency over its own
    interval via deltas instead of inheriting whatever reset phase the
    local round left behind.
    """

    def __init__(self, w: int) -> None:
        self.w = max(1, w)
        self._ghost: "OrderedDict[str, None]" = OrderedDict()
        self.hits = 0
        self.probes = 0
        self.total_hits = 0
        self.total_probes = 0
        # optional ghost-hit sink (core.sketch.DemandSketch.note): the
        # pool wires every CMU's window into its per-shard demand sketch
        # so the cross-shard round can size unmet working sets
        self.sink: Optional[Callable[[str], None]] = None

    def on_evict(self, key: str) -> None:
        self._ghost[key] = None
        self._ghost.move_to_end(key)
        while len(self._ghost) > self.w:
            self._ghost.popitem(last=False)

    def probe(self, key: str) -> bool:
        """Called on every cache miss; True = the miss was ghost-avoidable."""
        self.probes += 1
        self.total_probes += 1
        if key in self._ghost:
            self.hits += 1
            self.total_hits += 1
            del self._ghost[key]
            if self.sink is not None:
                self.sink(key)
            return True
        return False

    def hit_frequency(self) -> float:
        return self.hits / self.probes if self.probes else 0.0

    def reset_window(self) -> None:
        self.hits = 0
        self.probes = 0


@dataclass
class DemandEstimate:
    benefit: float          # B
    wants_more: bool        # has unmet demand at current quota
    can_shrink: bool        # above min share


def marginal_benefit(cmu: "CacheManageUnit", now: float, cfg: CacheConfig) -> DemandEstimate:
    """Compute B for one CacheManageUnit (pattern-dependent, §3.3)."""
    pat = cmu.effective_pattern()
    can_shrink = cmu.quota - cfg.min_share >= cfg.rebalance_quantum
    if pat is Pattern.SEQUENTIAL:
        return DemandEstimate(0.0, False, can_shrink)
    if pat is Pattern.RANDOM:
        q = cmu.mean_access_gap(now)
        # n = number of access units in the dataset (files for small-file
        # sets, blocks for big-file sets) — estimated from the observed mean
        # access size; one epoch re-touches each unit once, t = q * n.
        n_units = max(1, cmu.dataset_bytes // cmu.mean_access_size())
        if q is None or q <= 0:
            return DemandEstimate(0.0, cmu.quota < cmu.dataset_bytes, can_shrink)
        b = 1.0 / (q * n_units)
        return DemandEstimate(b, cmu.quota < cmu.dataset_bytes, can_shrink)
    if pat is Pattern.SKEWED:
        lam = cmu.arrival_rate(now)
        f = cmu.buffer_window.hit_frequency()
        b = lam * f / cmu.buffer_window.w
        return DemandEstimate(b, f > 0.0, can_shrink)
    # UNKNOWN: neutral small benefit proportional to recent activity.
    lam = cmu.arrival_rate(now)
    return DemandEstimate(1e-9 * lam, cmu.used >= 0.95 * cmu.quota, can_shrink)


@dataclass(frozen=True)
class PlacementHint:
    """Where one stream's blocks belong in a RAM/disk tier hierarchy
    (consumed by ``storage.tiers.TieredStore`` via ``note_pattern``)."""

    pattern: Pattern
    pin_ram: bool           # hot working set: keep RAM-resident (sticky)


def placement_hint(cmu: "CacheManageUnit", now: float,
                   cfg: CacheConfig) -> PlacementHint:
    """Tier placement verdict for one stream, from the same classifier
    state that drives allocation: SKEWED hot sets pin in RAM; a RANDOM
    set that *fits* its quota is worth pinning too (uniform residency);
    SEQUENTIAL/UNKNOWN data is never worth displacing RAM blocks —
    sequential extents are disk-eligible and stream from the spill tier.
    """
    pat = cmu.effective_pattern()
    if pat is Pattern.SKEWED:
        return PlacementHint(pat, True)
    if pat is Pattern.RANDOM:
        return PlacementHint(pat, cmu.dataset_bytes <= cmu.quota)
    return PlacementHint(pat, False)


class Rebalancer:
    """IGTCache's round-based quota shifting (§4)."""

    def __init__(self, cfg: CacheConfig) -> None:
        self.cfg = cfg
        self.last_round = 0.0

    def due(self, now: float) -> bool:
        return now - self.last_round >= self.cfg.rebalance_period

    # a taker must beat the donor by this factor (ping-pong damping)
    HYSTERESIS = 1.25

    def clears_hysteresis(self, donor_benefit: float,
                          taker_benefit: float) -> bool:
        """The taker must beat the donor by the damping factor."""
        return taker_benefit > max(donor_benefit * self.HYSTERESIS,
                                   donor_benefit + 1e-12)

    def pick_move(self, est: Dict["CacheManageUnit", DemandEstimate],
                  donors: List["CacheManageUnit"],
                  takers: List["CacheManageUnit"]) -> Optional[tuple]:
        """The paper's greedy rule for one quantum move: max-B taker with
        unmet demand ← min-B shrinkable donor, damped by hysteresis.
        Returns (donor, taker, bytes) or None when benefits have crossed.
        Shared by the per-pool round below and the cross-shard
        GlobalRebalancer (core.sharded)."""
        if not donors or not takers:
            return None
        donor = min(donors, key=lambda c: est[c].benefit)
        taker = max(takers, key=lambda c: est[c].benefit)
        if donor is taker or not self.clears_hysteresis(est[donor].benefit,
                                                        est[taker].benefit):
            return None
        amt = min(self.cfg.rebalance_quantum,
                  donor.quota - self.cfg.min_share)
        if amt <= 0:
            return None
        return donor, taker, amt

    def rebalance(self, cmus: List["CacheManageUnit"], now: float,
                  max_moves: Optional[int] = None) -> List[tuple]:
        """One round: shift quanta from min-B donors to max-B takers until
        benefits cross (with hysteresis) or the per-round move budget is hit.
        Returns the list of (donor, taker, bytes) moves."""
        self.last_round = now
        moves: List[tuple] = []
        if len(cmus) < 2:
            for c in cmus:
                c.buffer_window.reset_window()
            return moves
        if max_moves is None:
            max_moves = len(cmus)
        est = {c: marginal_benefit(c, now, self.cfg) for c in cmus}
        # Greedy max-B ← min-B quantum moves (the paper's rule), several per
        # round so convergence keeps pace with job lifetimes.
        for _ in range(max_moves):
            donors = [c for c in cmus if est[c].can_shrink]
            takers = [c for c in cmus if est[c].wants_more]
            got = self.pick_move(est, donors, takers)
            if got is None:
                break
            donor, taker, amt = got
            donor.set_quota(donor.quota - amt)
            taker.set_quota(taker.quota + amt)
            moves.append((donor, taker, amt))
            est[donor] = marginal_benefit(donor, now, self.cfg)
            est[taker] = marginal_benefit(taker, now, self.cfg)
        for c in cmus:
            c.buffer_window.reset_window()
        return moves

    def seed(self, newcomer: "CacheManageUnit",
             cmus: List["CacheManageUnit"]) -> None:
        """A newly promoted stream immediately receives its minimum share
        from the lowest-benefit donors (late arrivals must not starve until
        the next round)."""
        while newcomer.quota < self.cfg.min_share:
            donors = [c for c in cmus
                      if c is not newcomer
                      and c.quota - self.cfg.min_share >= self.cfg.rebalance_quantum]
            if not donors:
                break
            est = {c: marginal_benefit(c, 0.0, self.cfg) for c in donors}
            donor = min(donors, key=lambda c: est[c].benefit)
            amt = min(self.cfg.rebalance_quantum,
                      donor.quota - self.cfg.min_share,
                      self.cfg.min_share - newcomer.quota)
            if amt <= 0:
                break
            donor.set_quota(donor.quota - amt)
            newcomer.set_quota(newcomer.quota + amt)


# ---------------------------------------------------------------------------
# Baseline allocators (§5.4): Quiver-style and Fluid-style, extended to mixed
# workloads exactly as the paper's evaluation does.
# ---------------------------------------------------------------------------

class QuiverAllocator:
    """Quiver [49]-style: profile per-training-job benefit; split the space
    evenly between workload *types*, then give the training half to the
    highest-benefit training job (winner-take, per the paper's extension)."""

    def __init__(self, cfg: CacheConfig) -> None:
        self.cfg = cfg
        self.last_round = 0.0

    def due(self, now: float) -> bool:
        return now - self.last_round >= self.cfg.rebalance_period

    def rebalance(self, cmus: List["CacheManageUnit"], now: float,
                  capacity: int) -> None:
        self.last_round = now
        if not cmus:
            return
        training = [c for c in cmus if c.effective_pattern() is Pattern.RANDOM]
        other = [c for c in cmus if c not in training]
        half = capacity // 2
        if training:
            # benefit ~ data consumption rate / dataset size (Quiver's probe)
            best = max(training, key=lambda c: c.arrival_rate(now) /
                       max(1, c.dataset_bytes))
            for c in training:
                c.set_quota(self.cfg.min_share if c is not best else
                            max(self.cfg.min_share,
                                half - self.cfg.min_share * (len(training) - 1)))
        pool = capacity - sum(c.quota for c in training)
        if other:
            share = max(self.cfg.min_share, pool // len(other))
            for c in other:
                c.set_quota(share)


class FluidAllocator:
    """Fluid [40]-style: quota proportional to batch size (demand rate) for
    training jobs; query workloads share whatever training left unclaimed."""

    def __init__(self, cfg: CacheConfig) -> None:
        self.cfg = cfg
        self.last_round = 0.0

    def due(self, now: float) -> bool:
        return now - self.last_round >= self.cfg.rebalance_period

    def rebalance(self, cmus: List["CacheManageUnit"], now: float,
                  capacity: int) -> None:
        self.last_round = now
        training = [c for c in cmus if c.effective_pattern() is Pattern.RANDOM]
        other = [c for c in cmus if c not in training]
        rates = {c: max(1e-9, c.arrival_rate(now)) for c in training}
        total_rate = sum(rates.values())
        claimed = 0
        for c in training:
            q = (int(capacity * 0.7 * rates[c] / total_rate)
                 if total_rate > 0 else self.cfg.min_share)
            q = max(self.cfg.min_share, min(q, c.dataset_bytes))
            c.set_quota(q)
            claimed += q
        pool = max(0, capacity - claimed)
        if other:
            share = max(self.cfg.min_share, pool // len(other))
            for c in other:
                c.set_quota(share)

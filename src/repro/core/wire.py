"""Shared reply codec: one outcome encoding for every wire.

The multi-process shard driver (PR 5) proved out a compact, key-free
form for shipping a kernel ``ReadOutcome`` across a process boundary:
``(first_block, sizes, hit mask, prefetched-hit mask, prefetches)``.
The network cache daemon (``repro.daemon``) speaks the same frames over
a socket, so the codec lives here — imported by both
``core.procdriver`` (pipes) and ``daemon.wire`` (framed socket
protocol) so driver and daemon can never drift apart.

Design constraint carried over from PR 5: block **keys never cross the
wire**.  The kernel serves an extent as consecutive blocks
``first..first+n-1`` and the receiver still holds the request that
produced the outcome, so every key is reconstructible from
``(file_path, first_block + i)``.  What travels is plain ints and the
prefetch candidate list (pickle's C fast path); :class:`WireOutcome`
materializes ``BlockResult`` objects lazily so metadata-only callers
never pay for the reconstruction.
"""
from __future__ import annotations

from typing import List, Optional

from .types import PathT

__all__ = ["WireOutcome", "encode_outcome"]


def encode_outcome(out, first_block: int) -> tuple:
    """Compact wire form of one outcome: ``(first_block, sizes, hit
    mask, prefetched-hit mask, prefetches)`` — **no block keys**.

    ``out`` is anything with the ``ReadOutcome`` duck type (``blocks`` /
    ``prefetches``); a :class:`WireOutcome` re-encodes for free — its
    original tuple is returned as-is, so a daemon proxying outcomes it
    received from the process driver never re-materializes blocks."""
    if isinstance(out, WireOutcome):
        return out._enc
    hits = pf = 0
    sizes = []
    for i, b in enumerate(out.blocks):
        sizes.append(b.size)
        if b.hit:
            hits |= 1 << i
        if b.prefetched_hit:
            pf |= 1 << i
    return first_block, sizes, hits, pf, out.prefetches


class WireOutcome:
    """Receiver-side view of a wire-encoded ``ReadOutcome``: same duck
    type (``blocks`` / ``prefetches`` / ``cached_bytes`` /
    ``remote_bytes``), block objects (and their key strings)
    materialized on first access from the originating request."""

    __slots__ = ("_enc", "_path", "_blocks", "prefetches")

    def __init__(self, enc: tuple, file_path: PathT) -> None:
        self._enc = enc
        self._path = file_path
        self._blocks: Optional[List] = None
        self.prefetches = enc[4]

    @property
    def blocks(self) -> List:
        got = self._blocks
        if got is None:
            from .cache import path_key
            from .igtcache import BlockResult
            from .types import block_key
            first, sizes, hits, pf, _ = self._enc
            path = self._path
            got = [BlockResult(path_key(block_key(path, first + i)), s,
                               bool(hits >> i & 1), bool(pf >> i & 1))
                   for i, s in enumerate(sizes)]
            self._blocks = got
        return got

    @property
    def remote_bytes(self) -> int:
        _, sizes, hits, _, _ = self._enc
        return sum(s for i, s in enumerate(sizes) if not hits >> i & 1)

    @property
    def cached_bytes(self) -> int:
        _, sizes, hits, _, _ = self._enc
        return sum(s for i, s in enumerate(sizes) if hits >> i & 1)

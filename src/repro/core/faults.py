"""Fault-tolerance primitives shared by the shard driver and the client.

This module sits *below* both ``core.procdriver`` and ``core.client`` in
the import graph (procdriver imports client; client must catch driver
failures), so the error/budget vocabulary lives here:

  * ``ShardUnavailableError`` — a typed, per-shard failure the client can
    catch to serve a degraded read instead of surfacing a crash.
  * ``RestartBudget`` — sliding-window restart rate limit: a shard that
    keeps dying stops being respawned and is marked permanently DOWN.
  * shard state constants (``SHARD_UP`` / ``SHARD_RESTARTING`` /
    ``SHARD_DOWN``) used by the driver's supervisor and reported through
    ``fault_stats()``.

See docs/RELIABILITY.md for the full failure model.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

__all__ = [
    "DaemonUnavailableError", "RestartBudget", "SHARD_DOWN",
    "SHARD_RESTARTING", "SHARD_UP", "ShardUnavailableError",
]

# Shard lifecycle states (strings: cheap to report through stats dicts).
# The daemon supervisor reuses the same vocabulary for the whole-daemon
# lifecycle (up / restarting / down), reported via its events log.
SHARD_UP = "up"                  # worker alive, serving RPCs
SHARD_RESTARTING = "restarting"  # worker died, respawn in progress
SHARD_DOWN = "down"              # restart budget exhausted: permanently out


class DaemonUnavailableError(ConnectionError):
    """The cache daemon behind a ``RemoteCacheClient`` is unreachable —
    crashed, draining, or gone past its restart budget.

    Subclasses ``ConnectionError`` so pre-existing handlers (which
    matched the raw socket errors the old client surfaced) keep working;
    new callers catch this type for the daemon analog of
    :class:`ShardUnavailableError`.  With ``degraded=True`` (the client
    default) readers never see it — reads are served straight from the
    backing store until the daemon returns; it surfaces only for
    ``degraded=False`` clients and for operations that *need* the daemon
    (stats, snapshots, flush-with-result).

    ``state`` is the client's view of the connection
    (``"down"`` while reconnecting, ``"closed"`` after ``close()``).
    """

    def __init__(self, message: str, *, state: str = "down") -> None:
        super().__init__(message)
        self.state = state


class ShardUnavailableError(RuntimeError):
    """A shard worker is dead, restarting, or permanently down.

    Subclasses ``RuntimeError`` so pre-fault-tolerance callers (which
    matched the driver's generic worker-died RuntimeError) keep working;
    new callers catch this type to trigger degraded-mode reads.

    ``partial`` / ``indices`` carry partial-batch context for
    ``read_batch``: ``partial`` is the per-request outcome list with
    ``None`` holes at the failed positions, ``indices`` names those
    positions.  The client patches only the holes via degraded fetches —
    re-issuing the whole batch would double-observe the surviving
    shards' keys and distort their kernels' access streams.
    """

    def __init__(self, message: str, *, sid: int = -1,
                 state: str = SHARD_RESTARTING,
                 partial: Optional[list] = None,
                 indices: Optional[List[int]] = None) -> None:
        super().__init__(message)
        self.sid = sid
        self.state = state
        self.partial = partial
        self.indices = indices


@dataclass
class RestartBudget:
    """Sliding-window restart rate limit.

    ``allow(now)`` consumes one restart token if fewer than
    ``max_restarts`` fired within the trailing ``window_s`` seconds;
    otherwise returns ``False`` — the caller marks the shard permanently
    DOWN.  A crash-looping worker (bad region, poisoned store) thus
    converges to a stable degraded state instead of flapping forever.

    Timestamps are caller-supplied (wall or virtual clock) so tests are
    deterministic.
    """

    max_restarts: int = 3
    window_s: float = 60.0
    history: Deque[float] = field(default_factory=deque)

    def allow(self, now: float) -> bool:
        while self.history and now - self.history[0] > self.window_s:
            self.history.popleft()
        if len(self.history) >= self.max_restarts:
            return False
        self.history.append(now)
        return True

    @property
    def used(self) -> int:
        return len(self.history)
